"""Table II — predictor accuracy (MSE / MAPE) per model family x circuit.

MAPE is reported only where the paper reports it (M_ED, M_L) — value
predictors and static energy have near-zero-centered targets that
over-amplify percentage error (paper §V).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bank, emit, save_json

_MAPE_OK = {"M_ED", "M_L"}


def run(full: bool = False):
    rows = []
    for circuit in ("crossbar", "lif"):
        b = bank(circuit, full)
        for pname, fams in b.results.items():
            for fam, r in fams.items():
                row = dict(circuit=circuit, predictor=pname, family=fam,
                           test_mse=r.test_mse,
                           test_mape=(r.test_mape if pname in _MAPE_OK
                                      else None),
                           selected=bool(b.selected[pname] is r.model))
                rows.append(row)
                mape = f"mape={r.test_mape:.2f}%" if pname in _MAPE_OK else ""
                emit(f"table2/{circuit}/{pname}/{fam}", r.test_mse, mape)
    save_json("table2_accuracy", rows)
    # selected-model summary (the paper's bold entries)
    sel = {f"{c}/{p}": fam for c in ("crossbar", "lif")
           for p, fams in bank(c, full).results.items()
           for fam, r in fams.items() if bank(c, full).selected[p] is r.model}
    save_json("table2_selected", sel)
    return rows
