"""Shared benchmark plumbing: dataset/bank/surrogate caching, CSV emission,
and compile-vs-steady-state timing.

Timing contract: benchmark numbers NEVER include first-call jit
compilation. Either use artifacts that already separate the two
(``NetworkRun.compile_seconds`` / ``LayerRun.compile_seconds``) or wrap
the measured callable in :func:`warm_timed`, which performs one explicit
warmup call (reported as ``cold_seconds``) before timing steady state.
"""

from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.kernels import ops

RESULTS_DIR = ops.bench_results_dir()

# default scale (CPU container); --full switches to paper scale
SCALE = {
    "crossbar_runs": 400, "lif_runs": 800, "n_steps": 125,
    "gbdt_trees": 60, "gbdt_depth": 8, "mlp_epochs": 90,
    "prop_neurons": 2000, "prop_steps": 100,
    "scaling_ns": (10, 100, 1000, 5000, 20000),
    "scaling_steps": 100,
}

FULL_SCALE = {
    "crossbar_runs": 1000, "lif_runs": 2000, "n_steps": 125,
    "gbdt_trees": 120, "gbdt_depth": 10, "mlp_epochs": 150,
    "prop_neurons": 20000, "prop_steps": 100,
    "scaling_ns": (10, 100, 1000, 5000, 20000, 200000),
    "scaling_steps": 100,
}


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")


def warm_timed(fn, *args, repeats: int = 1, stat: str = "mean", **kw):
    """Explicit-warmup timing: (last_result, cold_seconds, steady_seconds).

    ``cold_seconds`` is the first call (trace + compile + execute);
    ``steady_seconds`` aggregates ``repeats`` subsequent calls — the mean
    by default, or the minimum with ``stat="min"`` (the noise-robust
    statistic for A/B comparisons on shared machines, where occasional
    contention inflates individual calls). Use for any measured callable
    that jit-compiles lazily on first call."""
    if stat not in ("mean", "min"):
        raise ValueError(f"stat must be 'mean' or 'min': {stat!r}")
    t0 = time.time()
    out = fn(*args, **kw)
    cold = time.time() - t0
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        out = fn(*args, **kw)
        times.append(time.time() - t0)
    steady = min(times) if stat == "min" else sum(times) / len(times)
    return out, cold, steady


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


@functools.lru_cache(maxsize=None)
def dataset(circuit: str, full: bool = False):
    from repro.core.dataset import TestbenchConfig, build_dataset
    sc = FULL_SCALE if full else SCALE
    runs = sc["crossbar_runs"] if circuit == "crossbar" else sc["lif_runs"]
    return build_dataset(circuit, TestbenchConfig(n_runs=runs,
                                                  n_steps=sc["n_steps"]))


@functools.lru_cache(maxsize=None)
def bank(circuit: str, full: bool = False,
         families: tuple = ("mean", "table", "linear", "gbdt", "mlp")):
    """Trains all model families; caches per circuit."""
    from repro.core.models import MODEL_FAMILIES, GBDTModel, MLPModel
    from repro.core.predictors import PredictorBank
    sc = FULL_SCALE if full else SCALE
    # configure heavy families to the benchmark scale
    MODEL_FAMILIES["gbdt"] = lambda: GBDTModel(n_trees=sc["gbdt_trees"],
                                               max_depth=sc["gbdt_depth"])
    MODEL_FAMILIES["mlp"] = lambda: MLPModel(max_epochs=sc["mlp_epochs"])
    b = PredictorBank(circuit, families=families).fit(dataset(circuit, full))
    from repro.core.models import GBDTModel as G, MLPModel as M
    MODEL_FAMILIES["gbdt"] = G
    MODEL_FAMILIES["mlp"] = M
    return b


@functools.lru_cache(maxsize=None)
def surrogate(circuit: str, full: bool = False,
              families: tuple = ("mean", "table", "linear", "gbdt", "mlp")):
    """The frozen deployable artifact for ``bank(...)`` (cached)."""
    return bank(circuit, full, families).to_surrogate()
