"""Benchmark harness — one entry per paper table (+ the roofline report).

``PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]
[--json BENCH.json]``

Prints ``name,us_per_call,derived`` CSV lines and writes JSON records under
results/benchmarks/. ``--json PATH`` additionally writes ONE
machine-readable trajectory record: a headline ``summary`` (events/s,
fused speedup, peak RSS, compile vs steady seconds) over the full
per-suite records — the perf baseline future PRs diff against (see
``BENCH_5.json`` at the repo root).

  table1    model training/testing times            (paper Table I)
  table2    predictor accuracy MSE/MAPE             (paper Table II)
  table3    error propagation LASANA-O vs -P + Fig8 (paper Table III)
  table4    runtime scaling vs layer size           (paper Table IV)
  network   network engine events/s vs naive loop   (§V-E system scale)
  mixed     heterogeneous crossbar->LIF graph       (§V-E mixed-signal)
  streaming chunked runs vs monolithic, T=10k       (ISSUE-4 tentpole)
  dse       vectorized 1024-candidate sweep vs loop (ISSUE-6 tentpole)
  serve     multi-tenant continuous batching        (ISSUE-8 tentpole)
  roofline  dry-run roofline terms                  (EXPERIMENTS §Roofline)
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _get(record, *path):
    """Nested dict lookup that tolerates missing suites/fields."""
    cur = record
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def _summary(records: dict) -> dict:
    """The headline trajectory numbers future PRs diff against."""
    net = records.get("network") or {}
    stream = records.get("streaming") or {}
    dse = records.get("dse") or {}
    serve = records.get("serve") or {}
    return {
        # throughput
        "events_per_sec_engine": _get(net, "events_per_sec_engine"),
        "events_per_sec_fused": _get(net, "fused_ab",
                                     "events_per_sec_fused"),
        "events_per_sec_unfused": _get(net, "fused_ab",
                                       "events_per_sec_unfused"),
        "events_per_sec_mega": _get(net, "fused_ab",
                                    "events_per_sec_mega"),
        "events_per_sec_stream": _get(stream, "events_per_sec_stream"),
        "events_per_sec_stream_mega": _get(stream,
                                           "events_per_sec_stream_mega"),
        # the ISSUE-5 headline
        "fused_speedup": _get(net, "fused_ab", "fused_speedup"),
        # the ISSUE-7 headline
        "mega_speedup_vs_fused": _get(net, "fused_ab",
                                      "mega_speedup_vs_fused"),
        "mega_speedup_vs_unfused": _get(net, "fused_ab",
                                        "mega_speedup_vs_unfused"),
        "mega_over_fused_stream": _get(stream, "mega_over_fused_stream"),
        "fused_hlo_dots": _get(net, "fused_ab", "hlo_fused", "dots"),
        "unfused_hlo_dots": _get(net, "fused_ab", "hlo_unfused", "dots"),
        "fused_over_unfused_stream": _get(stream,
                                          "fused_over_unfused_stream"),
        # memory
        "peak_rss_kb_stream": _get(stream, "peak_rss_kb_stream"),
        "peak_rss_kb_mono": _get(stream, "peak_rss_kb_mono"),
        # compile vs steady split
        "compile_seconds_fused": _get(net, "fused_ab",
                                      "fused_compile_seconds"),
        "steady_seconds_fused": _get(net, "fused_ab",
                                     "fused_steady_seconds"),
        "steady_seconds_unfused": _get(net, "fused_ab",
                                       "unfused_steady_seconds"),
        # the ISSUE-6 design-space sweep
        "dse_candidates_per_sec": _get(dse, "candidates_per_sec_batched"),
        "dse_speedup_vs_loop": _get(dse, "speedup_vs_loop"),
        "dse_compile_count": _get(dse, "compile_count"),
        "dse_pareto_size": _get(dse, "pareto_size"),
        # the ISSUE-8 serving layer
        "serve_requests_per_sec": _get(serve, "requests_per_sec_served"),
        "serve_speedup_vs_serial": _get(serve, "speedup_vs_serial"),
        "serve_compile_count": _get(serve, "compile_count"),
        "serve_occupancy": _get(serve, "batch_occupancy"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets/models (slow)")
    ap.add_argument("--only", default="",
                    help="comma list: table1,table2,table3,table4,network,"
                         "mixed,streaming,dse,serve,roofline")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write one machine-readable trajectory record "
                         "(summary + per-suite outputs) to PATH")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_dse, bench_mixed,
                            bench_models, bench_network, bench_propagation,
                            bench_roofline, bench_scaling, bench_serve,
                            bench_streaming)
    suites = {
        "table1": bench_models.run,
        "table2": bench_accuracy.run,
        "table3": bench_propagation.run,
        "table4": bench_scaling.run,
        "network": bench_network.run,
        "mixed": bench_mixed.run,
        "streaming": bench_streaming.run,
        "dse": bench_dse.run,
        "serve": bench_serve.run,
        "roofline": bench_roofline.run,
    }
    only = [s for s in args.only.split(",") if s] or list(suites)
    print("name,us_per_call,derived")
    records: dict = {}
    wall: dict = {}
    aborted = None        # a suite's acceptance SystemExit (smoke gates)
    for name in only:
        t0 = time.time()
        try:
            records[name] = suites[name](full=args.full)
        except SystemExit as e:
            # acceptance gates (fused floor, record parity) abort the run
            # — but the trajectory record must still be written below, or
            # the numbers needed to DIAGNOSE the failure are lost; gates
            # attach their measurements to the exception (bench_record)
            records[name] = {"aborted": str(e) or "SystemExit",
                             **(getattr(e, "bench_record", None) or {})}
            aborted = e
        wall[name] = time.time() - t0
        print(f"# {name} done in {wall[name]:.1f}s", file=sys.stderr)
        if aborted is not None:
            break

    if args.json:
        import jax

        from repro.kernels import ops
        payload = {
            "schema": 1,
            "generated_by": "benchmarks.run",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "full": bool(args.full),
            "smoke": ops.bench_smoke(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "suites_run": only,
            "aborted": str(aborted) if aborted is not None else None,
            "suite_wall_seconds": wall,
            "summary": _summary(records),
            "suites": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"# wrote {args.json}", file=sys.stderr)
    if aborted is not None:
        raise aborted


if __name__ == "__main__":
    main()
