"""Benchmark harness — one entry per paper table (+ the roofline report).

``PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]``

Prints ``name,us_per_call,derived`` CSV lines and writes JSON records under
results/benchmarks/.

  table1    model training/testing times            (paper Table I)
  table2    predictor accuracy MSE/MAPE             (paper Table II)
  table3    error propagation LASANA-O vs -P + Fig8 (paper Table III)
  table4    runtime scaling vs layer size           (paper Table IV)
  network   network engine events/s vs naive loop   (§V-E system scale)
  mixed     heterogeneous crossbar->LIF graph       (§V-E mixed-signal)
  streaming chunked runs vs monolithic, T=10k       (ISSUE-4 tentpole)
  roofline  dry-run roofline terms                  (EXPERIMENTS §Roofline)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets/models (slow)")
    ap.add_argument("--only", default="",
                    help="comma list: table1,table2,table3,table4,network,"
                         "mixed,streaming,roofline")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_mixed, bench_models,
                            bench_network, bench_propagation,
                            bench_roofline, bench_scaling, bench_streaming)
    suites = {
        "table1": bench_models.run,
        "table2": bench_accuracy.run,
        "table3": bench_propagation.run,
        "table4": bench_scaling.run,
        "network": bench_network.run,
        "mixed": bench_mixed.run,
        "streaming": bench_streaming.run,
        "roofline": bench_roofline.run,
    }
    only = [s for s in args.only.split(",") if s] or list(suites)
    print("name,us_per_call,derived")
    for name in only:
        t0 = time.time()
        suites[name](full=args.full)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
