"""Mixed-circuit graph throughput + attribution (heterogeneous engine).

A MENAGE-style crossbar->LIF->LIF graph with a recurrent inhibition edge
runs on all three backends from one ``NetworkSpec``:

  behavioral  — ideal update baseline (no energy)
  lasana      — Algorithm 1 over a per-circuit-kind SurrogateLibrary
  golden      — transient reference (energy ground truth)

Reported: events/s per backend, LASANA-vs-behavioral spike mismatch
(acceptance: < 2%), energy error vs golden, and the per-circuit-kind
energy/event attribution from ``NetworkRun.report()``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, surrogate

SHAPE = (196, 48, 32, 10)      # crossbar MAC front-end, two LIF banks
T_STEPS = 40
BATCH = 8


def _mixed_spec(seed=0):
    import jax.numpy as jnp
    from repro.core.network import (crossbar_layer, graph_spec, lif_layer,
                                    recurrent_edge)
    rng = np.random.default_rng(seed)
    xw = rng.integers(-1, 2, (SHAPE[0], SHAPE[1])).astype(np.float32)
    lw1 = (rng.normal(0, (2.0 / SHAPE[1]) ** 0.5,
                      (SHAPE[1], SHAPE[2])) * 2.2).astype(np.float32)
    lw2 = (rng.normal(0, (2.0 / SHAPE[2]) ** 0.5,
                      (SHAPE[2], SHAPE[3])) * 2.2).astype(np.float32)
    p = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    inhib = -0.4 * (1 - np.eye(SHAPE[3], dtype=np.float32))
    return graph_spec(
        [crossbar_layer(xw), lif_layer(lw1, p), lif_layer(lw2, p)],
        edges=[recurrent_edge(len(SHAPE) - 2, len(SHAPE) - 2, inhib)])


def _dac_stimulus(seed=1):
    """Time-varying ternary DAC patterns (~20% of lines re-drawn per tick)."""
    rng = np.random.default_rng(seed)
    seq = np.empty((T_STEPS, BATCH, SHAPE[0]), np.float32)
    cur = rng.integers(-1, 2, (BATCH, SHAPE[0])).astype(np.float32)
    for t in range(T_STEPS):
        flip = rng.random((BATCH, SHAPE[0])) < 0.2
        cur = np.where(flip, rng.integers(-1, 2, (BATCH, SHAPE[0])), cur)
        seq[t] = cur * 0.8
    return seq


def run(full: bool = False):
    import repro.lasana as lasana

    spec = _mixed_spec()
    seq = _dac_stimulus()
    library = lasana.SurrogateLibrary({
        "lif": surrogate("lif", full, families=("mean", "linear", "mlp")),
        "crossbar": surrogate("crossbar", full,
                              families=("linear", "gbdt", "mlp"))})

    runs = {}
    for backend, kw in (("behavioral", {}),
                        ("lasana", {"surrogates": library}), ("golden", {})):
        # one run per backend suffices: the engine AOT-compiles before
        # executing, so wall_seconds/events_per_sec are already
        # steady-state and compile_seconds is reported separately
        runs[backend] = lasana.simulate(spec, seq, backend=backend, **kw)

    reps = {k: r.report() for k, r in runs.items()}
    mism = float(np.mean([
        np.mean((runs["lasana"].layer_spikes[i] > 0.75)
                != (runs["behavioral"].layer_spikes[i] > 0.75))
        for i in (1, 2)]))
    e_l = reps["lasana"]["network"]["energy_j"]
    e_g = reps["golden"]["network"]["energy_j"]

    out = {
        "shape": list(SHAPE), "t_steps": T_STEPS, "batch": BATCH,
        "reports": reps,
        "by_circuit": reps["lasana"]["by_circuit"],
        "spike_mismatch_lasana_vs_behavioral": mism,
        "energy_err_vs_golden": abs(e_l - e_g) / max(e_g, 1e-30),
        "events_per_sec": {k: r["network"]["events_per_sec"]
                           for k, r in reps.items()},
    }
    save_json("mixed_network", out)
    for k, r in reps.items():
        emit(f"mixed/events_per_sec_{k}", r["network"]["events_per_sec"])
    emit("mixed/spike_mismatch", mism, "target < 0.02")
    emit("mixed/energy_err_vs_golden", out["energy_err_vs_golden"])
    for kind, agg in out["by_circuit"].items():
        emit(f"mixed/energy_nj_{kind}", agg["energy_j"] * 1e9,
             f"{agg['events']} events")
    if mism >= 0.02:
        print(f"# WARNING: mixed spike mismatch {mism:.2%} above 2% target")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
