"""Roofline table — re-reads the dry-run JSON cache (launch/dryrun.py must
have populated results/dryrun) and emits the per-cell roofline terms used by
EXPERIMENTS §Roofline."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json


def run(full: bool = False, dryrun_dir: str = "results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        ro = r["roofline"]
        rows.append(dict(
            arch=r.get("arch", r.get("cell", "?")),
            shape=r.get("shape", "-"), mesh=r.get("mesh", "-"),
            compute_s=ro["compute_s"], memory_s=ro["memory_s"],
            collective_s=ro["collective_s"], dominant=ro["dominant"],
            useful_ratio=ro["useful_ratio"],
            peak_gib=r.get("memory", {}).get("peak_live_bytes_per_device",
                                             0) / 2 ** 30))
        if r.get("mesh", "singlepod") == "singlepod":
            emit(f"roofline/{rows[-1]['arch']}/{rows[-1]['shape']}",
                 max(ro['compute_s'], ro['memory_s'], ro['collective_s']) * 1e6,
                 f"dom={ro['dominant']} useful={ro['useful_ratio']:.3f}")
    save_json("roofline_table", rows)
    return rows
