"""Serving throughput — the ISSUE-8 continuous-batching server.

A mixed multi-tenant workload (two distinct network specs = two shape
buckets, two hot-swappable surrogate versions, three tenants, random
stimulus lengths and batch sizes) is dispatched two ways:

  served   all requests submitted up-front to one ``lasana.serve()``
           server: the continuous-batching scheduler packs them onto the
           slot axes of (at most) one compiled program per bucket,
           join/leave at chunk boundaries
  serial   the pre-ISSUE-8 formulation: the same requests one
           ``lasana.simulate`` at a time on warm engines (compile
           excluded from both sides)

Reported: requests/s and wall seconds of both paths and their ratio
(acceptance: served >= 2x serial at full scale), the server's
``compile_count`` (acceptance: <= bucket count — programs scale with
shapes, never with requests/tenants/versions), mean batch occupancy,
worst queue wait (acceptance: no starvation), and per-request record
parity against solo runs (acceptance: bitwise on discrete records,
rtol 1e-5 on f32 energy/latency reductions plus a one-ULP absolute
epsilon on latency maxes — always enforced).

A fault-arm smoke then replays a slice of the workload through a
retry-enabled server while a seeded ``FaultPlan`` injects lane-step
crashes and NaN bursts at rate (ISSUE-10): every request must still
complete with the same record parity and zero leaked in-flight work.

``REPRO_BENCH_SMOKE=1`` shrinks to 64 requests / 32-tick chunks and
relaxes the speedup floor to parity (CI containers are noisy); the
correctness gates hard-fail either way via SystemExit with the record
attached.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json

N_REQUESTS, N_REQUESTS_SMOKE = 384, 64
CHUNK, CHUNK_SMOKE = 128, 32
T_CHOICES, T_CHOICES_SMOKE = (128, 256), (32, 64)
SLOT_WIDTHS, SLOT_WIDTHS_SMOKE = (16,), (8,)
N_TENANTS = 3

MIN_SPEEDUP, MIN_SPEEDUP_SMOKE = 2.0, 1.0
RTOL = 1e-5            # energy sums (reassociated f32 addition)
ATOL_LATENCY = 1e-6    # latency maxes additionally carry one-ULP (2^-23)
                       # vectorization-width noise, visible as absolute
                       # epsilon on near-zero latencies
RESULT_TIMEOUT = 600.0


def _light_surrogate(seed=0):
    """A fast linear-family LIF surrogate (training time is not what this
    suite measures)."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("lif", TestbenchConfig(n_runs=150, n_steps=80,
                                              seed=seed))
    return PredictorBank("lif", families=("linear",)).fit(ds).to_surrogate()


def _spec(seed):
    from repro.core.network import snn_spec
    rng = np.random.default_rng(seed)
    ws = [rng.normal(0, 0.8, (16, 10)).astype(np.float32),
          rng.normal(0, 0.8, (10, 5)).astype(np.float32)]
    return snn_spec(ws, [np.asarray([0.58, 0.5, 0.5, 0.5], np.float32)] * 2)


def _workload(n_req, t_choices, rng):
    """(spec_idx, surrogate_ref, tenant, stimulus) per request — both
    specs, both versions, and every tenant are guaranteed to appear.
    Requests are single-stream (batch 1, the per-tenant streaming regime
    this service multiplexes); multi-slot requests are covered by
    tests/test_serve.py parity."""
    jobs = []
    for i in range(n_req):
        t = int(rng.choice(t_choices))
        jobs.append({
            "spec": i % 2,
            "surrogate": "lif@1" if (i // 2) % 2 else "lif@2",
            "tenant": f"tenant{i % N_TENANTS}",
            "x": (rng.random((t, 1, 16)) < 0.2).astype(np.float32) * 1.5,
        })
    return jobs


def _check_parity(solo, served) -> bool:
    return (np.array_equal(solo.outputs, served.outputs)
            and np.array_equal(solo.events, served.events)
            and (solo.out_spikes is None
                 or np.array_equal(solo.out_spikes, served.out_spikes))
            and np.allclose(solo.energy, served.energy, rtol=RTOL, atol=0)
            and np.allclose(solo.latency, served.latency, rtol=RTOL,
                            atol=ATOL_LATENCY)
            and np.allclose(solo.flush_energy, served.flush_energy,
                            rtol=RTOL, atol=0))


def run(full: bool = False) -> dict:
    import repro.lasana as lasana

    from repro.kernels import ops
    smoke = ops.bench_smoke()
    n_req = N_REQUESTS_SMOKE if smoke else N_REQUESTS
    chunk = CHUNK_SMOKE if smoke else CHUNK
    t_choices = T_CHOICES_SMOKE if smoke else T_CHOICES
    widths = SLOT_WIDTHS_SMOKE if smoke else SLOT_WIDTHS

    t0 = time.time()
    s1, s2 = _light_surrogate(seed=0), _light_surrogate(seed=1)
    train_s = time.time() - t0
    specs = [_spec(0), _spec(1)]
    rng = np.random.default_rng(0)
    jobs = _workload(n_req, t_choices, rng)
    surs = {"lif@1": s1, "lif@2": s2}
    n_buckets = len(specs) * len(widths)

    srv = lasana.serve(slot_widths=widths, chunk_ticks=chunk,
                       max_in_flight=256, max_queue=1024)
    srv.register_surrogate("lif", s1)
    srv.register_surrogate("lif", s2)       # hot-swap: v2 is now latest

    # warm every lane (one request per spec x version): compiles the slot
    # programs once per bucket; versions reuse them
    t0 = time.time()
    for i, ref in enumerate(("lif@1", "lif@2", "lif@1", "lif@2")):
        srv.submit(specs[i % 2], jobs[0]["x"][:chunk],
                   surrogates=ref).result(timeout=RESULT_TIMEOUT)
    warm_s = time.time() - t0

    # timed served phase: everything in flight at once (the point)
    t0 = time.time()
    handles = [srv.submit(specs[j["spec"]], j["x"],
                          surrogates=j["surrogate"], tenant=j["tenant"])
               for j in jobs]
    results = [h.result(timeout=RESULT_TIMEOUT) for h in handles]
    served_s = time.time() - t0
    stats = srv.stats()                     # BEFORE solo runs share engines
    compile_count = stats["compile_count"]
    srv.close()

    # solo references double as parity oracles and as the serial warmup
    solos = [lasana.simulate(specs[j["spec"]], j["x"],
                             surrogates=surs[j["surrogate"]],
                             record_hidden=False) for j in jobs]
    mismatches = [i for i, (s, r) in enumerate(zip(solos, results))
                  if not _check_parity(s, r)]

    t0 = time.time()
    for j in jobs:
        lasana.simulate(specs[j["spec"]], j["x"],
                        surrogates=surs[j["surrogate"]],
                        record_hidden=False)
    serial_s = time.time() - t0
    speedup = serial_s / served_s

    # fault-arm smoke: replay a slice of the workload through a server
    # with retries enabled while a seeded plan injects lane-step crashes
    # and NaN bursts at rate (bounded by max_fires). Acceptance: every
    # request still completes with full record parity and nothing leaks
    # in flight — recovery is a correctness gate, not a perf number.
    # Degradation is disabled here: a behavioral fallback is correct
    # service behavior but would (by design) break the energy parity
    # oracle this bench enforces.
    from repro.resilience import FaultPlan, faults
    n_fault = min(32, n_req)
    # explicit early ordinals guarantee fires even at smoke scale (a
    # rate-only plan can roll zero hits over a few dozen lane steps and
    # silently turn this arm into a no-op); the rate rides on top
    plan = FaultPlan(seed=42, sites={
        "lane.step": {"at": [1, 4], "rate": 0.05, "max_fires": 4},
        "surrogate.nan": {"at": [2], "rate": 0.03, "max_fires": 3},
    })
    fsrv = lasana.serve(slot_widths=widths, chunk_ticks=chunk,
                        max_in_flight=256, max_queue=1024,
                        max_retries=6, retry_backoff_ms=2.0,
                        degrade_after=None)
    fsrv.register_surrogate("lif", s1)
    fsrv.register_surrogate("lif", s2)      # same v1/v2 ladder as above
    t0 = time.time()
    with faults.use_plan(plan):
        fhandles = [fsrv.submit(specs[j["spec"]], j["x"],
                                surrogates=j["surrogate"],
                                tenant=j["tenant"])
                    for j in jobs[:n_fault]]
        fresults = [h.result(timeout=RESULT_TIMEOUT) for h in fhandles]
    fault_s = time.time() - t0
    fstats = fsrv.stats()
    fsrv.close()
    fault_mismatches = [i for i in range(n_fault)
                        if not _check_parity(solos[i], fresults[i])]

    record = {
        "n_requests": n_req,
        "n_buckets": n_buckets,
        "chunk_ticks": chunk,
        "slot_widths": list(widths),
        "n_tenants": N_TENANTS,
        "train_seconds": train_s,
        "warm_seconds": warm_s,
        "served_seconds": served_s,
        "serial_seconds": serial_s,
        "requests_per_sec_served": n_req / served_s,
        "requests_per_sec_serial": n_req / serial_s,
        "speedup_vs_serial": speedup,
        "compile_count": compile_count,
        "batch_occupancy": stats["batch_occupancy"],
        "wait_chunks_max": stats["wait_chunks_max"],
        "chunks_total": stats["chunks_total"],
        "events_per_sec": stats["events_per_sec"],
        "parity_mismatches": len(mismatches),
        "fault_arm": {
            "n_requests": n_fault,
            "seconds": fault_s,
            "requests_retried": fstats["requests_retried"],
            "numerical_faults": fstats["numerical_faults"],
            "lane_hangs": fstats["lane_hangs"],
            "faults_injected": {s: plan.fired[s] for s in sorted(plan.sites)},
            "parity_mismatches": len(fault_mismatches),
        },
    }
    emit("serve_served", served_s / n_req * 1e6,
         f"requests_per_sec={n_req / served_s:.1f}")
    emit("serve_serial", serial_s / n_req * 1e6,
         f"requests_per_sec={n_req / serial_s:.1f}")
    emit("serve_speedup", 0.0, f"x{speedup:.2f}")
    emit("serve_compile_count", 0.0, f"{compile_count}/{n_buckets}")
    emit("serve_occupancy", 0.0, f"{stats['batch_occupancy']:.2f}")
    emit("serve_fault_arm", fault_s / n_fault * 1e6,
         f"injected={sum(plan.fired.values())} "
         f"retried={fstats['requests_retried']} parity_ok="
         f"{len(fault_mismatches) == 0}")
    save_json("serve", record)

    # acceptance gates — parity and program discipline are correctness,
    # not performance: they hard-fail at any scale
    if mismatches:
        err = SystemExit(
            f"continuous-batching parity broke for {len(mismatches)}/"
            f"{n_req} requests (indices {mismatches[:8]}): multiplexed "
            "records must match solo lasana.simulate")
        err.bench_record = record
        raise err
    if compile_count > n_buckets:
        err = SystemExit(
            f"server compiled {compile_count} programs for {n_buckets} "
            "buckets: programs must scale with shapes, not requests/"
            "versions/tenants")
        err.bench_record = record
        raise err
    if stats["wait_chunks_max"] > n_req:
        err = SystemExit(
            f"a request waited {stats['wait_chunks_max']} scheduler "
            f"rounds (> {n_req}): tenant round-robin is starving")
        err.bench_record = record
        raise err
    if fault_mismatches:
        err = SystemExit(
            f"fault-arm parity broke for {len(fault_mismatches)}/"
            f"{n_fault} requests (indices {fault_mismatches[:8]}): a "
            "retried/quarantined request must replay to the same record "
            "as a clean solo run")
        err.bench_record = record
        raise err
    if sum(plan.fired.values()) < 3:
        err = SystemExit(
            f"fault arm injected only {sum(plan.fired.values())} faults "
            "(expected >= 3 from the explicit ordinals): the recovery "
            "path was not actually exercised")
        err.bench_record = record
        raise err
    if fstats["requests_in_flight"] != 0 or fstats["requests_failed"] != 0:
        err = SystemExit(
            f"fault arm leaked work: in_flight="
            f"{fstats['requests_in_flight']}, failed="
            f"{fstats['requests_failed']} after every request was "
            "collected — recovery must drain cleanly")
        err.bench_record = record
        raise err
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    if speedup < floor:
        err = SystemExit(
            f"served speedup {speedup:.2f}x below the {floor:.1f}x "
            "acceptance floor vs serial dispatch")
        err.bench_record = record
        raise err
    return record


if __name__ == "__main__":
    run()
