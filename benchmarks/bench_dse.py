"""Design-space-exploration throughput — the ISSUE-6 vectorized sweep.

A 1024-candidate design space (random layer widths, tile sizes, V_dd
rails, MoE shapes, circuit mixes) is priced two ways with the same
trained crossbar surrogate:

  batched  core/explore.DSEEngine: the whole CandidateSpec batch through
           ONE AOT-compiled ``Surrogate.predict_heads`` pass; tile math
           is vectorized numpy over the candidate arrays
  loop     the pre-ISSUE-6 formulation: one eager per-candidate
           evaluation at a time (measured over a subset, extrapolated)

Reported: candidates/s of both paths and their ratio (acceptance:
batched >= 50x loop), the engine's ``compile_count`` across the full
sweep + a repeat + a retrained-surrogate hot-swap (acceptance: <= 2 —
the sweep is one compiled program and equal-structure surrogates
re-price for free), compile vs steady seconds, and the Pareto frontier
(indices + full rows) over (energy/token, critical latency, analog
fraction).

``REPRO_BENCH_SMOKE=1`` keeps the 1024-candidate space (the compile-once
contract is the point) but trims the loop-baseline subset; the gates
hard-fail the CI smoke leg via SystemExit with the record attached.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json, warm_timed

N_CANDIDATES = 1024
N_CANDIDATES_FULL = 4096
LOOP_SUBSET = 12
LOOP_SUBSET_SMOKE = 6
N_SAMPLES = 128          # testbench rows averaged per tile pricing

MIN_SPEEDUP = 50.0       # ISSUE-6 acceptance floor
MAX_COMPILES = 2


def _light_surrogate(seed=0):
    """A fast linear-family crossbar surrogate (training time is not what
    this suite measures)."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("crossbar", TestbenchConfig(n_runs=200, n_steps=80,
                                                   seed=seed))
    return PredictorBank("crossbar", families=("linear",)).fit(ds) \
        .to_surrogate()


def run(full: bool = False) -> dict:
    from repro.core.explore import CandidateSpec, DSEEngine

    from repro.kernels import ops
    smoke = ops.bench_smoke()
    n_cand = N_CANDIDATES_FULL if full else N_CANDIDATES
    n_loop = LOOP_SUBSET_SMOKE if smoke else LOOP_SUBSET

    t0 = time.time()
    sur = _light_surrogate(seed=0)
    train_s = time.time() - t0

    eng = DSEEngine(n_samples=N_SAMPLES)
    cands = CandidateSpec.sample(n_cand, seed=0)

    # batched path: first call compiles the sweep program, repeats measure
    # steady state (the serving regime a co-design loop lives in)
    rep, cold_s, steady_s = warm_timed(
        lambda: eng.evaluate(cands, sur), repeats=3, stat="min")
    cps_batched = n_cand / steady_s

    # hot-swap: a retrained equal-structure surrogate re-prices the whole
    # space through the SAME compiled program
    sur2 = _light_surrogate(seed=1)
    t0 = time.time()
    rep2 = eng.evaluate(cands, sur2)
    swap_s = time.time() - t0
    swap_changed = bool(
        not np.array_equal(rep2.tile_energy_j, rep.tile_energy_j))

    # loop baseline: eager per-candidate dispatch, extrapolated from a
    # subset (running all n_cand would take minutes by construction)
    sub = cands.take(np.arange(n_loop))
    t0 = time.time()
    for i in range(n_loop):
        eng.evaluate(sub.take([i]), sur, compiled=False)
    loop_s = time.time() - t0
    cps_loop = n_loop / loop_s
    speedup = cps_batched / cps_loop

    front = rep.pareto()
    record = {
        "n_candidates": n_cand,
        "n_samples": N_SAMPLES,
        "train_seconds": train_s,
        "compile_seconds": cold_s,
        "steady_seconds": steady_s,
        "swap_seconds": swap_s,
        "candidates_per_sec_batched": cps_batched,
        "candidates_per_sec_loop": cps_loop,
        "speedup_vs_loop": speedup,
        "compile_count": eng.compile_count,
        "swap_changed_prices": swap_changed,
        "loop_subset": n_loop,
        "pareto_size": int(front.size),
        "pareto_indices": front.tolist(),
        "pareto": rep.as_dict(front),
        "energy_per_token_j_min": float(rep.energy_per_token_j.min()),
        "latency_critical_ns_min": float(rep.latency_critical_ns.min()),
    }
    emit("dse_batched", steady_s / n_cand * 1e6,
         f"candidates_per_sec={cps_batched:.0f}")
    emit("dse_loop", loop_s / n_loop * 1e6,
         f"candidates_per_sec={cps_loop:.2f}")
    emit("dse_speedup", 0.0, f"x{speedup:.0f}")
    emit("dse_compile_count", 0.0, f"{eng.compile_count}")
    emit("dse_pareto", 0.0, f"size={front.size}")
    save_json("dse", record)

    # acceptance gates — a sweep that recompiles per candidate (or fails
    # to beat the loop by the floor) is a broken contract, not a slow run
    if eng.compile_count > MAX_COMPILES:
        err = SystemExit(
            f"DSE sweep recompiled per candidate: compile_count="
            f"{eng.compile_count} > {MAX_COMPILES} over sweep+repeat+swap")
        err.bench_record = record
        raise err
    if speedup < MIN_SPEEDUP:
        err = SystemExit(
            f"DSE batched speedup {speedup:.1f}x below the "
            f"{MIN_SPEEDUP:.0f}x acceptance floor")
        err.bench_record = record
        raise err
    if not swap_changed:
        err = SystemExit(
            "retrained surrogate hot-swap did not change sweep prices — "
            "the compiled program is not reading the surrogate argument")
        err.bench_record = record
        raise err
    return record


if __name__ == "__main__":
    run()
