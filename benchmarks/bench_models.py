"""Table I — total model training and testing times per family x circuit."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bank, emit, save_json


def run(full: bool = False):
    rows = []
    for circuit in ("crossbar", "lif"):
        b = bank(circuit, full)
        # aggregate across the five predictors (the paper reports totals)
        totals: dict[str, dict] = {}
        for pname, fams in b.results.items():
            for fam, r in fams.items():
                t = totals.setdefault(fam, {"train_s": 0.0, "test_s": 0.0})
                t["train_s"] += r.train_time
                t["test_s"] += r.test_time
        for fam, t in totals.items():
            rows.append(dict(circuit=circuit, family=fam, **t))
            emit(f"table1/{circuit}/{fam}/train", t["train_s"] * 1e6,
                 f"test_s={t['test_s']:.4f}")
    save_json("table1_model_times", rows)
    return rows
