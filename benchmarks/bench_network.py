"""Network-engine throughput — batched engine vs naive loop, fused vs
unfused inference (ISSUE-5 A/B).

A 3-layer spiking-MNIST-sized LIF network runs the same event stream two
ways:

  engine  core/network.py: one jit-compiled scan over ticks, all banks
          batched, idle neurons merged into E2 catch-up events
  naive   the pre-engine formulation: a Python loop over ticks and banks,
          one numpy predictor call per model per bank per tick

plus the ISSUE-5 fused-inference A/B on the standard 2-layer CPU
workload: the SAME spec/stimulus/surrogate through two compiled engine
programs —

  fused    lasana_step on ``Surrogate.predict_heads`` (one feature build
           per variant, same-family heads stacked into batched passes)
  unfused  lasana_step with one ``predict`` dispatch per head (the
           pre-ISSUE-5 formulation, ``NetworkEngine(fused=False)``)

Reported: events/s of engine vs naive (acceptance: >= 10x), fused vs
unfused events/s (acceptance: >= 1.3x steady state; the CI smoke leg
hard-fails below 1.0x), the per-program HLO instruction/dot counts of
both A/B programs (fusion must shrink the number of per-tick dot ops —
7 per-head chains collapse into stacked batched matmuls — not just win
a timer race), record parity between the two paths (discrete
outputs/events identical, energies within the documented rtol=1e-5),
compile vs steady-state seconds (explicit AOT warmup — first-call
compilation never pollutes events/s), and the per-layer energy report.

``REPRO_BENCH_SMOKE=1`` runs only the A/B (smaller tick count) and
enforces the >= 1.0x floor + record parity for CI.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bank, emit, save_json, surrogate, warm_timed

SNN_LAYERS = (196, 64, 32, 10)          # CPU scale
SNN_LAYERS_FULL = (784, 256, 128, 10)   # spiking-MNIST scale
T_STEPS = 60
BATCH = 8

AB_LAYERS = (196, 64, 10)               # the standard 2-layer A/B workload
AB_T_STEPS = 60
AB_T_STEPS_SMOKE = 24


def _make_net(layers, seed=0):
    rng = np.random.default_rng(seed)
    ws = []
    for i in range(len(layers) - 1):
        w = rng.normal(0, (2.0 / layers[i]) ** 0.5, (layers[i], layers[i + 1]))
        ws.append((w * 2.2).astype(np.float32))      # drive into spiking range
    params = [np.array([0.58, 0.5, 0.5, 0.5], np.float32) for _ in ws]
    return ws, params


def _poisson_spikes(t, b, n, rate=0.25, seed=1):
    rng = np.random.default_rng(seed)
    return (rng.random((t, b, n)) < rate).astype(np.float32) * 1.5


def run_naive(b, weights, spike_seq, params_list, clock=5.0):
    """Per-bank Python loop: Algorithm 1 semantics, one numpy predictor
    call per model per bank per tick (no jit, no cross-tick fusion)."""
    t_steps, batch, _ = spike_seq.shape
    layers = []
    for w, p in zip(weights, params_list):
        n = batch * w.shape[1]
        layers.append({
            "w": w, "conn": (np.abs(w) > 0).astype(np.float32),
            "v": np.zeros(n, np.float32), "o": np.zeros(n, np.float32),
            "t_last": np.zeros(n, np.float32),
            "params": np.broadcast_to(p[None], (n, p.shape[0])),
        })
    energy = 0.0
    events = 0
    t0 = time.time()
    for ti in range(t_steps):
        t = (ti + 1) * clock
        s = spike_seq[ti]
        for L in layers:
            drive = (s @ L["w"]) / 1.5
            pre = (s > 0.75).astype(np.float32)
            changed = ((pre @ L["conn"]) > 0.5).reshape(-1)
            x = np.stack([np.clip(drive, -1, 1),
                          np.full_like(drive, 1.5),
                          np.full_like(drive, 5.0)], -1).reshape(-1, 3)
            n = L["v"].shape[0]
            stale = changed & (L["t_last"] < t - clock)
            tau_idle = np.maximum(t - L["t_last"] - clock, 0.0)
            fi = np.concatenate([np.zeros_like(x), L["v"][:, None],
                                 tau_idle[:, None], L["params"]], 1)
            v_cur = np.where(stale, b.predict_np("M_V", fi), L["v"])
            e = np.where(stale, b.predict_np("M_ES", fi), 0.0)
            tau = np.full((n, 1), clock, np.float32)
            f = np.concatenate([x, v_cur[:, None], tau, L["params"]], 1)
            o_hat = b.predict_np("M_O", f)
            v_new = b.predict_np("M_V", f)
            fired = o_hat > 0.75
            o_res = np.where(fired, 1.5, 0.0)
            ftr = np.concatenate([f, L["o"][:, None], o_res[:, None]], 1)
            e_evt = np.where(fired, b.predict_np("M_ED", ftr),
                             b.predict_np("M_ES", f))
            b.predict_np("M_L", ftr)
            energy += float(np.sum(e + np.where(changed, e_evt, 0.0)))
            L["v"] = np.where(changed, v_new, v_cur).astype(np.float32)
            L["o"] = np.where(changed, o_res, L["o"]).astype(np.float32)
            L["t_last"] = np.where(changed, t, L["t_last"]).astype(np.float32)
            events += int(changed.sum())
            s = np.where(changed, o_res, 0.0).reshape(batch, -1)
    return {"events": events, "energy_j": energy,
            "wall_seconds": time.time() - t0}


def _hlo_counts(engine) -> dict:
    """HLO instruction / dot-op counts of an engine's compiled programs.

    The per-tick inference body lives inside the scan's while-loop, which
    appears once in the optimized HLO — so instruction counts compare the
    per-tick op graphs of two same-shape programs directly."""
    out = {}
    for key, (compiled, _) in engine._sim_cache.items():
        try:
            txt = compiled.as_text()
        except Exception:          # backend without HLO text dumps
            continue
        lines = [l for l in txt.splitlines() if " = " in l]
        out[key[0]] = {
            "instructions": len(lines),
            "dots": sum(1 for l in lines
                        if " dot(" in l or " custom-call" in l and "gemm"
                        in l),
        }
    return out


def _record_parity(run_f, run_u) -> dict:
    """Fused-vs-unfused record agreement (ISSUE-5 documented tolerance:
    discrete records identical, analog records to rtol 1e-5)."""
    e_f, e_u = run_f.energy, run_u.energy
    rel = float(np.max(np.abs(e_f - e_u)
                       / np.maximum(np.abs(e_u), 1e-30)))
    return {
        "outputs_identical": bool(np.array_equal(run_f.outputs,
                                                 run_u.outputs)),
        "events_identical": bool(np.array_equal(run_f.events,
                                                run_u.events)),
        "energy_max_rel_err": rel,
        "energy_within_tolerance": bool(np.allclose(e_f, e_u, rtol=1e-5,
                                                    atol=1e-20)),
    }


def run_fused_ab(full: bool = False, smoke: bool = False) -> dict:
    """Fused / unfused / megakernel A/B/C on the standard 2-layer CPU
    workload: the SAME spec/stimulus/surrogate through three compiled
    engine programs (per-predict-call, stacked 3-dispatch predict_heads,
    and the ISSUE-7 whole-tick megakernel via ``fused_kernel=True``)."""
    from repro.core.network import NetworkEngine, snn_spec

    t_steps = AB_T_STEPS_SMOKE if smoke else AB_T_STEPS
    ws, params = _make_net(AB_LAYERS, seed=5)
    spikes = _poisson_spikes(t_steps, BATCH, AB_LAYERS[0], seed=6)
    fams = ("mean", "linear", "mlp")
    sur = surrogate("lif", full, families=fams)
    spec = snn_spec(ws, params)

    repeats = 5                        # min-of-N steadies the CI floor
    eng_f = NetworkEngine(spec, surrogates=sur, record_hidden=False,
                          fused_kernel=False)
    run_f, cold_f, steady_f = warm_timed(eng_f.run, spikes,
                                         repeats=repeats, stat="min")
    eng_u = NetworkEngine(spec, surrogates=sur, record_hidden=False,
                          fused=False)
    run_u, cold_u, steady_u = warm_timed(eng_u.run, spikes,
                                         repeats=repeats, stat="min")
    eng_m = NetworkEngine(spec, surrogates=sur, record_hidden=False,
                          fused_kernel=True)
    run_m, cold_m, steady_m = warm_timed(eng_m.run, spikes,
                                         repeats=repeats, stat="min")
    events = int(run_f.events.sum())
    ev_fused = events / max(steady_f, 1e-9)
    ev_unfused = events / max(steady_u, 1e-9)
    ev_mega = events / max(steady_m, 1e-9)
    speedup = ev_fused / max(ev_unfused, 1e-9)
    parity = _record_parity(run_f, run_u)
    parity_mega = _record_parity(run_m, run_f)
    hlo_f = _hlo_counts(eng_f).get("mono", {})
    hlo_u = _hlo_counts(eng_u).get("mono", {})
    hlo_m = _hlo_counts(eng_m).get("mono", {})
    return {
        "layers": list(AB_LAYERS), "t_steps": t_steps, "batch": BATCH,
        "events": events,
        "events_per_sec_fused": ev_fused,
        "events_per_sec_unfused": ev_unfused,
        "events_per_sec_mega": ev_mega,
        "fused_speedup": speedup,
        "mega_speedup_vs_fused": ev_mega / max(ev_fused, 1e-9),
        "mega_speedup_vs_unfused": ev_mega / max(ev_unfused, 1e-9),
        "fused_compile_seconds": run_f.compile_seconds,
        "unfused_compile_seconds": run_u.compile_seconds,
        "mega_compile_seconds": run_m.compile_seconds,
        "fused_steady_seconds": steady_f,
        "unfused_steady_seconds": steady_u,
        "mega_steady_seconds": steady_m,
        "fused_cold_call_seconds": cold_f,
        "unfused_cold_call_seconds": cold_u,
        "mega_cold_call_seconds": cold_m,
        "hlo_fused": hlo_f, "hlo_unfused": hlo_u, "hlo_mega": hlo_m,
        "parity": parity,
        "parity_mega": parity_mega,
    }


def _gate_fail(msg: str, record: dict):
    """Abort on a failed acceptance gate WITHOUT losing the measurements.

    The computed A/B record rides on the exception (``bench_record``) so
    ``benchmarks.run --json`` can still write it — the failing record is
    exactly the one worth keeping — and it is saved to
    results/benchmarks/ before raising."""
    save_json("network_engine", {"fused_ab": record, "gate_failure": msg})
    err = SystemExit(msg)
    err.bench_record = {"fused_ab": record, "gate_failure": msg}
    raise err


def run(full: bool = False):
    import repro.lasana as lasana
    from repro.core.network import snn_spec

    from repro.kernels import ops
    smoke = ops.bench_smoke()

    # --- ISSUE-5 fused-vs-unfused A/B (the CI smoke contract) ------------
    ab = run_fused_ab(full, smoke)
    emit("network/events_per_sec_fused", ab["events_per_sec_fused"])
    emit("network/events_per_sec_unfused", ab["events_per_sec_unfused"])
    emit("network/fused_speedup", ab["fused_speedup"],
         f"target >=1.3x; hlo dots {ab['hlo_fused'].get('dots')} vs "
         f"{ab['hlo_unfused'].get('dots')} "
         f"(instrs {ab['hlo_fused'].get('instructions')} vs "
         f"{ab['hlo_unfused'].get('instructions')})")
    emit("network/events_per_sec_mega", ab["events_per_sec_mega"])
    emit("network/mega_speedup_vs_fused", ab["mega_speedup_vs_fused"],
         f"target >=1.15x; hlo instrs {ab['hlo_mega'].get('instructions')} "
         f"vs {ab['hlo_fused'].get('instructions')}")
    emit("network/mega_speedup_vs_unfused", ab["mega_speedup_vs_unfused"],
         "target >=1.8x")
    parity = ab["parity"]
    if not (parity["outputs_identical"] and parity["events_identical"]
            and parity["energy_within_tolerance"]):
        # deterministic on the pinned stack (fixed seeds, pinned jax):
        # discrete records are exactly equal unless an o_hat lands within
        # ULPs of the spike threshold, which this seeded workload avoids.
        # A jax/XLA upgrade that reassociates dots differently could move
        # a borderline spike — if this gate ever trips after an upgrade,
        # compare parity["energy_max_rel_err"] against the documented
        # rtol=1e-5 before suspecting the fused path itself.
        _gate_fail(f"fused/unfused records diverged: {parity}", ab)
    pm = ab["parity_mega"]
    if not (pm["outputs_identical"] and pm["events_identical"]
            and pm["energy_within_tolerance"]):
        # the megakernel is a pure reformulation of the fused tick: its
        # discrete records must match the 3-dispatch path bit for bit
        _gate_fail(f"megakernel/fused records diverged: {pm}", ab)
    if ab["fused_speedup"] < 1.3:
        print(f"# WARNING: fused speedup {ab['fused_speedup']:.2f}x below "
              "1.3x target")
    if ab["mega_speedup_vs_fused"] < 1.15:
        print(f"# WARNING: megakernel speedup "
              f"{ab['mega_speedup_vs_fused']:.2f}x below 1.15x target")
    if smoke and ab["fused_speedup"] < 1.0:
        # the CI floor: fusion must never LOSE throughput
        _gate_fail(
            f"fused path slower than unfused ({ab['fused_speedup']:.2f}x "
            "< 1.0x smoke floor)", ab)
    if smoke and ab["mega_speedup_vs_fused"] < 1.0:
        # same floor for the megakernel: it must never LOSE to its own
        # fused 3-dispatch baseline
        _gate_fail(
            f"megakernel slower than fused baseline "
            f"({ab['mega_speedup_vs_fused']:.2f}x < 1.0x smoke floor)", ab)
    if smoke:
        out = {"fused_ab": ab, "smoke": True}
        save_json("network_engine", out)
        return out

    layers = SNN_LAYERS_FULL if full else SNN_LAYERS
    ws, params = _make_net(layers)
    spikes = _poisson_spikes(T_STEPS, BATCH, layers[0])
    fams = ("mean", "linear", "mlp")
    b = bank("lif", full, families=fams)
    sur = surrogate("lif", full, families=fams)
    spec = snn_spec(ws, params)

    # the engine AOT-compiles on first use: wall_seconds is steady-state
    # execution, compile_seconds the one-time trace+compile — reported
    # separately (never mixed into events/s)
    eng = lasana.engine(spec, record_hidden=False)
    run_e, cold_s, _ = warm_timed(eng.run, spikes, surrogates=sur)
    rep = run_e.report()
    ev_engine = rep["network"]["events_per_sec"]

    # naive: same event stream, Python loop over ticks x banks (numpy —
    # nothing compiles, so cold == steady and no warmup is needed)
    naive = run_naive(b, ws, spikes, params)
    ev_naive = naive["events"] / max(naive["wall_seconds"], 1e-9)
    speedup = ev_engine / max(ev_naive, 1e-9)

    # golden reference for context (the SPICE stand-in through the engine)
    run_g = lasana.engine(spec, backend="golden", record_hidden=False
                          ).run(spikes)
    rep_g = run_g.report()

    out = {
        "layers": list(layers), "t_steps": T_STEPS, "batch": BATCH,
        "engine": rep, "naive": naive,
        "golden": rep_g["network"],
        "fused_ab": ab,
        "events_per_sec_engine": ev_engine,
        "events_per_sec_naive": ev_naive,
        "speedup_engine_over_naive": speedup,
        "engine_compile_seconds": run_e.compile_seconds,
        "engine_steady_seconds": run_e.wall_seconds,
        "engine_cold_call_seconds": cold_s,
        "energy_err_vs_golden": abs(
            rep["network"]["energy_j"] - rep_g["network"]["energy_j"])
        / max(rep_g["network"]["energy_j"], 1e-30),
    }
    save_json("network_engine", out)
    emit("network/events_per_sec_engine", ev_engine)
    emit("network/events_per_sec_naive", ev_naive)
    emit("network/compile_seconds", run_e.compile_seconds,
         f"steady={run_e.wall_seconds:.4f}s cold_call={cold_s:.2f}s")
    for l in rep["layers"]:       # per-layer attribution (circuit + backend)
        emit(f"network/layer{l['layer']}_{l['circuit']}_energy_nj",
             l["energy_j"] * 1e9, f"{l['events']} events, {l['backend']}")
    emit("network/speedup", speedup,
         f"target >=10x; energy_err={out['energy_err_vs_golden']:.2%}")
    if speedup < 10:
        print(f"# WARNING: engine speedup {speedup:.1f}x below 10x target")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
