"""Network-engine throughput — batched event-driven engine vs naive loop.

A 3-layer spiking-MNIST-sized LIF network runs the same event stream two
ways:

  engine  core/network.py: one jit-compiled scan over ticks, all banks
          batched, idle neurons merged into E2 catch-up events
  naive   the pre-engine formulation: a Python loop over ticks and banks,
          one numpy predictor call per model per bank per tick

Reported: events/s of both, the speedup (acceptance: >= 10x), compile vs
steady-state seconds for the engine (the compiled program is timed with an
explicit AOT warmup — first-call compilation never pollutes events/s), and
the network-level per-layer energy/latency report from the engine run.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bank, emit, save_json, surrogate, warm_timed

SNN_LAYERS = (196, 64, 32, 10)          # CPU scale
SNN_LAYERS_FULL = (784, 256, 128, 10)   # spiking-MNIST scale
T_STEPS = 60
BATCH = 8


def _make_net(layers, seed=0):
    rng = np.random.default_rng(seed)
    ws = []
    for i in range(len(layers) - 1):
        w = rng.normal(0, (2.0 / layers[i]) ** 0.5, (layers[i], layers[i + 1]))
        ws.append((w * 2.2).astype(np.float32))      # drive into spiking range
    params = [np.array([0.58, 0.5, 0.5, 0.5], np.float32) for _ in ws]
    return ws, params


def _poisson_spikes(t, b, n, rate=0.25, seed=1):
    rng = np.random.default_rng(seed)
    return (rng.random((t, b, n)) < rate).astype(np.float32) * 1.5


def run_naive(b, weights, spike_seq, params_list, clock=5.0):
    """Per-bank Python loop: Algorithm 1 semantics, one numpy predictor
    call per model per bank per tick (no jit, no cross-tick fusion)."""
    t_steps, batch, _ = spike_seq.shape
    layers = []
    for w, p in zip(weights, params_list):
        n = batch * w.shape[1]
        layers.append({
            "w": w, "conn": (np.abs(w) > 0).astype(np.float32),
            "v": np.zeros(n, np.float32), "o": np.zeros(n, np.float32),
            "t_last": np.zeros(n, np.float32),
            "params": np.broadcast_to(p[None], (n, p.shape[0])),
        })
    energy = 0.0
    events = 0
    t0 = time.time()
    for ti in range(t_steps):
        t = (ti + 1) * clock
        s = spike_seq[ti]
        for L in layers:
            drive = (s @ L["w"]) / 1.5
            pre = (s > 0.75).astype(np.float32)
            changed = ((pre @ L["conn"]) > 0.5).reshape(-1)
            x = np.stack([np.clip(drive, -1, 1),
                          np.full_like(drive, 1.5),
                          np.full_like(drive, 5.0)], -1).reshape(-1, 3)
            n = L["v"].shape[0]
            stale = changed & (L["t_last"] < t - clock)
            tau_idle = np.maximum(t - L["t_last"] - clock, 0.0)
            fi = np.concatenate([np.zeros_like(x), L["v"][:, None],
                                 tau_idle[:, None], L["params"]], 1)
            v_cur = np.where(stale, b.predict_np("M_V", fi), L["v"])
            e = np.where(stale, b.predict_np("M_ES", fi), 0.0)
            tau = np.full((n, 1), clock, np.float32)
            f = np.concatenate([x, v_cur[:, None], tau, L["params"]], 1)
            o_hat = b.predict_np("M_O", f)
            v_new = b.predict_np("M_V", f)
            fired = o_hat > 0.75
            o_res = np.where(fired, 1.5, 0.0)
            ftr = np.concatenate([f, L["o"][:, None], o_res[:, None]], 1)
            e_evt = np.where(fired, b.predict_np("M_ED", ftr),
                             b.predict_np("M_ES", f))
            b.predict_np("M_L", ftr)
            energy += float(np.sum(e + np.where(changed, e_evt, 0.0)))
            L["v"] = np.where(changed, v_new, v_cur).astype(np.float32)
            L["o"] = np.where(changed, o_res, L["o"]).astype(np.float32)
            L["t_last"] = np.where(changed, t, L["t_last"]).astype(np.float32)
            events += int(changed.sum())
            s = np.where(changed, o_res, 0.0).reshape(batch, -1)
    return {"events": events, "energy_j": energy,
            "wall_seconds": time.time() - t0}


def run(full: bool = False):
    import repro.lasana as lasana
    from repro.core.network import snn_spec

    layers = SNN_LAYERS_FULL if full else SNN_LAYERS
    ws, params = _make_net(layers)
    spikes = _poisson_spikes(T_STEPS, BATCH, layers[0])
    fams = ("mean", "linear", "mlp")
    b = bank("lif", full, families=fams)
    sur = surrogate("lif", full, families=fams)
    spec = snn_spec(ws, params)

    # the engine AOT-compiles on first use: wall_seconds is steady-state
    # execution, compile_seconds the one-time trace+compile — reported
    # separately (never mixed into events/s)
    eng = lasana.engine(spec, record_hidden=False)
    run_e, cold_s, _ = warm_timed(eng.run, spikes, surrogates=sur)
    rep = run_e.report()
    ev_engine = rep["network"]["events_per_sec"]

    # naive: same event stream, Python loop over ticks x banks (numpy —
    # nothing compiles, so cold == steady and no warmup is needed)
    naive = run_naive(b, ws, spikes, params)
    ev_naive = naive["events"] / max(naive["wall_seconds"], 1e-9)
    speedup = ev_engine / max(ev_naive, 1e-9)

    # golden reference for context (the SPICE stand-in through the engine)
    run_g = lasana.engine(spec, backend="golden", record_hidden=False
                          ).run(spikes)
    rep_g = run_g.report()

    out = {
        "layers": list(layers), "t_steps": T_STEPS, "batch": BATCH,
        "engine": rep, "naive": naive,
        "golden": rep_g["network"],
        "events_per_sec_engine": ev_engine,
        "events_per_sec_naive": ev_naive,
        "speedup_engine_over_naive": speedup,
        "engine_compile_seconds": run_e.compile_seconds,
        "engine_steady_seconds": run_e.wall_seconds,
        "engine_cold_call_seconds": cold_s,
        "energy_err_vs_golden": abs(
            rep["network"]["energy_j"] - rep_g["network"]["energy_j"])
        / max(rep_g["network"]["energy_j"], 1e-30),
    }
    save_json("network_engine", out)
    emit("network/events_per_sec_engine", ev_engine)
    emit("network/events_per_sec_naive", ev_naive)
    emit("network/compile_seconds", run_e.compile_seconds,
         f"steady={run_e.wall_seconds:.4f}s cold_call={cold_s:.2f}s")
    for l in rep["layers"]:       # per-layer attribution (circuit + backend)
        emit(f"network/layer{l['layer']}_{l['circuit']}_energy_nj",
             l["energy_j"] * 1e9, f"{l['events']} events, {l['backend']}")
    emit("network/speedup", speedup,
         f"target >=10x; energy_err={out['energy_err_vs_golden']:.2%}")
    if speedup < 10:
        print(f"# WARNING: engine speedup {speedup:.1f}x below 10x target")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
