"""Streaming chunked runs vs monolithic — throughput + peak host memory.

The ISSUE-4 acceptance workload: a mixed crossbar->LIF recurrent graph
driven for T=10k ticks (the long-horizon regime where the monolithic
``lax.scan`` materializes the whole (T, B, n) stimulus and every (T, ...)
output trace at once). Both paths run the SAME graph and stimulus:

  mono     ``lasana.simulate`` — one program over the full T axis
  stream   ``lasana.simulate_stream`` — chunked, donated carries, the
           stimulus produced by a host generator so no (T, B, n) array
           ever exists on device

Reported (via ``common.warm_timed``, so first-call compilation never
pollutes the steady numbers): events/s of both paths, the streaming
speed ratio (acceptance: >= 0.8x of monolithic — streaming must not cost
throughput), bit-identity of the two records, zero-recompile surrogate
hot-swap across chunks, and per-phase peak resident memory (a sampling
thread watches VmRSS during each run — ``ru_maxrss`` is useless here
because surrogate training earlier in the process already set the
watermark).

``REPRO_BENCH_SMOKE=1`` shrinks T for the CI smoke leg.
"""

from __future__ import annotations

import itertools
import threading
import time

import numpy as np

from benchmarks.common import emit, save_json, surrogate, warm_timed

T_STEPS = 10_000
T_STEPS_SMOKE = 600
CHUNK_TICKS = 256
BATCH = 4
FAN_IN, N_MAC, N_LIF = 40, 16, 8
BLOCK = 500                     # host-generator production granularity


def _vm_rss_kb() -> int:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1])
    except OSError:
        pass
    import resource
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)


class _PeakRss:
    """Samples VmRSS on a thread; ``with _PeakRss() as p: ... p.peak_kb``."""

    def __init__(self, interval: float = 0.005):
        self._interval = interval
        self._stop = threading.Event()
        self.peak_kb = 0

    def _watch(self):
        while not self._stop.is_set():
            self.peak_kb = max(self.peak_kb, _vm_rss_kb())
            time.sleep(self._interval)

    def __enter__(self):
        self.peak_kb = _vm_rss_kb()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join()
        self.peak_kb = max(self.peak_kb, _vm_rss_kb())


def _make_spec():
    import jax.numpy as jnp
    from repro.core.network import (crossbar_layer, graph_spec, lif_layer,
                                    recurrent_edge)
    rng = np.random.default_rng(0)
    xw = rng.integers(-1, 2, (FAN_IN, N_MAC)).astype(np.float32)
    lw = (rng.normal(0, 0.5, (N_MAC, N_LIF)) * 2.2).astype(np.float32)
    params = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    inhib = -0.5 * (1 - np.eye(N_LIF, dtype=np.float32))
    return graph_spec([crossbar_layer(xw), lif_layer(lw, params)],
                      edges=[recurrent_edge(1, 1, inhib)])


def _stimulus_blocks(t_steps: int, block: int = BLOCK):
    """Host generator of ternary DAC drive — the bounded-memory source."""
    rng = np.random.default_rng(1)
    for a in range(0, t_steps, block):
        t = min(block, t_steps - a)
        yield (rng.integers(-1, 2, (t, BATCH, FAN_IN)) * 0.8
               ).astype(np.float32)


def run(full: bool = False):
    import repro.lasana as lasana

    from repro.kernels import ops
    t_steps = T_STEPS_SMOKE if ops.bench_smoke() else T_STEPS
    spec = _make_spec()
    fams = ("mean", "linear")
    banks = {"lif": surrogate("lif", full, families=fams),
             "crossbar": surrogate("crossbar", full, families=fams)}
    eng = lasana.engine(spec, record_hidden=False)

    rss0 = _vm_rss_kb()
    with _PeakRss() as p_stream:
        run_s, cold_s, _ = warm_timed(
            lambda: eng.run_stream(_stimulus_blocks(t_steps),
                                   chunk_ticks=CHUNK_TICKS,
                                   surrogates=banks))
    rep_s = run_s.report()["network"]

    # monolithic needs the whole (T, B, n) stimulus materialized
    with _PeakRss() as p_mono:
        x = np.concatenate(list(_stimulus_blocks(t_steps)), axis=0)
        run_m, cold_m, _ = warm_timed(eng.run, x, surrogates=banks)
    rep_m = run_m.report()["network"]

    identical = (np.array_equal(run_m.outputs, run_s.outputs)
                 and np.array_equal(run_m.energy, run_s.energy)
                 and np.array_equal(run_m.events, run_s.events)
                 and np.array_equal(run_m.flush_energy, run_s.flush_energy))

    # ISSUE-5 fused A/B: the same stream through the per-predict-call
    # formulation — fusion must not cost streaming throughput, and the
    # two records must agree (discrete exactly, energies to rtol 1e-5)
    from repro.core.network import NetworkEngine
    eng_u = NetworkEngine(spec, record_hidden=False, fused=False)
    run_u, _, _ = warm_timed(
        lambda: eng_u.run_stream(_stimulus_blocks(t_steps),
                                 chunk_ticks=CHUNK_TICKS,
                                 surrogates=banks))
    rep_u = run_u.report()["network"]
    fused_ratio = rep_s["events_per_sec"] / max(rep_u["events_per_sec"],
                                                1e-9)
    fused_parity = (np.array_equal(run_s.outputs, run_u.outputs)
                    and np.array_equal(run_s.events, run_u.events)
                    and np.allclose(run_s.energy, run_u.energy,
                                    rtol=1e-5, atol=1e-20))

    # ISSUE-7 megakernel arm: the same stream with fused_kernel=True — on
    # this MIXED crossbar->LIF recurrent graph the engine packs heads
    # across both circuit kinds into one library-wide stack, so this arm
    # exercises the cross-kind pack on a real workload
    eng_m = NetworkEngine(spec, record_hidden=False, fused_kernel=True)
    run_mg, _, _ = warm_timed(
        lambda: eng_m.run_stream(_stimulus_blocks(t_steps),
                                 chunk_ticks=CHUNK_TICKS,
                                 surrogates=banks))
    rep_mg = run_mg.report()["network"]
    mega_ratio = rep_mg["events_per_sec"] / max(rep_s["events_per_sec"],
                                                1e-9)
    mega_parity = (np.array_equal(run_s.outputs, run_mg.outputs)
                   and np.array_equal(run_s.events, run_mg.events)
                   and np.allclose(run_s.energy, run_mg.energy,
                                   rtol=1e-5, atol=1e-20))

    # surrogate hot-swap across chunks must reuse the compiled programs
    compiles = eng.compile_count
    lif2 = lasana.train("lif", lasana.TrainConfig(
        n_runs=60, n_steps=40, seed=9, families=fams))
    swaps = itertools.cycle([banks, {"lif": lif2,
                                     "crossbar": banks["crossbar"]}])
    eng.run_stream(_stimulus_blocks(t_steps), chunk_ticks=CHUNK_TICKS,
                   surrogates=swaps)
    swap_recompiles = eng.compile_count - compiles

    ratio = rep_s["events_per_sec"] / max(rep_m["events_per_sec"], 1e-9)
    out = {
        "t_steps": t_steps, "chunk_ticks": CHUNK_TICKS, "batch": BATCH,
        "bit_identical": bool(identical),
        "swap_recompiles": int(swap_recompiles),
        "compile_count": int(eng.compile_count),
        "stream": rep_s, "mono": rep_m,
        "stream_cold_call_seconds": cold_s,
        "mono_cold_call_seconds": cold_m,
        "events_per_sec_stream": rep_s["events_per_sec"],
        "events_per_sec_mono": rep_m["events_per_sec"],
        "events_per_sec_stream_unfused": rep_u["events_per_sec"],
        "events_per_sec_stream_mega": rep_mg["events_per_sec"],
        "stream_over_mono": ratio,
        "fused_over_unfused_stream": fused_ratio,
        "mega_over_fused_stream": mega_ratio,
        "fused_parity": bool(fused_parity),
        "mega_parity": bool(mega_parity),
        "rss_kb_baseline": rss0,
        "peak_rss_kb_stream": p_stream.peak_kb,
        "peak_rss_kb_mono": p_mono.peak_kb,
        "stream_peak_delta_kb": p_stream.peak_kb - rss0,
        "mono_peak_delta_kb": p_mono.peak_kb - rss0,
        "stimulus_bytes": int(x.nbytes),
    }
    save_json("streaming", out)
    emit("streaming/events_per_sec_stream", rep_s["events_per_sec"])
    emit("streaming/events_per_sec_mono", rep_m["events_per_sec"])
    emit("streaming/ratio", ratio,
         f"bit_identical={identical} swap_recompiles={swap_recompiles}")
    emit("streaming/fused_over_unfused", fused_ratio,
         f"record_parity={fused_parity}")
    emit("streaming/mega_over_fused", mega_ratio,
         f"record_parity={mega_parity} (cross-kind pack)")
    emit("streaming/peak_rss_delta_kb_stream",
         p_stream.peak_kb - rss0,
         f"mono peaks {p_mono.peak_kb - rss0} kb over the same baseline")
    if ratio < 0.8:
        # timing is machine-dependent: warn, never fail CI on throughput
        print(f"# WARNING: streaming at {ratio:.2f}x of monolithic "
              "events/s (acceptance target >= 0.8x)")
    # correctness acceptance is binary and deterministic — fail loudly so
    # the CI smoke leg actually guards the contract
    if not identical:
        raise SystemExit(
            "streaming record diverged from monolithic (bit-identity "
            "acceptance violated)")
    if not mega_parity:
        raise SystemExit(
            "megakernel streaming record diverged from the fused baseline "
            "(discrete records must match exactly, energy to rtol 1e-5)")
    if swap_recompiles:
        raise SystemExit(
            f"surrogate hot-swap recompiled {swap_recompiles} programs "
            "(zero-recompile acceptance violated)")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
