"""Table III + Fig 8 — behavioral error propagation on an N-neuron layer.

LASANA-O: oracle (golden) state fed to every prediction.
LASANA-P: predicted state fed back (the deployment mode).
Also records per-timestep normalized MSE to verify error does not diverge.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, FULL_SCALE, emit, save_json, surrogate
from repro.core.simulate import make_stimulus, run_golden, run_lasana


def _metrics(golden, sim, spiking=True):
    spikes_g = golden.outputs > 0.75
    spikes_s = sim.outputs > 0.75
    e1 = spikes_g  # dynamic events = golden spikes
    out = {
        "state_mse": float(np.mean((golden.states - sim.states) ** 2)),
        "output_mse": float(np.mean((golden.outputs - sim.outputs) ** 2)),
        "spike_acc": float(np.mean(spikes_g == spikes_s)),
    }
    if e1.any():
        le = np.abs(sim.latency - golden.latency)[e1]
        out["latency_mse"] = float(np.mean(
            (sim.latency - golden.latency)[e1] ** 2))
        out["latency_mape"] = float(np.mean(
            le / np.maximum(golden.latency[e1], 1e-3)) * 100)
        ed = (sim.energy - golden.energy)[e1] * 1e12
        out["dyn_energy_mse_pJ2"] = float(np.mean(ed ** 2))
        out["dyn_energy_mape"] = float(np.mean(
            np.abs(ed) / np.maximum(golden.energy[e1] * 1e12, 1e-6)) * 100)
    stat = ~e1
    es = (sim.energy - golden.energy)[stat] * 1e12
    out["stat_energy_mse_pJ2"] = float(np.mean(es ** 2))
    return out


def run(full: bool = False):
    sc = FULL_SCALE if full else SCALE
    n, t = sc["prop_neurons"], sc["prop_steps"]
    b = surrogate("lif", full)
    active, x, params = make_stimulus("lif", n, t, seed=42)
    golden = run_golden("lif", active, x, params)
    lasana_p = run_lasana(b, "lif", active, x, params)
    lasana_o = run_lasana(b, "lif", active, x, params,
                          oracle_states=golden.states)
    rows = {
        "n_neurons": n, "t_steps": t,
        "LASANA-O": _metrics(golden, lasana_o),
        "LASANA-P": _metrics(golden, lasana_p),
    }
    # Fig 8: per-timestep state MSE (normalized to the run mean)
    mse_t = np.mean((golden.states - lasana_p.states) ** 2, axis=1)
    rows["per_tick_state_mse"] = (mse_t / (mse_t.mean() + 1e-12)).tolist()
    first = float(np.mean(mse_t[: t // 3]))
    last = float(np.mean(mse_t[-t // 3:]))
    rows["mse_drift_ratio_last_over_first"] = last / max(first, 1e-12)
    save_json("table3_propagation", rows)
    for mode in ("LASANA-O", "LASANA-P"):
        m = rows[mode]
        emit(f"table3/{mode}/state_mse", m["state_mse"],
             f"spike_acc={m['spike_acc']:.4f}")
    emit("fig8/drift_ratio", rows["mse_drift_ratio_last_over_first"],
         "last_third/first_third per-tick state MSE")
    return rows
