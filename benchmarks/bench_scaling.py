"""Table IV — runtime scaling with layer size N.

Columns mirror the paper: golden transient sim (the SPICE stand-in),
behavioral (SV-RNM stand-in), behavioral + ML energy/latency annotation,
standalone LASANA. Wall times exclude compilation: every runner reports
``compile_seconds`` and ``wall_seconds`` separately (LayerRun), so no
external warmup calls are needed.

Honesty note (EXPERIMENTS §Paper-validation): our golden integrator is a
vectorized JAX program, orders of magnitude faster than a real SPICE solve,
so absolute speedups are smaller than the paper's 4 orders of magnitude;
the *scaling shape* (speedup grows with N, annotation overhead ~1%) is the
reproducible claim.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SCALE, FULL_SCALE, emit, save_json, surrogate
from repro.core.simulate import (make_stimulus, run_behavioral, run_golden,
                                 run_lasana)


def run(full: bool = False):
    sc = FULL_SCALE if full else SCALE
    b = surrogate("lif", full)
    rows = []
    for n in sc["scaling_ns"]:
        active, x, params = make_stimulus("lif", n, sc["scaling_steps"],
                                          seed=n)
        g = run_golden("lif", active, x, params)
        t_gold = g.wall_seconds
        bh = run_behavioral("lif", active, x, params)
        t_beh = bh.wall_seconds
        lz = run_lasana(b, "lif", active, x, params)
        t_las = lz.wall_seconds
        # annotation mode: behavioral outputs AND states are supplied,
        # LASANA only adds the energy/latency annotation
        an = run_lasana(b, "lif", active, x, params,
                        oracle_states=bh.states,
                        annotate_outputs=bh.outputs)
        t_ann = an.wall_seconds
        row = dict(n=n, golden_s=t_gold, behavioral_s=t_beh,
                   annotation_extra_s=t_ann, lasana_s=t_las,
                   speedup_vs_golden=t_gold / max(t_las, 1e-9),
                   speedup_vs_behavioral=t_beh / max(t_las, 1e-9))
        rows.append(row)
        emit(f"table4/n{n}/lasana", t_las * 1e6,
             f"golden_s={t_gold:.3f} speedup={row['speedup_vs_golden']:.1f}x")
    save_json("table4_scaling", rows)
    return rows
