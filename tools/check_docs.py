#!/usr/bin/env python
"""Docs checker: execute fenced python snippets + verify intra-repo links.

Scans README.md and docs/*.md for:

  * fenced ```python blocks — each is executed in a subprocess with
    PYTHONPATH=src (cwd = repo root). A block is skipped iff its info
    string or first line contains ``no-run`` (for illustrative fragments
    that aren't self-contained).
  * markdown links [text](target) — http(s)/mailto/anchor links are
    ignored; everything else must resolve to an existing file/dir
    relative to the containing document (fragments stripped).

Exit status is nonzero on any snippet failure or broken link, so the CI
``docs`` leg fails when documentation drifts from the code.

    PYTHONPATH=src python tools/check_docs.py [files...]
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE_RE = re.compile(r"^```(?P<info>[^\n]*)\n(?P<body>.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SNIPPET_TIMEOUT = 600


def doc_files(argv):
    if argv:
        return [pathlib.Path(a) for a in argv]
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def iter_snippets(text):
    for m in FENCE_RE.finditer(text):
        info = m.group("info").strip().lower()
        body = m.group("body")
        lang = info.split()[0] if info else ""
        if lang != "python":
            continue
        first = body.lstrip().splitlines()[0] if body.strip() else ""
        if "no-run" in info or "no-run" in first:
            continue
        yield m.start(), body


def run_snippet(body, label):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(body)
        path = f.name
    try:
        proc = subprocess.run([sys.executable, path], cwd=ROOT, env=env,
                              capture_output=True, text=True,
                              timeout=SNIPPET_TIMEOUT)
    finally:
        os.unlink(path)
    if proc.returncode != 0:
        return (f"{label}: snippet failed (exit {proc.returncode})\n"
                f"--- stderr ---\n{proc.stderr.strip()[-2000:]}")
    return None


def check_links(doc, text):
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        resolved = (doc.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{doc.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main(argv):
    failures = []
    n_snippets = 0
    for doc in doc_files(argv):
        text = doc.read_text()
        failures += check_links(doc, text)
        for pos, body in iter_snippets(text):
            n_snippets += 1
            line = text[:pos].count("\n") + 1
            label = f"{doc.relative_to(ROOT)}:{line}"
            print(f"running {label} ...", flush=True)
            err = run_snippet(body, label)
            if err:
                failures.append(err)
    if failures:
        print("\n".join(["", "DOCS CHECK FAILED:"] + failures))
        return 1
    print(f"docs check OK: {n_snippets} snippets executed, links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
