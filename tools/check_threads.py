#!/usr/bin/env python
"""CI gate: AST concurrency lint of the threaded serve subsystem
(see repro/analysis/thread_lint.py and docs/analysis.md).

Every field of SimServer / Lane / ArtifactStore is annotated in
thread_lint.LINT_TABLE as lock-guarded, driver-thread-only, immutable-
after-init, lifecycle-only, or internally-synchronized; the lint flags
guarded state touched outside ``with self._lock``, blocking work
(compiles, device syncs, lane construction) or user callbacks
(``on_chunk``) invoked while holding the lock, driver-owned state
touched from foreign threads, and any *unannotated* field (the table
must stay complete — adding a field without classifying its locking
discipline is itself a finding).

Exit 0 when clean; exit 1 with one line per finding, each naming
file:Class.method and the offending field/call.
"""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.analysis import thread_lint

    findings = thread_lint.run_lint(root=ROOT)
    if findings:
        print(f"thread lint: {len(findings)} finding(s)", file=sys.stderr)
        for f in findings:
            print(f"  {f}", file=sys.stderr)
        return 1
    n_classes = sum(len(c) for c in thread_lint.LINT_TABLE.values())
    print(f"thread lint: clean ({n_classes} annotated classes, "
          f"{len(thread_lint.LINT_TABLE)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
