#!/usr/bin/env python
"""CI gate: trace-time program audit of every registered hot-path
entrypoint (see repro/analysis/jaxpr_audit.py and docs/analysis.md).

Checks, against tests/data/program_budgets.json and the hard-coded
architectural ceilings:

  * per-tick dispatch budgets (fused <= 3, annotation/megakernel == 1)
  * dot_general / scan / pallas_call counts per traced program
  * donation discipline (every donate_argnums leaf actually aliased)
  * no fp64 promotion / host-callback primitives in traced bodies
  * cache-key completeness + the id()-in-a-cache-key ban
  * environment-read discipline (kernels/ops.py is the only reader)

Exit 0 when the repo is clean; exit 1 with one line per finding, each
naming the entrypoint/cache/file. Intentional program changes:

    PYTHONPATH=src python tools/check_programs.py --regen

then review the program_budgets.json diff like any frozen surface
(tests/data/api_surface.txt has the same workflow). Ceilings are not
regenerable — a program exceeding them must be fixed, not re-frozen.
"""

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--regen", action="store_true",
        help="re-freeze tests/data/program_budgets.json from the "
             "current programs (ceilings still apply)")
    args = parser.parse_args()

    from repro.analysis import jaxpr_audit

    if args.regen:
        rows = jaxpr_audit.collect_budgets()
        jaxpr_audit.save_budgets(rows)
        print(f"re-froze {len(rows)} entrypoint budgets -> "
              f"{jaxpr_audit.BUDGETS_PATH.relative_to(ROOT)}")
        # even a regen must respect the architectural ceilings and the
        # non-budget checks — re-run the full audit against the fresh file
        findings = jaxpr_audit.run_audit(jaxpr_audit.load_budgets())
    else:
        findings = jaxpr_audit.run_audit(jaxpr_audit.load_budgets())

    if findings:
        print(f"program audit: {len(findings)} finding(s)",
              file=sys.stderr)
        for f in findings:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("program audit: clean "
          f"({len(jaxpr_audit.load_budgets())} entrypoints)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
