#!/usr/bin/env python
"""Public-API guard for the ``repro.lasana`` facade.

Fails (nonzero exit) when:

  * a symbol in ``repro.lasana.__all__`` — or a public method/property of
    an exported class — is missing a docstring, or
  * the generated API surface differs from the frozen snapshot
    (``tests/data/api_surface.txt``) without the snapshot being
    regenerated.

The snapshot is one line per symbol: ``name [kind] signature``, with
class members indented. Any intentional API change must ship with a
regenerated snapshot (making API diffs visible in review):

    PYTHONPATH=src python tools/check_api.py          # check (CI mode)
    PYTHONPATH=src python tools/check_api.py --regen  # refresh snapshot
"""

from __future__ import annotations

import argparse
import inspect
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

SNAPSHOT = ROOT / "tests" / "data" / "api_surface.txt"


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return ""


def _class_members(cls):
    for name, member in sorted(vars(cls).items()):
        if name.startswith("_"):
            continue
        yield name, member


def build_surface():
    """-> (lines, missing_docstrings) for repro.lasana.__all__."""
    import repro.lasana as facade
    lines, missing = [], []
    for name in sorted(facade.__all__):
        obj = getattr(facade, name)
        if inspect.isclass(obj):
            kind = "class"
        elif inspect.isfunction(obj):
            kind = "function"
        else:
            kind = type(obj).__name__
        doc = inspect.getdoc(obj) if (inspect.isclass(obj) or callable(obj)) \
            else True
        if not doc:
            missing.append(f"repro.lasana.{name}")
        lines.append(f"{name} [{kind}]{_signature(obj) if kind != 'int' else ''}")
        if inspect.isclass(obj):
            for mname, member in _class_members(obj):
                target = member
                tag = "method"
                if isinstance(member, property):
                    target, tag = member.fget, "property"
                elif isinstance(member, staticmethod):
                    target, tag = member.__func__, "staticmethod"
                elif isinstance(member, classmethod):
                    target, tag = member.__func__, "classmethod"
                if callable(target):
                    if not inspect.getdoc(target):
                        missing.append(f"repro.lasana.{name}.{mname}")
                    lines.append(f"  .{mname} [{tag}]{_signature(target)}")
                else:                            # dataclass field default etc.
                    lines.append(f"  .{mname} [attribute]")
    return lines, missing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the frozen snapshot from the live API")
    args = ap.parse_args(argv)

    lines, missing = build_surface()
    text = "\n".join(lines) + "\n"

    if missing:
        print("API CHECK FAILED: missing docstrings on public symbols:")
        for m in missing:
            print(f"  {m}")
        return 1

    if args.regen:
        SNAPSHOT.parent.mkdir(parents=True, exist_ok=True)
        SNAPSHOT.write_text(text)
        print(f"wrote {SNAPSHOT.relative_to(ROOT)} ({len(lines)} lines)")
        return 0

    if not SNAPSHOT.exists():
        print(f"API CHECK FAILED: snapshot {SNAPSHOT.relative_to(ROOT)} "
              "missing; run tools/check_api.py --regen and commit it")
        return 1
    frozen = SNAPSHOT.read_text()
    if frozen != text:
        import difflib
        print("API CHECK FAILED: repro.lasana surface drifted from the "
              "frozen snapshot. If intentional, regenerate with "
              "tools/check_api.py --regen and commit the diff:")
        print("".join(difflib.unified_diff(
            frozen.splitlines(keepends=True), text.splitlines(keepends=True),
            fromfile="tests/data/api_surface.txt", tofile="live API")))
        return 1
    print(f"api check OK: {len(lines)} surface lines match the snapshot, "
          "all public symbols documented")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
