"""Streaming a long-horizon spiking run in bounded memory.

    PYTHONPATH=src python examples/streaming_snn.py

The monolithic ``lasana.simulate`` materializes the whole (T, B, n)
stimulus and every output trace at once — fine for 100 ticks, hostile at
realistic horizons. ``lasana.simulate_stream`` cuts the T axis into
chunks, carries the network state chunk-to-chunk as DONATED buffers (XLA
aliases it in place), and fetches each chunk's records to the host while
the next chunk computes. The merged record is bit-identical to the
monolithic one.

This example runs a 2-layer LIF net for T=4,000 ticks three ways:

1. ``lasana.stream`` — the generator variant, consumed as a live
   dashboard (per-chunk events/s and running energy);
2. ``lasana.simulate_stream`` with a surrogate HOT-SWAP mid-stream
   (retrained weights every chunk, zero recompiles);
3. the monolithic reference, to verify bit-identity.
"""

import itertools

import numpy as np

import repro.lasana as lasana
from repro.core.network import NetworkRun, snn_spec

T_STEPS, BATCH, CHUNK = 4_000, 8, 512


def stimulus_blocks(t_steps, n_in, block=250, rate=0.2, seed=3):
    """Host generator: Poisson spike blocks produced on the fly — no
    (T, B, n) array ever exists, on host or device."""
    rng = np.random.default_rng(seed)
    for a in range(0, t_steps, block):
        t = min(block, t_steps - a)
        yield (rng.random((t, BATCH, n_in)) < rate
               ).astype(np.float32) * 1.5


def main():
    rng = np.random.default_rng(0)
    w1 = (rng.normal(0, 0.35, (64, 32)) * 2.2).astype(np.float32)
    w2 = (rng.normal(0, 0.35, (32, 10)) * 2.2).astype(np.float32)
    params = [np.asarray([0.58, 0.5, 0.5, 0.5], np.float32)] * 2
    spec = snn_spec([w1, w2], params)

    print("== train two equal-structure surrogates (weight-swap demo) ==")
    cfg = lambda seed: lasana.TrainConfig(n_runs=150, n_steps=60,
                                          seed=seed, families=("linear",))
    s1, s2 = lasana.train("lif", cfg(1)), lasana.train("lif", cfg(2))

    print(f"== 1/3: live dashboard over {T_STEPS} ticks, "
          f"chunk={CHUNK} ==")
    acc = lasana.StreamingRun()
    for rec in lasana.stream(spec, stimulus_blocks(T_STEPS, 64),
                             chunk_ticks=CHUNK, surrogates=s1):
        acc.update(rec)
        rate = rec.events.sum() / max(rec.wall_seconds, 1e-9)
        print(f"   tick {acc.ticks:5d}/{T_STEPS}  "
              f"chunk events/s {rate:10.0f}  "
              f"running energy {acc.energy_j * 1e9:8.2f} nJ")
    merged = acc.result()

    print("== 2/3: hot-swap retrained surrogates every chunk ==")
    eng = lasana.engine(spec, record_hidden=False)
    compiles = eng.compile_count
    swapped = lasana.simulate_stream(
        spec, stimulus_blocks(T_STEPS, 64), chunk_ticks=CHUNK,
        surrogates=itertools.cycle([s1, s2]))
    print(f"   recompiles during swap stream: "
          f"{eng.compile_count - compiles} (surrogates are traced, "
          f"donated pytree arguments)")
    print(f"   energy shifted by the swapped weights: "
          f"{abs(swapped.energy.sum() - merged.energy.sum()) * 1e9:.2f} nJ")

    print("== 3/3: verify against the monolithic record ==")
    x = np.concatenate(list(stimulus_blocks(T_STEPS, 64)), axis=0)
    mono = lasana.simulate(spec, x, surrogates=s1, record_hidden=False)
    identical = (np.array_equal(mono.outputs, merged.outputs)
                 and np.array_equal(mono.energy, merged.energy)
                 and np.array_equal(mono.events, merged.events)
                 and np.array_equal(mono.flush_energy,
                                    merged.flush_energy))
    print(f"   bit-identical to lasana.simulate: {identical}")
    rep_s, rep_m = merged.report()["network"], mono.report()["network"]
    print(f"   events/s: stream {rep_s['events_per_sec']:.0f} vs "
          f"mono {rep_m['events_per_sec']:.0f}")
    assert identical
    assert isinstance(NetworkRun.merge([merged]), NetworkRun)


if __name__ == "__main__":
    main()
