"""Spiking MNIST case study (paper §V-E, second half).

A 784-128-10 SNN (ANN-to-SNN conversion, Poisson rate coding, 100 ticks)
runs once through the golden LIF integrator and once through per-neuron
LASANA instances wired by the network connectivity. Reported: MNIST-style
accuracy of both, spike-level agreement, total-energy error, wall time.

    PYTHONPATH=src python examples/snn_mnist.py [--n-test 100]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import TestbenchConfig, build_dataset
from repro.core.predictors import PredictorBank
from repro.core.simulate import run_snn_golden, run_snn_lasana
from repro.data.mnist import make_digits, poisson_encode

LAYERS = (784, 128, 10)
T_STEPS = 100


def train_ann(seed=0, n_train=4000, steps=400):
    imgs, labels = make_digits(n_train, size=28, seed=seed)
    key = jax.random.PRNGKey(seed)
    ws = []
    for i in range(len(LAYERS) - 1):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (LAYERS[i], LAYERS[i + 1]))
                  * (2.0 / LAYERS[i]) ** 0.5)

    def forward(ws, x):
        h = x
        for i, w in enumerate(ws):
            h = h @ w
            if i < len(ws) - 1:
                h = jax.nn.relu(h)
        return h

    def loss(ws, x, y):
        return -jnp.mean(jax.nn.log_softmax(forward(ws, x))
                         [jnp.arange(len(y)), y])

    x, y = jnp.asarray(imgs), jnp.asarray(labels)
    gfn = jax.jit(jax.grad(loss))
    for _ in range(steps):
        g = gfn(ws, x, y)
        ws = [w - 0.1 * gi for w, gi in zip(ws, g)]
    # ANN->SNN conversion: normalize each layer to its 99th-percentile preact
    h = np.asarray(x)
    out = []
    for i, w in enumerate(ws):
        pre = h @ np.asarray(w)
        scale = np.percentile(np.abs(pre), 99)
        out.append(np.asarray(w) / scale * 2.2)     # drive into spiking range
        h = np.maximum(pre, 0)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-test", type=int, default=100)
    ap.add_argument("--bank-runs", type=int, default=600)
    args = ap.parse_args()

    print("== training + converting 784-128-10 ANN->SNN ==")
    ws = train_ann()
    imgs, labels = make_digits(args.n_test, size=28, seed=777)
    spikes = poisson_encode(imgs, T_STEPS, seed=5) * 1.5   # V_dd spikes
    spikes = jnp.asarray(spikes)

    # per-layer LIF knobs: paper's setting (all 0.5 V, V_leak = 0.58 V)
    params = [np.tile(np.array([[0.58, 0.5, 0.5, 0.5]], np.float32),
                      (1, 1)) for _ in ws]
    params = [jnp.asarray(p[0]) for p in params]
    w_jax = [jnp.asarray(w) for w in ws]

    print("== golden SNN simulation ==")
    t0 = time.time()
    counts_g, e_g = run_snn_golden("lif", w_jax, spikes, params)
    counts_g = np.asarray(jax.block_until_ready(counts_g))
    t_gold = time.time() - t0
    acc_g = float(np.mean(np.argmax(counts_g, -1) == labels))

    print("== training LIF surrogate bank ==")
    ds = build_dataset("lif", TestbenchConfig(n_runs=args.bank_runs,
                                              n_steps=100))
    bank = PredictorBank("lif", families=("linear", "mlp")).fit(ds)

    print("== LASANA SNN simulation ==")
    t0 = time.time()
    counts_l, e_l = run_snn_lasana(bank, w_jax, spikes, params)
    counts_l = np.asarray(jax.block_until_ready(counts_l))
    t_las = time.time() - t0
    acc_l = float(np.mean(np.argmax(counts_l, -1) == labels))

    e_g, e_l = float(e_g), float(e_l)
    print(f"\n   accuracy: golden {acc_g:.2%} vs LASANA {acc_l:.2%} "
          f"(delta {abs(acc_g - acc_l) * 100:.2f} pts)")
    print(f"   total energy err: {abs(e_l - e_g) / max(e_g, 1e-30):.2%}")
    print(f"   wall: golden {t_gold:.1f}s vs LASANA {t_las:.1f}s")


if __name__ == "__main__":
    main()
