"""Spiking MNIST case study (paper §V-E, second half).

A 784-128-10 SNN (ANN-to-SNN conversion, Poisson rate coding, 100 ticks)
runs through the ``repro.lasana`` facade once per backend: golden LIF
integration vs. a trained LASANA ``Surrogate`` wired by the same
connectivity. Reported: MNIST-style accuracy of both, spike-level
agreement, total-energy error, per-layer report, wall time.

    PYTHONPATH=src python examples/snn_mnist.py [--n-test 100]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.lasana as lasana
from repro.core.network import snn_spec
from repro.data.mnist import make_digits, poisson_encode

LAYERS = (784, 128, 10)
T_STEPS = 100


def train_ann(seed=0, n_train=4000, steps=400):
    imgs, labels = make_digits(n_train, size=28, seed=seed)
    key = jax.random.PRNGKey(seed)
    ws = []
    for i in range(len(LAYERS) - 1):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (LAYERS[i], LAYERS[i + 1]))
                  * (2.0 / LAYERS[i]) ** 0.5)

    def forward(ws, x):
        h = x
        for i, w in enumerate(ws):
            h = h @ w
            if i < len(ws) - 1:
                h = jax.nn.relu(h)
        return h

    def loss(ws, x, y):
        return -jnp.mean(jax.nn.log_softmax(forward(ws, x))
                         [jnp.arange(len(y)), y])

    x, y = jnp.asarray(imgs), jnp.asarray(labels)
    gfn = jax.jit(jax.grad(loss))
    for _ in range(steps):
        g = gfn(ws, x, y)
        ws = [w - 0.1 * gi for w, gi in zip(ws, g)]
    # ANN->SNN conversion: normalize each layer to its 99th-percentile preact
    h = np.asarray(x)
    out = []
    for i, w in enumerate(ws):
        pre = h @ np.asarray(w)
        scale = np.percentile(np.abs(pre), 99)
        out.append(np.asarray(w) / scale * 2.2)     # drive into spiking range
        h = np.maximum(pre, 0)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-test", type=int, default=100)
    ap.add_argument("--bank-runs", type=int, default=600)
    args = ap.parse_args()

    print("== training + converting 784-128-10 ANN->SNN ==")
    ws = train_ann()
    imgs, labels = make_digits(args.n_test, size=28, seed=777)
    spikes = poisson_encode(imgs, T_STEPS, seed=5) * 1.5   # V_dd spikes
    spikes = jnp.asarray(spikes)

    # per-layer LIF knobs: paper's setting (all 0.5 V, V_leak = 0.58 V)
    params = [jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32) for _ in ws]
    spec = snn_spec([jnp.asarray(w) for w in ws], params)

    print("== golden SNN simulation (lasana.simulate) ==")
    run_g = lasana.simulate(spec, spikes, backend="golden")
    acc_g = float(np.mean(np.argmax(run_g.outputs, -1) == labels))

    print("== training LIF surrogate artifact ==")
    surrogate = lasana.train("lif", lasana.TrainConfig(
        n_runs=args.bank_runs, n_steps=100, families=("linear", "mlp")))

    print("== LASANA SNN simulation (lasana.simulate) ==")
    run_l = lasana.simulate(spec, spikes, surrogates=surrogate)
    acc_l = float(np.mean(np.argmax(run_l.outputs, -1) == labels))

    rep_g, rep_l = run_g.report(), run_l.report()
    e_g = rep_g["network"]["energy_j"]
    e_l = rep_l["network"]["energy_j"]
    spike_match = float(np.mean(
        (run_g.out_spikes > 0.75) == (run_l.out_spikes > 0.75)))

    print(f"\n   accuracy: golden {acc_g:.2%} vs LASANA {acc_l:.2%} "
          f"(delta {abs(acc_g - acc_l) * 100:.2f} pts)")
    print(f"   output spike agreement: {spike_match:.2%}")
    print(f"   total energy err: {abs(e_l - e_g) / max(e_g, 1e-30):.2%}")
    print("   per-layer (LASANA): " + "; ".join(
        f"L{l['layer']} [{l['circuit']}]: {l['energy_j'] * 1e9:.2f} nJ, "
        f"{l['events']} events" for l in rep_l["layers"]))
    print(f"   events/s: LASANA {rep_l['network']['events_per_sec']:.3g} "
          f"vs golden {rep_g['network']['events_per_sec']:.3g}")
    print(f"   wall: golden {run_g.wall_seconds:.1f}s vs LASANA "
          f"{run_l.wall_seconds:.1f}s")


if __name__ == "__main__":
    main()
