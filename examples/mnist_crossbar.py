"""Crossbar MNIST case study (paper §V-E, first half).

A 400-120-84-10 ternary-weight network runs on 32-input PCM crossbar rows:
every layer matmul is tiled into 32-wide row segments, each segment is one
LASANA crossbar-row instance (the paper's 67-crossbar accelerator built
from 32x LASANA rows per crossbar). We compare the full golden transient
simulation of every row event against LASANA surrogates: classification
accuracy, per-inference energy, and wall time.

    PYTHONPATH=src python examples/mnist_crossbar.py [--n-test 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import CrossbarRow
from repro.core.dataset import TestbenchConfig, build_dataset
from repro.core.predictors import PredictorBank, build_features
from repro.data.mnist import make_digits

LAYERS = (400, 120, 84, 10)


def train_ternary_net(seed=0, n_train=4000, steps=300):
    """Train float net on synthetic digits, then ternarize to {-1,0,1}."""
    imgs, labels = make_digits(n_train, size=20, seed=seed)
    key = jax.random.PRNGKey(seed)
    ws = []
    for i in range(len(LAYERS) - 1):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (LAYERS[i], LAYERS[i + 1]))
                  * (2.0 / LAYERS[i]) ** 0.5)

    def forward(ws, x):
        h = x * 1.6 - 0.8                      # pixel -> [-0.8, 0.8] volts
        for i, w in enumerate(ws):
            h = h @ w
            if i < len(ws) - 1:
                h = jnp.tanh(h)
        return h

    def loss(ws, x, y):
        logits = forward(ws, x)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])

    x = jnp.asarray(imgs)
    y = jnp.asarray(labels)
    lr = 0.05
    gfn = jax.jit(jax.grad(loss))
    for s in range(steps):
        g = gfn(ws, x, y)
        ws = [w - lr * gi for w, gi in zip(ws, g)]
    # ternarize: w -> {-1,0,1} at the 0.5-sigma threshold, scale folded out
    tern = []
    for w in ws:
        t = np.asarray(w)
        thr = 0.5 * t.std()
        tern.append(np.sign(t) * (np.abs(t) > thr))
    return tern


def _row_segments(w):
    """(n_in, n_out) ternary matrix -> (n_seg_rows, 33) crossbar params."""
    n_in, n_out = w.shape
    n_seg = -(-n_in // 32)
    pad = n_seg * 32 - n_in
    wp = np.pad(w, ((0, pad), (0, 0)))
    segs = wp.reshape(n_seg, 32, n_out).transpose(2, 0, 1).reshape(-1, 32)
    return np.concatenate([segs, np.zeros((len(segs), 1))], 1).astype(np.float32)


def run_layer(x_volts, w, circ, bank=None):
    """x: (B, n_in) volts -> (analog outputs (B, n_out), energy J, latency ns).

    Golden when bank is None, LASANA otherwise. Each output neuron sums
    ceil(n_in/32) crossbar-row voltages (ADC'd digitally).
    """
    b, n_in = x_volts.shape
    n_out = w.shape[1]
    n_seg = -(-n_in // 32)
    params = _row_segments(w)                       # (n_out*n_seg, 33)
    xp = np.pad(x_volts, ((0, 0), (0, n_seg * 32 - n_in)))
    xin = xp.reshape(b, n_seg, 32)
    xin = np.broadcast_to(xin[:, None], (b, n_out, n_seg, 32)).reshape(-1, 32)
    pall = np.broadcast_to(params[None], (b, *params.shape)).reshape(-1, 33)
    n_rows = xin.shape[0]
    if bank is None:
        st, obs = circ.step(jnp.zeros((n_rows, 1)), jnp.asarray(xin),
                            jnp.asarray(pall))
        v = np.asarray(obs["output"])
        e = float(np.sum(np.asarray(obs["energy"])))
        lat = float(np.max(np.asarray(obs["latency"])))
    else:
        feats = np.concatenate(
            [xin, np.zeros((n_rows, 1), np.float32),
             np.full((n_rows, 1), circ.clock_ns, np.float32), pall], 1)
        v = np.asarray(bank.predict("M_O", jnp.asarray(feats)))
        feats_tr = np.concatenate(
            [feats, np.zeros((n_rows, 1), np.float32),
             v[:, None].astype(np.float32)], 1)
        e = float(np.sum(np.asarray(
            bank.predict("M_ED", jnp.asarray(feats_tr)))))
        lat = float(np.max(np.asarray(
            bank.predict("M_L", jnp.asarray(feats_tr)))))
    # 8-bit ADC over [-2, 2], then digital gain compensation: the TIA gives
    # v = -R_f*G_unit*dot = -0.48*dot (inverting), undone in the digital domain
    v = np.round((v + 2.0) / 4.0 * 255) / 255 * 4.0 - 2.0
    gain = -circ.r_f * circ.g_unit
    out = v.reshape(b, n_out, n_seg).sum(-1) / gain
    return out, e, lat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-test", type=int, default=200)
    ap.add_argument("--bank-runs", type=int, default=400)
    args = ap.parse_args()

    print("== training ternary 400-120-84-10 network on synthetic digits ==")
    ws = train_ternary_net()
    imgs, labels = make_digits(args.n_test, size=20, seed=999)
    circ = CrossbarRow()
    n_tiles = sum((-(-w.shape[0] // 32)) * w.shape[1] for w in ws) / 32
    print(f"   {n_tiles:.0f} 32x32-crossbar equivalents")

    print("== training crossbar surrogate bank ==")
    ds = build_dataset("crossbar", TestbenchConfig(n_runs=args.bank_runs,
                                                   n_steps=100))
    bank = PredictorBank("crossbar", families=("linear", "gbdt", "mlp")).fit(ds)

    def infer(bank_or_none):
        x = imgs * 1.6 - 0.8
        e_tot, lat_tot = 0.0, 0.0
        for i, w in enumerate(ws):
            x, e, lat = run_layer(x, w, circ, bank_or_none)
            e_tot += e
            lat_tot += lat
            if i < len(ws) - 1:
                x = np.tanh(x)                      # digital activation
                x = x * 0.8                         # DAC back to volts
        pred = np.argmax(x, -1)
        return pred, e_tot, lat_tot

    # digital reference (exact ternary matmuls, same activations)
    def infer_digital():
        x = imgs * 1.6 - 0.8
        for i, w in enumerate(ws):
            x = x @ w
            if i < len(ws) - 1:
                x = np.tanh(x) * 0.8
        return np.argmax(x, -1)

    acc_d = float(np.mean(infer_digital() == labels))
    print(f"   digital ternary-net reference accuracy: {acc_d:.2%}")

    print("== golden (SPICE stand-in) inference ==")
    t0 = time.time()
    pred_g, e_g, lat_g = infer(None)
    t_gold = time.time() - t0
    acc_g = float(np.mean(pred_g == labels))

    print("== LASANA inference ==")
    t0 = time.time()
    pred_l, e_l, lat_l = infer(bank)
    t_las = time.time() - t0
    acc_l = float(np.mean(pred_l == labels))

    print(f"\n   accuracy: golden {acc_g:.2%} vs LASANA {acc_l:.2%} "
          f"(delta {abs(acc_g - acc_l) * 100:.2f} pts)")
    print(f"   energy/inference: golden {e_g / args.n_test * 1e9:.3f} nJ vs "
          f"LASANA {e_l / args.n_test * 1e9:.3f} nJ "
          f"(err {abs(e_l - e_g) / e_g:.2%})")
    print(f"   latency err: {abs(lat_l - lat_g) / max(lat_g, 1e-9):.2%}")
    print(f"   wall: golden {t_gold:.1f}s vs LASANA {t_las:.1f}s "
          f"({t_gold / max(t_las, 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
