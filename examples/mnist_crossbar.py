"""Crossbar MNIST case study (paper §V-E, first half).

A 400-120-84-10 ternary-weight network runs on 32-input PCM crossbar rows:
the network engine (core/network.py) tiles every layer matmul into 32-wide
row segments, each segment one crossbar-row instance (the paper's
67-crossbar accelerator built from 32x LASANA rows per crossbar), with the
8-bit ADC and digital tanh activation between layers. We compare the full
golden transient simulation of every row event against LASANA surrogates:
classification accuracy, per-inference energy, and wall time.

    PYTHONPATH=src python examples/mnist_crossbar.py [--n-test 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.lasana as lasana
from repro.core.network import crossbar_mlp_spec
from repro.data.mnist import make_digits

LAYERS = (400, 120, 84, 10)


def train_ternary_net(seed=0, n_train=4000, steps=300):
    """Train float net on synthetic digits, then ternarize to {-1,0,1}."""
    imgs, labels = make_digits(n_train, size=20, seed=seed)
    key = jax.random.PRNGKey(seed)
    ws = []
    for i in range(len(LAYERS) - 1):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (LAYERS[i], LAYERS[i + 1]))
                  * (2.0 / LAYERS[i]) ** 0.5)

    def forward(ws, x):
        h = x * 1.6 - 0.8                      # pixel -> [-0.8, 0.8] volts
        for i, w in enumerate(ws):
            h = h @ w
            if i < len(ws) - 1:
                h = jnp.tanh(h)
        return h

    def loss(ws, x, y):
        logits = forward(ws, x)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])

    x = jnp.asarray(imgs)
    y = jnp.asarray(labels)
    lr = 0.05
    gfn = jax.jit(jax.grad(loss))
    for s in range(steps):
        g = gfn(ws, x, y)
        ws = [w - lr * gi for w, gi in zip(ws, g)]
    # ternarize: w -> {-1,0,1} at the 0.5-sigma threshold, scale folded out
    tern = []
    for w in ws:
        t = np.asarray(w)
        thr = 0.5 * t.std()
        tern.append(np.sign(t) * (np.abs(t) > thr))
    return tern


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-test", type=int, default=200)
    ap.add_argument("--bank-runs", type=int, default=400)
    args = ap.parse_args()

    print("== training ternary 400-120-84-10 network on synthetic digits ==")
    ws = train_ternary_net()
    imgs, labels = make_digits(args.n_test, size=20, seed=999)
    spec = crossbar_mlp_spec(ws)
    n_tiles = sum((-(-w.shape[0] // 32)) * w.shape[1] for w in ws) / 32
    print(f"   {n_tiles:.0f} 32x32-crossbar equivalents")

    print("== training crossbar surrogate artifact ==")
    surrogate = lasana.train("crossbar", lasana.TrainConfig(
        n_runs=args.bank_runs, n_steps=100,
        families=("linear", "gbdt", "mlp")))

    x_volts = imgs * 1.6 - 0.8

    # digital reference (exact ternary matmuls, same activations)
    def infer_digital():
        x = x_volts
        for i, w in enumerate(ws):
            x = x @ w
            if i < len(ws) - 1:
                x = np.tanh(x) * 0.8
        return np.argmax(x, -1)

    acc_d = float(np.mean(infer_digital() == labels))
    print(f"   digital ternary-net reference accuracy: {acc_d:.2%}")

    print("== golden (SPICE stand-in) inference (lasana.simulate) ==")
    run_g = lasana.simulate(spec, x_volts, backend="golden")
    acc_g = float(np.mean(np.argmax(run_g.outputs, -1) == labels))

    print("== LASANA inference (lasana.simulate) ==")
    run_l = lasana.simulate(spec, x_volts, surrogates=surrogate)
    acc_l = float(np.mean(np.argmax(run_l.outputs, -1) == labels))

    rep_g, rep_l = run_g.report(), run_l.report()
    e_g, e_l = rep_g["network"]["energy_j"], rep_l["network"]["energy_j"]
    lat_g = sum(l["max_latency_ns"] for l in rep_g["layers"])
    lat_l = sum(l["max_latency_ns"] for l in rep_l["layers"])

    print(f"\n   accuracy: golden {acc_g:.2%} vs LASANA {acc_l:.2%} "
          f"(delta {abs(acc_g - acc_l) * 100:.2f} pts)")
    print(f"   energy/inference: golden {e_g / args.n_test * 1e9:.3f} nJ vs "
          f"LASANA {e_l / args.n_test * 1e9:.3f} nJ "
          f"(err {abs(e_l - e_g) / e_g:.2%})")
    print(f"   latency err: {abs(lat_l - lat_g) / max(lat_g, 1e-9):.2%}")
    print("   per-layer (LASANA): " + "; ".join(
        f"L{l['layer']} [{l['circuit']}]: {l['energy_j'] * 1e9:.2f} nJ, "
        f"{l['events']} rows" for l in rep_l["layers"]))
    print(f"   wall: golden {run_g.wall_seconds:.1f}s vs LASANA "
          f"{run_l.wall_seconds:.1f}s "
          f"({run_g.wall_seconds / max(run_l.wall_seconds, 1e-9):.1f}x)")


if __name__ == "__main__":
    main()
