"""Architecture exploration: every assigned LM architecture mapped onto
32x32 analog crossbar macros with LASANA energy/latency annotation
(the paper's purpose — §I "rapid exploration and co-design" — applied to
modern LM workloads; see DESIGN.md §2.3).

    PYTHONPATH=src python examples/explore_accelerator.py [--reduced]
"""

import argparse

import repro.lasana as lasana
from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.core.explore import explore_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="use reduced configs (fast)")
    ap.add_argument("--bank-runs", type=int, default=300)
    args = ap.parse_args()

    print("== training crossbar surrogates ==")
    surrogate = lasana.train("crossbar", lasana.TrainConfig(
        n_runs=args.bank_runs, n_steps=100, families=("linear", "gbdt")))

    print("== mapping architectures onto analog CiM macros ==\n")
    get = reduced_config if args.reduced else get_config
    for arch in ARCH_IDS:
        rep = explore_arch(get(arch), surrogate)
        print("  " + rep.summary())


if __name__ == "__main__":
    main()
