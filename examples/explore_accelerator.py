"""Architecture exploration: map LM architectures onto analog crossbar
macros with LASANA energy/latency annotation (the paper's purpose — §I
"rapid exploration and co-design" — applied to modern LM workloads; see
DESIGN.md §2.3).

Two modes share one trained crossbar surrogate:

  zoo (default)  every assigned LM architecture through the per-arch
                 ``explore_arch`` report
  --sweep N      an N-point randomized design space (layer widths, tile
                 size, V_dd, MoE shape, circuit mix) priced through ONE
                 compiled program via ``lasana.explore``, with the
                 Pareto frontier over (energy/token, critical latency,
                 analog fraction) printed

    PYTHONPATH=src python examples/explore_accelerator.py [--reduced]
    PYTHONPATH=src python examples/explore_accelerator.py --sweep 2048
"""

import argparse

import repro.lasana as lasana
from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.core.explore import explore_arch


def sweep(surrogate, n: int, seed: int) -> None:
    cands = lasana.CandidateSpec.sample(n, seed=seed)
    rep = lasana.explore(cands, surrogate)
    print(f"== {n}-candidate sweep: one compiled program, "
          f"{rep.wall_seconds:.2f}s eval ==\n")
    front = rep.pareto()
    print(f"Pareto frontier ({front.size} of {n} candidates), "
          "best-energy first:")
    order = front[rep.energy_per_token_j[front].argsort()]
    for i in order[:20]:
        print("  " + rep.summary(int(i)))
    if front.size > 20:
        print(f"  ... {front.size - 20} more frontier points")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="use reduced configs (fast)")
    ap.add_argument("--bank-runs", type=int, default=300)
    ap.add_argument("--sweep", type=int, default=0, metavar="N",
                    help="price an N-point random design space instead of "
                         "the architecture zoo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("== training crossbar surrogates ==")
    surrogate = lasana.train("crossbar", lasana.TrainConfig(
        n_runs=args.bank_runs, n_steps=100, families=("linear", "gbdt")))

    if args.sweep:
        sweep(surrogate, args.sweep, args.seed)
        return

    print("== mapping architectures onto analog CiM macros ==\n")
    get = reduced_config if args.reduced else get_config
    for arch in ARCH_IDS:
        rep = explore_arch(get(arch), surrogate)
        print("  " + rep.summary())


if __name__ == "__main__":
    main()
