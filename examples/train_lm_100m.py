"""End-to-end LM training driver: a ~100M-parameter dense model trained for
a few hundred steps on the synthetic corpus through the full production
stack (mesh/rules, microbatched train step, prefetching pipeline, async
checkpoints, watchdog, auto-resume).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300

On this CPU container a 100M model runs ~5 s/step; pass --tiny for a
25M model at ~1 s/step. On a real pod the same driver shards over
whatever mesh the launcher finds.
"""

import argparse
import sys

from repro.launch.train import parse_args, train

MODEL_100M = dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                  d_ff=2048, vocab=32768)
MODEL_25M = dict(n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
                 d_ff=1024, vocab=16384)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="results/ckpt_100m")
    args = ap.parse_args()

    # register a custom config under the starcoder2 family
    from repro.configs.base import AttentionKind, Family, ModelConfig
    import repro.configs as cfgs
    dims = MODEL_25M if args.tiny else MODEL_100M
    cfg = ModelConfig(name="lm-100m", family=Family.DENSE,
                      attention=AttentionKind.GQA, rope_theta=1e4, **dims)
    print(f"model: {cfg.describe()}")

    import repro.launch.train as T
    orig = T.reduced_config
    T.reduced_config = lambda _arch: cfg
    try:
        targs = parse_args([
            "--arch", "starcoder2-3b", "--reduced",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--log-every", "10", "--warmup", "30", "--lr", "6e-4"])
        out = train(targs)
        first = sum(out["losses"][:10]) / max(len(out["losses"][:10]), 1)
        last = sum(out["losses"][-10:]) / max(len(out["losses"][-10:]), 1)
        print(f"loss: first-10 avg {first:.3f} -> last-10 avg {last:.3f}")
    finally:
        T.reduced_config = orig


if __name__ == "__main__":
    main()
