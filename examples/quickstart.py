"""Quickstart: the whole LASANA flow in two minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. golden-simulate a randomized LIF testbench (the SPICE stand-in)
2. extract E1/E2/E3 events, train the five surrogate predictors
3. replay a fresh 1,000-neuron layer through Algorithm 1
4. compare LASANA vs golden: spike accuracy, energy error, runtime
"""

import numpy as np

from repro.core.dataset import TestbenchConfig, build_dataset
from repro.core.predictors import PredictorBank
from repro.core.simulate import make_stimulus, run_golden, run_lasana


def main():
    print("== 1/4: dataset generation (golden transient sim) ==")
    ds = build_dataset("lif", TestbenchConfig(n_runs=300, n_steps=100))
    print(f"   events: {ds.counts()}  ({ds.gen_seconds:.1f}s)")

    print("== 2/4: training surrogate predictors ==")
    bank = PredictorBank("lif", families=("linear", "mlp")).fit(ds, verbose=True)

    print("== 3/4: Algorithm 1 over a 1,000-neuron layer, 100 ticks ==")
    active, x, params = make_stimulus("lif", 1000, 100, seed=123)
    golden = run_golden("lif", active, x, params)
    surro = run_lasana(bank, "lif", active, x, params)

    print("== 4/4: LASANA vs golden ==")
    acc = float(np.mean((golden.outputs > 0.75) == (surro.outputs > 0.75)))
    e_err = abs(surro.energy.sum() - golden.energy.sum()) / golden.energy.sum()
    print(f"   spike accuracy : {acc:.2%}")
    print(f"   total-energy err: {e_err:.2%}")
    print(f"   wall: golden {golden.wall_seconds:.2f}s vs "
          f"LASANA {surro.wall_seconds:.2f}s")


if __name__ == "__main__":
    main()
