"""Quickstart: the whole LASANA flow in two minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. ``lasana.train``: golden-simulate a randomized LIF testbench (the SPICE
   stand-in), extract E1/E2/E3 events, fit + select the five predictors,
   and freeze them into a deployable ``Surrogate`` artifact
2. persist the artifact (``save``/``load`` round-trip — what a serving
   fleet would deploy)
3. replay a fresh 1,000-neuron layer through Algorithm 1
4. compare LASANA vs golden: spike accuracy, energy error, runtime
"""

import numpy as np

import repro.lasana as lasana
from repro.core.simulate import make_stimulus, run_golden, run_lasana


def main():
    print("== 1/4: train a surrogate (golden sim -> events -> predictors) ==")
    surrogate = lasana.train(
        "lif", lasana.TrainConfig(n_runs=300, n_steps=100,
                                  families=("linear", "mlp")),
        verbose=True)

    print("== 2/4: persist + reload the artifact ==")
    surrogate.save("results/quickstart_lif.npz")
    surrogate = lasana.load("results/quickstart_lif.npz")
    print(f"   {surrogate}")

    print("== 3/4: Algorithm 1 over a 1,000-neuron layer, 100 ticks ==")
    active, x, params = make_stimulus("lif", 1000, 100, seed=123)
    golden = run_golden("lif", active, x, params)
    surro = run_lasana(surrogate, "lif", active, x, params)

    print("== 4/4: LASANA vs golden ==")
    acc = float(np.mean((golden.outputs > 0.75) == (surro.outputs > 0.75)))
    e_err = abs(surro.energy.sum() - golden.energy.sum()) / golden.energy.sum()
    print(f"   spike accuracy : {acc:.2%}")
    print(f"   total-energy err: {e_err:.2%}")
    print(f"   wall: golden {golden.wall_seconds:.2f}s vs "
          f"LASANA {surro.wall_seconds:.2f}s "
          f"(compile excluded: {golden.compile_seconds:.2f}s / "
          f"{surro.compile_seconds:.2f}s)")


if __name__ == "__main__":
    main()
