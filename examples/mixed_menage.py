"""Mixed-circuit MENAGE-style accelerator demo (heterogeneous graph engine).

A crossbar MAC front-end feeds a spiking LIF classifier bank with lateral
(recurrent, one-tick-delayed) inhibition — the mixed-signal composition of
MENAGE-class accelerators (analog in-memory MACs + event-driven neuron
banks), expressed as ONE ``NetworkSpec`` and run on all three backends
through the ``repro.lasana`` facade:

  golden      — full transient ODE integration of every row/neuron
  behavioral  — ideal discrete update (no energy/latency)
  lasana      — Algorithm 1 over a per-circuit-kind ``SurrogateLibrary``
                ({"crossbar": ..., "lif": ...})

The graph:  pixels (DAC volts, held per tick)
              -> crossbar_layer(ternary W1)        # analog MAC, 8-bit ADC
              -> lif_layer(W2)                     # spiking readout
                   ^---- recurrent_edge(1, 1, -c*(1-I))   # lateral inhibition

Reported: classification accuracy per backend, LASANA-vs-behavioral spike
mismatch (acceptance: <2%), and the per-layer energy report attributed by
circuit kind.

    PYTHONPATH=src python examples/mixed_menage.py [--n-test 64]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.lasana as lasana
from repro.core.network import (crossbar_layer, graph_spec, lif_layer,
                                recurrent_edge)
from repro.data.mnist import make_digits

SIZE = 12                       # 12x12 synthetic digits -> 144 DAC lines
N_HID = 24                      # crossbar MAC outputs
N_CLS = 10
T_STEPS = 30


def train_front_and_readout(seed=0, n_train=3000, steps=300):
    """Float 144-24-10 net on synthetic digits; layer 1 ternarized for the
    crossbar, layer 2 rescaled into the LIF spiking drive range."""
    imgs, labels = make_digits(n_train, size=SIZE, seed=seed)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (SIZE * SIZE, N_HID)) * (2.0 / SIZE ** 2) ** 0.5
    w2 = jax.random.normal(k2, (N_HID, N_CLS)) * (2.0 / N_HID) ** 0.5

    def forward(ws, x):
        h = jnp.tanh((x * 1.6 - 0.8) @ ws[0])
        return h @ ws[1]

    def loss(ws, x, y):
        return -jnp.mean(jax.nn.log_softmax(forward(ws, x))
                         [jnp.arange(len(y)), y])

    ws = [w1, w2]
    x, y = jnp.asarray(imgs), jnp.asarray(labels)
    gfn = jax.jit(jax.grad(loss))
    for _ in range(steps):
        g = gfn(ws, x, y)
        ws = [w - 0.15 * gi for w, gi in zip(ws, g)]
    t = np.asarray(ws[0])
    tern = np.sign(t) * (np.abs(t) > 0.5 * t.std())       # {-1, 0, 1}
    w2 = np.asarray(ws[1])
    w2 = w2 / np.percentile(np.abs(w2), 99) * 1.8          # spiking range
    return tern.astype(np.float32), w2.astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-test", type=int, default=64)
    ap.add_argument("--lif-runs", type=int, default=600)
    ap.add_argument("--xbar-runs", type=int, default=200)
    args = ap.parse_args()

    print(f"== training {SIZE * SIZE}-{N_HID}-{N_CLS} mixed net "
          "(ternary crossbar front-end + LIF readout) ==")
    w1, w2 = train_front_and_readout()
    imgs, labels = make_digits(args.n_test, size=SIZE, seed=777)

    lif_params = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    inhib = -0.4 * (1.0 - np.eye(N_CLS, dtype=np.float32))
    spec = graph_spec(
        [crossbar_layer(w1), lif_layer(w2, lif_params)],
        edges=[recurrent_edge(1, 1, inhib)])
    # DAC volts held for T_STEPS ticks (sample-and-hold stimulus)
    x_volts = (imgs * 1.6 - 0.8).astype(np.float32)
    seq = jnp.asarray(np.broadcast_to(x_volts, (T_STEPS, *x_volts.shape)))

    print("== golden (SPICE stand-in) simulation ==")
    run_g = lasana.simulate(spec, seq, backend="golden")
    print("== behavioral simulation ==")
    run_b = lasana.simulate(spec, seq, backend="behavioral")

    print("== training the per-circuit-kind surrogate library ==")
    library = lasana.SurrogateLibrary({
        "lif": lasana.train("lif", lasana.TrainConfig(
            n_runs=args.lif_runs, n_steps=100,
            families=("linear", "mlp"))),
        "crossbar": lasana.train("crossbar", lasana.TrainConfig(
            n_runs=args.xbar_runs, n_steps=100,
            families=("linear", "gbdt", "mlp"))),
    })

    print("== LASANA simulation (one spec, one surrogate library) ==")
    run_l = lasana.simulate(spec, seq, surrogates=library)

    accs = {name: float(np.mean(np.argmax(r.outputs, -1) == labels))
            for name, r in (("golden", run_g), ("behavioral", run_b),
                            ("lasana", run_l))}
    mism = float(np.mean((run_l.layer_spikes[1] > 0.75)
                         != (run_b.layer_spikes[1] > 0.75)))
    rep = run_l.report()

    print("\n   accuracy: " + "  ".join(f"{k} {v:.2%}"
                                        for k, v in accs.items()))
    print(f"   LASANA-vs-behavioral spike mismatch: {mism:.2%} "
          f"(target < 2%)")
    print("   per-layer (LASANA): " + "; ".join(
        f"L{l['layer']} [{l['circuit']}]: {l['energy_j'] * 1e9:.3f} nJ, "
        f"{l['events']} events" for l in rep["layers"]))
    print("   by circuit kind: " + "; ".join(
        f"{k}: {v['energy_j'] * 1e9:.3f} nJ / {v['events']} events"
        for k, v in rep["by_circuit"].items()))
    print(f"   events/s: LASANA {rep['network']['events_per_sec']:.3g} | "
          f"wall: golden {run_g.wall_seconds:.1f}s, behavioral "
          f"{run_b.wall_seconds:.1f}s, LASANA {run_l.wall_seconds:.1f}s")
    if mism >= 0.02:
        raise SystemExit(f"spike mismatch {mism:.2%} exceeds the 2% target")


if __name__ == "__main__":
    main()
