"""Optimizer substrate: AdamW with bf16/fp32 state policies, LR schedules,
global-norm clipping, and int8 error-feedback gradient compression.

No optax in this container — implemented from scratch on pytrees. The state
layout mirrors the param tree leaf-for-leaf so checkpointing and
mesh-elastic restore treat (params, m, v) uniformly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# --- schedules ---------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = (step + 1.0) / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)
    return sched


def constant_lr(base_lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.full((), base_lr, jnp.float32)


# --- global-norm clip -----------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


# --- AdamW -----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    state_dtype: Any = jnp.float32     # m/v dtype; bf16 halves optimizer HBM
    compress_grads: bool = False       # int8 error-feedback on DP gradients


class AdamW:
    """Stateless functional AdamW; state = {'m','v','err'?} mirroring params."""

    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg
        self.schedule = warmup_cosine(cfg.lr, cfg.warmup_steps, cfg.total_steps)

    def init(self, params):
        c = self.cfg
        zeros = lambda p: jnp.zeros(p.shape, c.state_dtype)
        state = {"m": jax.tree.map(zeros, params),
                 "v": jax.tree.map(zeros, params)}
        if c.compress_grads:
            state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                        params)
        return state

    def init_abstract(self, param_specs_abstract):
        """ShapeDtypeStruct state tree (dry-run: lower without allocation)."""
        c = self.cfg
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, c.state_dtype)
        state = {"m": jax.tree.map(zeros, param_specs_abstract),
                 "v": jax.tree.map(zeros, param_specs_abstract)}
        if c.compress_grads:
            state["err"] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                param_specs_abstract)
        return state

    def update(self, grads, state, params, step):
        c = self.cfg
        if c.compress_grads:
            grads, err = compress_decompress(grads, state["err"])
        grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - c.b1 ** t
        bc2 = 1.0 - c.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g32
            v_new = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + c.eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                delta = delta + c.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(c.state_dtype),
                    v_new.astype(c.state_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v}
        if c.compress_grads:
            new_state["err"] = err
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# --- int8 error-feedback compression ------------------------------------------------

def quantize_int8(x):
    """Symmetric per-tensor int8. -> (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """int8 quantize grads with error feedback (1-bit-Adam style residuals).

    On a real cluster the int8 payload is what crosses the DP interconnect
    (4x smaller all-reduce); numerically this function is exactly that
    round-trip, and the residual carries the quantization error into the
    next step so convergence is preserved.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, err)
    new_g = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
