"""Sharded, atomic, mesh-elastic checkpointing.

Format: one directory per step —
    step_0000100.tmp/           (written, fsynced)
      meta.json                 treedef + shapes/dtypes + user metadata
      leaf_00000.zst ...        zstd-compressed array chunks (``.raw``
                                uncompressed fallback when the optional
                                ``zstandard`` module is unavailable; the
                                codec is recorded in meta.json)
    -> atomic rename to step_0000100/   (commit point)

Design decisions for 1000+ node scale (documented here because the CPU
container exercises them at miniature scale):

  * Leaves are written as *global* arrays with their logical spec recorded,
    never device layouts — restore re-shards onto ANY mesh (elastic resume
    after losing a pod is a restore onto the survivor mesh).
  * On a real cluster each host writes only the shards it owns
    (``addressable_shards``); here one process owns everything, so the
    gather is a no-op in structure but the format is identical.
  * Async: ``save(..., blocking=False)`` hands the host arrays to a writer
    thread; the step loop never waits on the filesystem.
  * Crash safety: the ``.tmp`` rename is the commit; half-written dirs are
    ignored and GC'd; ``latest_step`` only sees committed checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

try:                                    # optional: fall back to raw chunks
    import zstandard as zstd
except ImportError:                     # pragma: no cover - env dependent
    zstd = None


def _tree_flatten_with_names(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # --- discovery --------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # --- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, metadata: Optional[dict] = None,
             blocking: bool = True):
        """Checkpoint a pytree of jax/np arrays at ``step``."""
        self.wait()
        leaves, treedef = _tree_flatten_with_names(tree)
        # device->host fetch happens on the caller thread (cheap, sharded);
        # compression + IO go to the writer thread.
        host_leaves = [np.asarray(x) for x in leaves]
        codec = "zstd" if zstd is not None else "raw"
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "codec": codec,
            "user": metadata or {},
            "time": time.time(),
        }

        def write():
            try:
                tmp = os.path.join(self.dir, f"step_{step:07d}.tmp")
                final = os.path.join(self.dir, f"step_{step:07d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                cctx = zstd.ZstdCompressor(level=3) if codec == "zstd" else None
                ext = "zst" if codec == "zstd" else "raw"
                for i, arr in enumerate(host_leaves):
                    raw = arr.tobytes()
                    if cctx is not None:
                        raw = cctx.compress(raw)
                    with open(os.path.join(tmp, f"leaf_{i:05d}.{ext}"),
                              "wb") as f:
                        f.write(raw)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)            # commit point
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError(f"async checkpoint write failed: {err}") from err

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:07d}"),
                          ignore_errors=True)
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # --- restore -----------------------------------------------------------

    def restore(self, step: int, like, *, shardings=None):
        """Restore into the structure of ``like``; re-shard if given.

        ``like`` may contain arrays or ShapeDtypeStructs; ``shardings`` (a
        matching pytree of NamedSharding) enables mesh-elastic placement.
        """
        path = os.path.join(self.dir, f"step_{step:07d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like)
        if len(leaves_like) != meta["n_leaves"]:
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves; target structure "
                f"has {len(leaves_like)}")
        codec = meta.get("codec", "zstd")
        if codec == "zstd" and zstd is None:
            raise RuntimeError(
                f"checkpoint {path} is zstd-compressed but the zstandard "
                "module is not installed")
        dctx = zstd.ZstdDecompressor() if codec == "zstd" else None
        ext = "zst" if codec == "zstd" else "raw"
        out = []
        for i, ref in enumerate(leaves_like):
            with open(os.path.join(path, f"leaf_{i:05d}.{ext}"), "rb") as f:
                raw = f.read()
            if dctx is not None:
                raw = dctx.decompress(raw)
            arr = np.frombuffer(raw, dtype=np.dtype(meta["dtypes"][i]))
            arr = arr.reshape(meta["shapes"][i])
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta["user"]

    def restore_latest(self, like, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None
        tree, user = self.restore(step, like, shardings=shardings)
        return step, tree, user
