"""Analog crossbar MVM kernel: differential-pair conductances + TIA
saturation, fused (the golden model's per-step target computation and the
MVM macro of the exploration feature).

Grid over circuit blocks; each block computes
    v_tgt = V_sat * tanh(-R_f * G_unit * (W v + b V_bias) / V_sat)
    tau   = tau0 * (1 + 0.5 * mean|W|)
with the (block, 32) x (block, 33) operands VMEM-resident. Rows are
independent (each circuit has its own weights) so the product is an
elementwise-multiply + row reduction — VPU work, MXU-free, which is the
right mapping for per-row distinct weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(n_inputs, g_unit, r_f, v_sat, v_bias, tau_base):
    def kernel(v_ref, w_ref, tgt_ref, tau_ref):
        v = v_ref[...].astype(jnp.float32)            # (bn, n_in)
        wfull = w_ref[...].astype(jnp.float32)        # (bn, n_in + 1)
        w = wfull[:, :n_inputs]
        bias = wfull[:, n_inputs]
        i_sig = g_unit * (jnp.sum(w * v, axis=-1) + bias * v_bias)
        v_lin = -r_f * i_sig
        tgt_ref[...] = v_sat * jnp.tanh(v_lin / v_sat)
        load = jnp.mean(jnp.abs(w), axis=-1)
        tau_ref[...] = tau_base * (1.0 + 0.5 * load)
    return kernel


def crossbar_target(v, w, *, g_unit=12e-6, r_f=40e3, v_sat=2.0, v_bias=0.8,
                    tau_base=0.15, block_n: int = 256, interpret: bool = True):
    """v: (N, n_in), w: (N, n_in+1) -> (v_tgt (N,), tau (N,))."""
    n, n_in = v.shape
    assert n % block_n == 0, (n, block_n)
    kernel = _make_kernel(n_in, g_unit, r_f, v_sat, v_bias, tau_base)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n_in), lambda i: (i, 0)),
            pl.BlockSpec((block_n, n_in + 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(v, w)
