"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.circuits import CrossbarRow, LIFNeuron


def mlp_surrogate_ref(x, w1, b1, w2, b2, w3, b3):
    h1 = jnp.maximum(x.astype(jnp.float32) @ w1 + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
    return h2 @ w3 + b3


def crossbar_target_ref(v, w, *, g_unit=12e-6, r_f=40e3, v_sat=2.0,
                        v_bias=0.8, tau_base=0.15):
    circ = CrossbarRow(g_unit=g_unit, r_f=r_f, v_sat=v_sat, v_bias=v_bias,
                       tau_base_ns=tau_base, n_inputs=v.shape[1])
    return circ._target(v, w)


def lif_step_ref(state, x, params, *, circ: LIFNeuron | None = None):
    circ = circ or LIFNeuron()
    return circ.step(state, x, params)


def flash_attention_ref(q, k, v):
    """Causal softmax attention, fp32 accumulation. q,k,v: (BH, S, D)."""
    s = q.shape[1]
    d = q.shape[2]
    logits = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)
