"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret mode
executes kernel bodies in Python for correctness validation). On real TPUs
set ``REPRO_PALLAS_INTERPRET=0`` / pass interpret=False and the same
BlockSpecs compile to Mosaic.

Wrappers handle padding to hardware-aligned shapes so callers stay
shape-agnostic: MLP feature dims pad to 128, circuit counts pad to the
block size.
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import crossbar_mvm as _xbar
from repro.kernels import flash_attn as _fa
from repro.kernels import lif_scan as _lif
from repro.kernels import mlp_surrogate as _mlp


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def fused_kernel_enabled(override: bool | None = None) -> bool:
    """THE single source of truth for the ``REPRO_FUSED_KERNEL`` knob.

    Every module that dispatches on the fused-kernel path (surrogate head
    stacking, the whole-tick megakernel, network program-cache keys,
    ``simulate``/``distributed`` cache keys) resolves the flag through this
    helper instead of re-reading the environment, so an explicit
    ``fused_kernel=`` keyword always wins over ``REPRO_FUSED_KERNEL`` and
    tests can toggle the path without env mutation.
    """
    if override is not None:
        return bool(override)
    return os.environ.get("REPRO_FUSED_KERNEL", "0") == "1"


def tick_pallas_enabled(override: bool | None = None) -> bool:
    """Whether the whole-tick megakernel runs as a ``pallas_call``.

    Resolution order: explicit ``override`` kwarg, then the
    ``REPRO_TICK_PALLAS`` env var ("1"/"0"), then the platform default —
    Pallas on real accelerators, the mathematically identical jnp body on
    CPU (where interpret-mode Pallas adds per-tick overhead for no gain).
    CI sets ``REPRO_TICK_PALLAS=1`` to execute the kernel code path in
    interpret mode on the CPU container.
    """
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_TICK_PALLAS")
    if env is not None:
        return env == "1"
    return not _interpret_default()


def bench_smoke() -> bool:
    """The ``REPRO_BENCH_SMOKE`` knob: CI-scale benchmark inputs.

    Benchmarks resolve smoke mode through this accessor (never the raw
    environment) so the program auditor's environment-discipline pass can
    verify ``ops`` is the only module reading configuration state."""
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def bench_results_dir(default: str = "results/benchmarks") -> str:
    """Where benchmark CSV/JSON artifacts land (``REPRO_BENCH_DIR``)."""
    return os.environ.get("REPRO_BENCH_DIR", default)


def engine_cache_capacity(default: int = 8) -> int:
    """Per-spec engine-LRU capacity (``REPRO_ENGINE_CACHE``).

    ``default`` is the caller's compiled-in capacity
    (``lasana.ENGINE_CACHE_CAPACITY``, which tests monkeypatch); the env
    var lets a deployment retune a running server without code changes.
    """
    env = os.environ.get("REPRO_ENGINE_CACHE")
    return int(env) if env else int(default)


def moe_capacity_factor(default: float) -> float:
    """Expert capacity-factor override (``REPRO_MOE_CF``); ``default`` is
    the model config's compiled-in factor."""
    return float(os.environ.get("REPRO_MOE_CF", default))


def microbatches_override():
    """``REPRO_MICROBATCHES`` as an int, or None when unset/empty."""
    env = os.environ.get("REPRO_MICROBATCHES")
    return int(env) if env else None


def fault_plan_path():
    """``REPRO_FAULT_PLAN``: path of a JSON fault-injection plan, or None.

    The resilience layer (``repro.resilience.faults``) resolves the
    ambient plan through this accessor — like every other knob, the raw
    environment is read only here so the program auditor's env-discipline
    pass keeps ``ops`` the single configuration reader. An empty value
    means no ambient plan (injection sites are no-op pass-throughs)."""
    return os.environ.get("REPRO_FAULT_PLAN") or None


# --- trace-time dispatch accounting (the program auditor's hook) --------------
#
# Hot-path inference entrypoints (Surrogate.predict / predict_heads, the
# whole-tick megakernel) report each surrogate dispatch here AT TRACE TIME.
# Scan bodies trace exactly once, so the count observed while tracing a
# tick program is its per-tick dispatch count — the quantity the frozen
# budgets in tests/data/program_budgets.json gate (fused <= 3 stacked
# dispatches, megakernel == 1; see docs/analysis.md). Outside an active
# scope (the production path) record_dispatch is a no-op attribute check.

_DISPATCH_SCOPE = None


def record_dispatch(name: str) -> None:
    """Report one surrogate dispatch (trace-time; no-op outside audits)."""
    if _DISPATCH_SCOPE is not None:
        _DISPATCH_SCOPE.append(name)


@contextlib.contextmanager
def dispatch_scope():
    """Collect ``record_dispatch`` names emitted while tracing under it.

    Yields the (live) list of dispatch names; scopes nest by save/restore
    so an audit inside an audit never double-counts."""
    global _DISPATCH_SCOPE
    prev, log = _DISPATCH_SCOPE, []
    _DISPATCH_SCOPE = log
    try:
        yield log
    finally:
        _DISPATCH_SCOPE = prev


# --- hot-path entrypoint registry ---------------------------------------------
#
# The program auditor (repro.analysis.jaxpr_audit) traces every registered
# entrypoint and checks its dispatch/dot budgets, donation discipline, and
# dtype/callback hygiene. The registry lives here — ops is the leaf module
# every hot path already imports — so registration can never cycle; the
# audit module registers the builders at ITS import time.

_ENTRYPOINTS: dict = {}


def register_entrypoint(name: str):
    """Decorator: register an audit entrypoint builder under ``name``."""
    def deco(builder):
        _ENTRYPOINTS[name] = builder
        return builder
    return deco


def registered_entrypoints() -> dict:
    """Name -> builder snapshot of the audit entrypoint registry."""
    return dict(_ENTRYPOINTS)


def _pad_to(x, n, axis, value=0.0):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mlp_surrogate(x, w1, b1, w2, b2, w3, b3, *, block_n: int = 256,
                  interpret: bool | None = None):
    """(N, F) -> (N,) fused MLP inference; pads N to block and F/H to 128."""
    interpret = _interpret_default() if interpret is None else interpret
    n, f = x.shape
    n_pad = _ceil_to(n, block_n)
    f_pad = _ceil_to(f, 128)
    h1_pad = _ceil_to(w1.shape[1], 128)
    h2_pad = _ceil_to(w2.shape[1], 128)
    xp = _pad_to(_pad_to(x, n_pad, 0), f_pad, 1)
    w1p = _pad_to(_pad_to(w1, f_pad, 0), h1_pad, 1)
    b1p = _pad_to(b1, h1_pad, 0)
    w2p = _pad_to(_pad_to(w2, h1_pad, 0), h2_pad, 1)
    b2p = _pad_to(b2, h2_pad, 0)
    w3p = _pad_to(w3, h2_pad, 0)
    out = _mlp.mlp_surrogate(xp, w1p, b1p, w2p, b2p, w3p, b3,
                             block_n=block_n, interpret=interpret)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mlp_surrogate_heads(x, x_mu, x_sd, y_mu, y_sd, w1, b1, w2, b2, w3, b3,
                        *, block_n: int = 256,
                        interpret: bool | None = None):
    """(N, F) + P stacked heads -> (P, N): fused multi-head MLP inference.

    The serving-side entry for the fused hot path
    (``Surrogate.predict_heads`` with ``REPRO_FUSED_KERNEL=1``): all P
    heads' weights stay VMEM-resident while the grid walks N-blocks.
    Stacked shapes: ``x_mu``/``x_sd`` (P, F), ``y_mu``/``y_sd`` (P, 1),
    ``w1`` (P, F, H1), ``b1`` (P, H1), ``w2`` (P, H1, H2), ``b2``
    (P, H2), ``w3`` (P, H2, 1), ``b3`` (P, 1).

    Ragged N is handled HERE (the raw kernel is shape-strict): N pads to
    the block size and F/H1/H2 pad to 128. Padded feature columns get
    ``x_sd = 1`` (a zero pad would divide by zero and poison the matmul
    with NaNs); their weights pad to zero, so padded columns contribute
    exactly nothing.
    """
    interpret = _interpret_default() if interpret is None else interpret
    n, f = x.shape
    n_pad = _ceil_to(n, block_n)
    f_pad = _ceil_to(f, 128)
    h1_pad = _ceil_to(w1.shape[2], 128)
    h2_pad = _ceil_to(w2.shape[2], 128)
    xp = _pad_to(_pad_to(x, n_pad, 0), f_pad, 1)
    xmu = _pad_to(x_mu, f_pad, 1)
    xsd = _pad_to(x_sd, f_pad, 1, value=1.0)
    w1p = _pad_to(_pad_to(w1, f_pad, 1), h1_pad, 2)
    b1p = _pad_to(b1, h1_pad, 1)
    w2p = _pad_to(_pad_to(w2, h1_pad, 1), h2_pad, 2)
    b2p = _pad_to(b2, h2_pad, 1)
    w3p = _pad_to(w3, h2_pad, 1)
    out = _mlp.mlp_surrogate_heads(
        xp, xmu, xsd, y_mu, y_sd, w1p, b1p, w2p, b2p, w3p, b3,
        block_n=block_n, interpret=interpret)
    return out[:, :n, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def crossbar_target(v, w, *, block_n: int = 256, interpret: bool | None = None):
    """(N, n_in), (N, n_in+1) -> (v_tgt (N,), tau (N,))."""
    interpret = _interpret_default() if interpret is None else interpret
    n = v.shape[0]
    n_pad = _ceil_to(n, block_n)
    tgt, tau = _xbar.crossbar_target(_pad_to(v, n_pad, 0),
                                     _pad_to(w, n_pad, 0),
                                     block_n=block_n, interpret=interpret)
    return tgt[:n], tau[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lif_step(state, x, params, *, block_n: int = 256,
             interpret: bool | None = None):
    """One golden LIF clock period for N neurons (kernelized SPICE farm)."""
    interpret = _interpret_default() if interpret is None else interpret
    n = state.shape[0]
    n_pad = _ceil_to(n, block_n)
    new_state, obs = _lif.lif_step(
        _pad_to(state, n_pad, 0), _pad_to(x, n_pad, 0),
        _pad_to(params, n_pad, 0), block_n=block_n, interpret=interpret)
    return new_state[:n], {k: v[:n] for k, v in obs.items()}


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lif_chunk(state, x_seq, params, *, block_n: int = 256,
              interpret: bool | None = None):
    """T golden LIF clock periods as ONE time-looped kernel launch.

    ``x_seq`` is (T, N, 3); circuit state stays VMEM-resident across the
    whole chunk (the lif_scan substep loop nests inside an outer tick
    loop). Per-tick observables come back as (T, N) sequences.
    """
    interpret = _interpret_default() if interpret is None else interpret
    n = state.shape[0]
    n_pad = _ceil_to(n, block_n)
    new_state, obs = _lif.lif_chunk(
        _pad_to(state, n_pad, 0), _pad_to(x_seq, n_pad, 1),
        _pad_to(params, n_pad, 0), block_n=block_n, interpret=interpret)
    return new_state[:n], {k: v[:, :n] for k, v in obs.items()}


def network_tick(*args, **kwargs):
    """One whole LASANA tick (idle -> act -> transition) as ONE kernel.

    Thin delegate so ``ops`` stays the single kernel entry namespace; the
    padding wrapper and kernel live in ``kernels.tick_megakernel`` (which
    imports circuit/wrapper math, so it is imported lazily here to keep
    ``ops`` a leaf module).
    """
    from repro.kernels import tick_megakernel as _tick
    return _tick.network_tick(*args, **kwargs)


def network_tick_chunk(*args, **kwargs):
    """A whole chunk of LASANA ticks as ONE time-looped kernel launch."""
    from repro.kernels import tick_megakernel as _tick
    return _tick.network_tick_chunk(*args, **kwargs)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Causal attention (B, H, S, D) -> (B, H, S, D)."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, s, d = q.shape
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = _fa.flash_attention(qf, kf, vf, block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.reshape(b, h, s, d)
