"""Whole-tick LASANA megakernel: Algorithm 1 as ONE kernel launch.

PR 5 collapsed the per-tick hot path to three stacked ``predict_heads``
dispatches (idle -> act -> transition); each still round-trips its
intermediates through HBM and relaunches. This module chains all three
stages of ``wrapper.lasana_step`` inside a single ``pallas_call``: the
surrogate weights and per-head standardizers stay VMEM-resident while the
grid walks circuit blocks, and the idle catch-up, active-variant heads,
output resolution, transition splice, and the Algorithm-1 record tail
(`_finish_tick`) all run on scratch values that never leave the core.
The ambitious end state is ``network_tick_chunk``: a time-looped variant
in the style of ``kernels/lif_scan.py`` that carries circuit state in
VMEM across a whole streaming chunk, one launch per chunk.

Head packing
------------
:func:`pack_heads` lifts a ``Surrogate``'s five Algorithm-1 predictors
into TWO canonical stacks — the A stack (idle/act feature width) holding
``M_ES``/``M_V``/``M_O`` and the T stack (transition width) holding
``M_ED``/``M_L`` — each a uniform ``(P, F, H1)/(P, H1, H2)/(P, H2, 1)``
array layout plus standardizers, regardless of predictor family. Layout
is uniform so one set of kernel refs serves every head, but EVALUATION
stays native-cost per family (:func:`_eval_stack` dispatches statically
on :class:`PackLayout` tags: a mean head is one broadcast, a linear head
one dot, only true MLP heads pay three matmuls). :func:`pack_library`
extends the stacking *across* circuit kinds in mixed graphs: every
kind's stacks pad to a common width and concatenate, so one resident
weight block serves all banks and a kind addresses its own heads through
static stack offsets.

Numerics contract (enforced by tests/test_megakernel.py): discrete
records (outputs, event classes, spike trains, t_last) are bit-identical
to the stacked-dispatch and per-call paths; continuous heads
(energy/latency/v) agree to rtol 1e-5 — head packing reorders float
reductions exactly like PR 5's stacking did. The jnp body and the Pallas
kernel compute the same math; ``REPRO_TICK_PALLAS`` (or the
``pallas=``/``ops.tick_pallas_enabled`` override) picks the launcher, and
interpret mode lets CPU CI execute the kernel code path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.circuits import augment_features, get_circuit
from repro.core.wrapper import (LasanaState, _features, _finish_tick,
                                _resolve_output, _splice_transition)
from repro.kernels import ops

# Stack membership, in stack order. The A stack serves BOTH the idle and
# the active variant (same feature width); the T stack serves the
# transition variant (o_prev/o_new columns spliced in).
PACK_HEADS_A = ("M_ES", "M_V", "M_O")
PACK_HEADS_T = ("M_ED", "M_L")
_PACKABLE = ("mean", "linear", "mlp")
_STACK_KEYS = ("x_mu", "x_sd", "y_mu", "y_sd",
               "w0", "b0", "w1", "b1", "w2", "b2", "scale")


@dataclasses.dataclass(frozen=True)
class PackLayout:
    """Static (hashable) metadata of one circuit kind's slice of a pack.

    ``a_fams``/``t_fams`` are the per-head family tags in stack order —
    they drive the native-cost dispatch in :func:`_eval_stack` and are
    part of every compiled program's identity. ``a_off``/``t_off`` are the
    kind's first stack indices in a :func:`pack_library` unified pack
    (0 for a single-kind pack)."""

    a_fams: tuple
    t_fams: tuple
    a_off: int = 0
    t_off: int = 0


def _canonical(arrays, fam, f, h1, h2, scale):
    """One head's params in the uniform (F, H1)/(H1, H2)/(H2, 1) layout.

    mean:   y = b2 (x ignored; standardizers neutral)
    linear: y = ((x - x_mu) / x_sd) @ w0[:, 0] + b2
    mlp:    the production 3-layer net, zero-padded into (h1, h2) —
            padded hidden units have zero weights in AND out, and
            relu(0) = 0, so padding contributes exactly nothing.
    Unused slots hold zeros (x_sd holds ONES — a zero pad would divide
    by zero and poison downstream ops with NaNs)."""
    f32 = jnp.float32
    out = {
        "x_mu": jnp.zeros((f,), f32),
        "x_sd": jnp.ones((f,), f32),
        "y_mu": jnp.zeros((1,), f32),
        "y_sd": jnp.ones((1,), f32),
        "w0": jnp.zeros((f, h1), f32),
        "b0": jnp.zeros((h1,), f32),
        "w1": jnp.zeros((h1, h2), f32),
        "b1": jnp.zeros((h2,), f32),
        "w2": jnp.zeros((h2, 1), f32),
        "b2": jnp.zeros((1,), f32),
        "scale": jnp.full((1,), scale, f32),
    }
    if fam == "mean":
        out["b2"] = jnp.asarray(arrays["mu"], f32).reshape(1)
    elif fam == "linear":
        out["x_mu"] = jnp.asarray(arrays["mu"], f32)
        out["x_sd"] = jnp.asarray(arrays["sd"], f32)
        out["w0"] = out["w0"].at[:, 0].set(jnp.asarray(arrays["w"][:-1], f32))
        out["b2"] = jnp.asarray(arrays["w"][-1:], f32)
    else:
        out["x_mu"] = jnp.asarray(arrays["x_mu"], f32)
        out["x_sd"] = jnp.asarray(arrays["x_sd"], f32)
        out["y_mu"] = jnp.asarray(arrays["y_mu"], f32).reshape(1)
        out["y_sd"] = jnp.asarray(arrays["y_sd"], f32).reshape(1)
        out["w0"] = ops._pad_to(jnp.asarray(arrays["w0"], f32), h1, 1)
        out["b0"] = ops._pad_to(jnp.asarray(arrays["b0"], f32), h1, 0)
        out["w1"] = ops._pad_to(
            ops._pad_to(jnp.asarray(arrays["w1"], f32), h1, 0), h2, 1)
        out["b1"] = ops._pad_to(jnp.asarray(arrays["b1"], f32), h2, 0)
        out["w2"] = ops._pad_to(jnp.asarray(arrays["w2"], f32), h2, 0)
        out["b2"] = jnp.asarray(arrays["b2"], f32).reshape(1)
    return out


def _mlp_layers(arrays) -> int:
    return sum(1 for k in arrays if k.startswith("w"))


def pack_heads(surrogate):
    """Build (pack, :class:`PackLayout`) for one surrogate, or (None, None).

    Eligibility is fully static (manifest families + array shapes), so the
    decision — and the fallback to the PR 5 stacked-dispatch path — never
    burns a trace-time branch: all five Algorithm-1 predictors present,
    every family packable (mean/linear/mlp with the production 3-layer
    config), the circuit registered, and trained feature widths matching
    the circuit's augmented widths. The arrays themselves may be traced
    (the pack is rebuilt from surrogate leaves inside jit, so hot-swapped
    surrogates reuse the compiled program)."""
    try:
        man = surrogate.manifest
        params = surrogate.params
    except AttributeError:
        return None, None
    try:
        circ = get_circuit(man.circuit)
    except KeyError:
        return None, None
    if circ is None or not hasattr(circ, "n_inputs"):
        return None, None
    names = PACK_HEADS_A + PACK_HEADS_T
    if not set(names) <= set(man.predictors):
        return None, None
    fams = {p: man.family_of(p) for p in names}
    if any(f not in _PACKABLE for f in fams.values()):
        return None, None
    f_raw = circ.n_inputs + 2 + circ.n_params
    probe = jnp.zeros((1, f_raw), jnp.float32)
    f_aug = int(augment_features(circ, probe).shape[1])
    probe_tr = jnp.zeros((1, f_raw + 2), jnp.float32)
    f_tr = int(augment_features(circ, probe_tr).shape[1])

    def native_width(p):
        a, fam = params[p], fams[p]
        if fam == "mlp":
            if _mlp_layers(a) != 3:
                return None
            return int(a["w0"].shape[0])
        if fam == "linear":
            return int(a["mu"].shape[0])
        return f_aug if p in PACK_HEADS_A else f_tr    # mean: width-free

    if any(native_width(p) != f_aug for p in PACK_HEADS_A):
        return None, None
    if any(native_width(p) != f_tr for p in PACK_HEADS_T):
        return None, None
    h1 = max([int(params[p]["w0"].shape[1])
              for p in names if fams[p] == "mlp"], default=1)
    h2 = max([int(params[p]["w1"].shape[1])
              for p in names if fams[p] == "mlp"], default=1)

    def stack(pnames, f):
        heads = [_canonical(params[p], fams[p], f, h1, h2, man.scale_of(p))
                 for p in pnames]
        return {k: jnp.stack([h[k] for h in heads]) for k in _STACK_KEYS}

    pack = {"a": stack(PACK_HEADS_A, f_aug), "t": stack(PACK_HEADS_T, f_tr)}
    layout = PackLayout(a_fams=tuple(fams[p] for p in PACK_HEADS_A),
                        t_fams=tuple(fams[p] for p in PACK_HEADS_T))
    return pack, layout


def _pad_stack(s, f, h1, h2):
    """Pad one canonical stack to (f, h1, h2); exact by construction
    (zero weights, ones x_sd — see _canonical)."""
    return {
        "x_mu": ops._pad_to(s["x_mu"], f, 1),
        "x_sd": ops._pad_to(s["x_sd"], f, 1, value=1.0),
        "y_mu": s["y_mu"], "y_sd": s["y_sd"], "scale": s["scale"],
        "w0": ops._pad_to(ops._pad_to(s["w0"], f, 1), h1, 2),
        "b0": ops._pad_to(s["b0"], h1, 1),
        "w1": ops._pad_to(ops._pad_to(s["w1"], h1, 1), h2, 2),
        "b1": ops._pad_to(s["b1"], h2, 1),
        "w2": ops._pad_to(s["w2"], h2, 1),
        "b2": s["b2"],
    }


def pack_library(banks):
    """Cross-kind head stacking: one unified pack for a whole library.

    Every kind's A/T stacks pad to the library-wide max feature/hidden
    widths and concatenate along the head axis, so a mixed graph keeps ONE
    resident weight block and each kind addresses its heads through the
    static ``a_off``/``t_off`` in its :class:`PackLayout`. Returns
    ``(pack, {kind: PackLayout})`` — or ``(None, {})`` if any kind is
    ineligible (callers fall back to per-kind packs / stacked dispatch)."""
    kinds = sorted(banks.kinds())
    packs, layouts = {}, {}
    for kind in kinds:
        p, lo = pack_heads(banks[kind])
        if p is None:
            return None, {}
        packs[kind] = p
        layouts[kind] = lo
    if len(kinds) == 1:
        return packs[kinds[0]], layouts
    f_a = max(p["a"]["w0"].shape[1] for p in packs.values())
    f_t = max(p["t"]["w0"].shape[1] for p in packs.values())
    h1 = max(p["a"]["w0"].shape[2] for p in packs.values())
    h2 = max(p["a"]["w1"].shape[2] for p in packs.values())
    a_parts = [_pad_stack(packs[k]["a"], f_a, h1, h2) for k in kinds]
    t_parts = [_pad_stack(packs[k]["t"], f_t, h1, h2) for k in kinds]
    pack = {
        "a": {k: jnp.concatenate([p[k] for p in a_parts]) for k in _STACK_KEYS},
        "t": {k: jnp.concatenate([p[k] for p in t_parts]) for k in _STACK_KEYS},
    }
    offs = {}
    a_off = t_off = 0
    for kind in kinds:
        offs[kind] = PackLayout(a_fams=layouts[kind].a_fams,
                                t_fams=layouts[kind].t_fams,
                                a_off=a_off, t_off=t_off)
        a_off += len(PACK_HEADS_A)
        t_off += len(PACK_HEADS_T)
    return pack, offs


def _pad_cols(x, f):
    """Zero-pad feature columns up to a stack's width (inert: padded
    columns carry x_sd=1 standardizers and zero weights)."""
    return ops._pad_to(x, f, 1)


def _eval_stack(s, x, off: int, fams):
    """Evaluate heads ``off .. off+len(fams)-1`` of canonical stack ``s``
    on augmented features ``x`` (N, F) — native cost per family.

    The uniform array layout exists for VMEM residency, NOT to force every
    head through MLP math: the family tags are static, so a mean head
    lowers to one broadcast and a linear head to one dot. All families
    share the destandardize + scale tail."""
    n = x.shape[0]
    f32 = jnp.float32
    ys = []
    for j, fam in enumerate(fams):
        i = off + j
        if fam == "mean":
            y = jnp.broadcast_to(s["b2"][i, 0], (n,))
        elif fam == "linear":
            xs = (x - s["x_mu"][i]) / s["x_sd"][i]
            y = jnp.dot(xs, s["w0"][i, :, 0],
                        preferred_element_type=f32) + s["b2"][i, 0]
        else:
            xs = (x - s["x_mu"][i]) / s["x_sd"][i]
            h = jax.nn.relu(jnp.dot(xs, s["w0"][i],
                                    preferred_element_type=f32) + s["b0"][i])
            h = jax.nn.relu(jnp.dot(h, s["w1"][i],
                                    preferred_element_type=f32) + s["b1"][i])
            y = jnp.dot(h, s["w2"][i],
                        preferred_element_type=f32)[:, 0] + s["b2"][i, 0]
        ys.append((y * s["y_sd"][i, 0] + s["y_mu"][i, 0]) / s["scale"][i, 0])
    return ys


def _tick_arrays(sA, sT, v, o, t_last, params, changed, x, t, *, circuit,
                 clock_ns, out_eps, spiking, vdd, annotate, known_out,
                 layout, skip):
    """The whole-tick dataflow on raw arrays — shared verbatim by the jnp
    body and the Pallas kernel, so the two launchers cannot drift.

    ``skip=True`` (jnp body only) wraps the idle stage in a
    ``lax.cond(any(stale))``: the skip branch returns zeros, which is
    EXACT because ``_finish_tick`` only consumes ``e_s_idle``/``v_hat``
    where ``stale`` — the main steady-state win over the 3-dispatch path,
    which always pays the idle evaluation. Kernel bodies run ``skip=False``
    (no conds inside a kernel); the results are identical either way.

    Returns ``(v', o', t_last', e, l)``."""
    circ = get_circuit(circuit)
    n = v.shape[0]
    f32 = jnp.float32
    f_a = sA["w0"].shape[1]
    f_t = sT["w0"].shape[1]
    ia, it = layout.a_off, layout.t_off

    # --- idle stage (Algorithm 1 lines 3-9): one merged catch-up event
    stale = changed & (t_last < t - clock_ns)
    tau_idle = jnp.maximum(t - t_last - clock_ns, 0.0)
    n_idle_heads = 1 if annotate else 2      # annotation never catches up v

    def idle_eval(_):
        fi = _features(jnp.zeros_like(x), v, tau_idle, params)
        ai = _pad_cols(augment_features(circ, fi), f_a)
        ys = _eval_stack(sA, ai, ia, layout.a_fams[:n_idle_heads])
        if annotate:
            return ys[0], jnp.zeros((n,), f32)
        return ys[0], ys[1]                  # e_s_idle, v_hat

    if skip:
        e_s_idle, v_hat = jax.lax.cond(
            jnp.any(stale), idle_eval,
            lambda _: (jnp.zeros((n,), f32), jnp.zeros((n,), f32)), None)
    else:
        e_s_idle, v_hat = idle_eval(None)

    # --- active stage (lines 10-22) on the caught-up state
    v_cur = v if annotate else jnp.where(stale, v_hat, v)
    tau_act = jnp.full((n,), clock_ns, f32)
    feats = _features(x, v_cur, tau_act, params)
    aug_act = augment_features(circ, feats)
    aa = _pad_cols(aug_act, f_a)
    if annotate:
        (e_s,) = _eval_stack(sA, aa, ia, layout.a_fams[:1])
        o_hat = known_out
        v_new = v_cur                        # caller substitutes behavioral v
    else:
        e_s, v_new, o_hat = _eval_stack(sA, aa, ia, layout.a_fams)

    # --- transition stage (lines 23-29): splice the resolved output in
    out_changed, o_resolved = _resolve_output(
        o_hat, o, out_eps=out_eps, spiking=spiking, vdd=vdd)

    def tr_eval(_):
        aug_tr = _splice_transition(aug_act, feats.shape[1], o, o_resolved)
        at = _pad_cols(aug_tr, f_t)
        return tuple(_eval_stack(sT, at, it, layout.t_fams))

    if skip:
        # ``_finish_tick`` consumes ``e_d``/``lat`` only where
        # ``changed & out_changed``, so a tick on which no event resolves
        # skips the whole transition stack — exact, same argument as the
        # idle skip above
        e_d, lat = jax.lax.cond(
            jnp.any(changed & out_changed), tr_eval,
            lambda _: (jnp.zeros((n,), f32), jnp.zeros((n,), f32)), None)
    else:
        e_d, lat = tr_eval(None)

    state = LasanaState(v=v, o=o, t_last=t_last, params=params)
    new_state, e, l, _ = _finish_tick(
        state, changed, stale, e_s_idle, e_d, e_s, lat, out_changed,
        o_hat, v_cur, v_new, t, spiking=spiking, vdd=vdd)
    return new_state.v, new_state.o, new_state.t_last, e, l


def megakernel_step(pack, circuit, state, changed, x, t, clock_ns, *,
                    out_eps: float = 0.02, spiking: bool = False,
                    known_out=None, vdd: float = 1.5, layout: PackLayout,
                    pallas: bool | None = None):
    """One whole LASANA tick through the megakernel path.

    Drop-in for ``wrapper.lasana_step`` given a pre-built head pack;
    returns ``(new_state, e, l, o)``. ``pallas=None`` resolves the
    launcher via :func:`ops.tick_pallas_enabled`; the jnp body
    additionally wraps the whole tick in ``lax.cond(any(changed))`` —
    exact, because every record and state write-back is masked by
    ``changed`` in ``_finish_tick``."""
    ops.record_dispatch("megakernel_step")
    if pallas is None:
        pallas = ops.tick_pallas_enabled()
    annotate = known_out is not None
    if pallas:
        known = known_out if annotate else jnp.zeros_like(state.v)
        v, o, tl, e, l = network_tick(
            pack, state.v, state.o, state.t_last, state.params, changed,
            x, t, known, circuit=circuit, clock_ns=clock_ns, layout=layout,
            out_eps=out_eps, spiking=spiking, vdd=vdd, annotate=annotate)
        new_state = LasanaState(v=v, o=o, t_last=tl, params=state.params)
        return new_state, e, l, new_state.o

    def run(_):
        return _tick_arrays(
            pack["a"], pack["t"], state.v, state.o, state.t_last,
            state.params, changed, x, t, circuit=circuit,
            clock_ns=clock_ns, out_eps=out_eps, spiking=spiking, vdd=vdd,
            annotate=annotate, known_out=known_out, layout=layout,
            skip=True)

    def idle(_):
        z = jnp.zeros_like(state.v)
        return state.v, state.o, state.t_last, z, z

    v, o, tl, e, l = jax.lax.cond(jnp.any(changed), run, idle, None)
    new_state = LasanaState(v=v, o=o, t_last=tl, params=state.params)
    return new_state, e, l, new_state.o


def megakernel_chunk(pack, circuit, state, changed_seq, x_seq, t_seq,
                     clock_ns, *, out_eps: float = 0.02,
                     spiking: bool = True, vdd: float = 1.5,
                     layout: PackLayout, pallas: bool | None = None):
    """A whole chunk of ticks; the time-looped ambitious end state.

    jnp body: a ``lax.scan`` of :func:`megakernel_step` (bit-identical to
    ticking one step at a time, so streaming chunk boundaries cannot
    change results). Pallas: ONE ``network_tick_chunk`` launch whose
    in-kernel time loop carries v/o/t_last in VMEM across the chunk.
    Returns ``(new_state, o_seq, e_seq, l_seq)`` with (T, N) sequences."""
    if pallas is None:
        pallas = ops.tick_pallas_enabled()
    if pallas:
        v, o, tl, o_seq, e_seq, l_seq = network_tick_chunk(
            pack, state.v, state.o, state.t_last, state.params,
            changed_seq, x_seq, t_seq, circuit=circuit, clock_ns=clock_ns,
            layout=layout, out_eps=out_eps, spiking=spiking, vdd=vdd)
        new_state = LasanaState(v=v, o=o, t_last=tl, params=state.params)
        return new_state, o_seq, e_seq, l_seq

    def tick(st, xs):
        ch, xi, t = xs
        ns, e, l, o = megakernel_step(
            pack, circuit, st, ch, xi, t, clock_ns, out_eps=out_eps,
            spiking=spiking, vdd=vdd, layout=layout, pallas=False)
        return ns, (o, e, l)

    new_state, (o_seq, e_seq, l_seq) = jax.lax.scan(
        tick, state, (changed_seq, x_seq, t_seq))
    return new_state, o_seq, e_seq, l_seq


# ---------------------------------------------------------------------------
# Pallas launchers


def _resident(arr):
    """BlockSpec pinning a whole array into every grid step (VMEM-resident
    weights/standardizers, exactly like mlp_surrogate's head stacks)."""
    nd = arr.ndim
    return pl.BlockSpec(arr.shape, lambda i, _nd=nd: (0,) * _nd)


def _stack_refs(refs, base):
    return {k: refs[base + j][...] for j, k in enumerate(_STACK_KEYS)}


_N_STACK = len(_STACK_KEYS)


def _make_tick_kernel(circuit, clock_ns, out_eps, spiking, vdd, annotate,
                      layout):
    """Kernel body: both head stacks resident, one N-block of circuits per
    grid step, all three stages chained in registers/VMEM scratch."""

    def kernel(*refs):
        sA = _stack_refs(refs, 0)
        sT = _stack_refs(refs, _N_STACK)
        i = 2 * _N_STACK
        v, o, t_last = refs[i][...], refs[i + 1][...], refs[i + 2][...]
        params = refs[i + 3][...]
        changed = refs[i + 4][...] > 0.5
        x = refs[i + 5][...]
        t = refs[i + 6][0]
        known = refs[i + 7][...] if annotate else None
        v_ref, o_ref, tl_ref, e_ref, l_ref = refs[i + 8:i + 13]
        v1, o1, tl1, e1, l1 = _tick_arrays(
            sA, sT, v, o, t_last, params, changed, x, t, circuit=circuit,
            clock_ns=clock_ns, out_eps=out_eps, spiking=spiking, vdd=vdd,
            annotate=annotate, known_out=known, layout=layout, skip=False)
        v_ref[...] = v1
        o_ref[...] = o1
        tl_ref[...] = tl1
        e_ref[...] = e1
        l_ref[...] = l1

    return kernel


def _padded_pack(pack):
    """Pad stack dims to lane multiples for the hardware path (exact —
    zero weights, ones x_sd; the N-padding counterpart lives in the
    callers)."""
    f_a = ops._ceil_to(pack["a"]["w0"].shape[1], 128)
    f_t = ops._ceil_to(pack["t"]["w0"].shape[1], 128)
    h1 = ops._ceil_to(pack["a"]["w0"].shape[2], 128)
    h2 = ops._ceil_to(pack["a"]["w1"].shape[2], 128)
    return {"a": _pad_stack(pack["a"], f_a, h1, h2),
            "t": _pad_stack(pack["t"], f_t, h1, h2)}


_TICK_STATICS = ("circuit", "clock_ns", "layout", "out_eps", "spiking",
                 "vdd", "annotate", "block_n", "interpret")


@functools.partial(jax.jit, static_argnames=_TICK_STATICS)
def network_tick(pack, v, o, t_last, params, changed, x, t, known, *,
                 circuit, clock_ns, layout: PackLayout,
                 out_eps: float = 0.02, spiking: bool = False,
                 vdd: float = 1.5, annotate: bool = False,
                 block_n: int = 256, interpret: bool | None = None):
    """One whole LASANA tick as ONE ``pallas_call``.

    Ragged shapes are handled HERE (the raw kernel is shape-strict): N
    pads to ``block_n`` with ``changed=False`` rows (every write-back is
    masked by ``changed``, so pad rows are inert) and the pack's F/H dims
    pad to 128 — padded feature columns get ``x_sd = 1`` (the zero pad
    would divide by zero; see the named regression tests) and zero
    weights. Returns ``(v', o', t_last', e, l)``, each ``(N,)``."""
    interpret = ops._interpret_default() if interpret is None else interpret
    n = v.shape[0]
    n_pad = ops._ceil_to(n, block_n)
    pp = _padded_pack(pack)
    f32 = jnp.float32
    inputs = (
        *[pp["a"][k] for k in _STACK_KEYS],
        *[pp["t"][k] for k in _STACK_KEYS],
        ops._pad_to(v, n_pad, 0),
        ops._pad_to(o, n_pad, 0),
        ops._pad_to(t_last, n_pad, 0),
        ops._pad_to(params, n_pad, 0),
        ops._pad_to(changed.astype(f32), n_pad, 0),
        ops._pad_to(x, n_pad, 0),
        jnp.reshape(jnp.asarray(t, f32), (1,)),
        ops._pad_to(known, n_pad, 0),
    )
    n_blk = pl.BlockSpec((block_n,), lambda i: (i,))
    in_specs = [
        *[_resident(a) for a in inputs[:2 * _N_STACK]],
        n_blk, n_blk, n_blk,
        pl.BlockSpec((block_n, params.shape[1]), lambda i: (i, 0)),
        n_blk,
        pl.BlockSpec((block_n, x.shape[1]), lambda i: (i, 0)),
        pl.BlockSpec((1,), lambda i: (0,)),
        n_blk,
    ]
    kernel = _make_tick_kernel(circuit, clock_ns, out_eps, spiking, vdd,
                               annotate, layout)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // block_n,),
        in_specs=in_specs,
        out_specs=[n_blk] * 5,
        out_shape=[jax.ShapeDtypeStruct((n_pad,), f32)] * 5,
        interpret=interpret,
    )(*inputs)
    return tuple(a[:n] for a in out)


def _make_chunk_kernel(circuit, clock_ns, out_eps, spiking, vdd, layout):
    """Time-looped kernel body: circuit state (v, o, t_last) lives in
    VMEM across the whole chunk; per-tick inputs are sliced and per-tick
    outputs stored inside the ``fori_loop`` (lif_scan's structure, one
    level up the stack)."""

    def kernel(*refs):
        sA = _stack_refs(refs, 0)
        sT = _stack_refs(refs, _N_STACK)
        i = 2 * _N_STACK
        v0, o0, tl0 = refs[i][...], refs[i + 1][...], refs[i + 2][...]
        params = refs[i + 3][...]
        ch_ref, x_ref, t_ref = refs[i + 4], refs[i + 5], refs[i + 6]
        v_ref, o_ref, tl_ref = refs[i + 7], refs[i + 8], refs[i + 9]
        os_ref, es_ref, ls_ref = refs[i + 10], refs[i + 11], refs[i + 12]
        t_steps = ch_ref.shape[0]
        row = (slice(None),)

        def body(ti, carry):
            v, o, tl = carry
            ch = pl.load(ch_ref, (pl.dslice(ti, 1), *row))[0] > 0.5
            xx = pl.load(x_ref, (pl.dslice(ti, 1), *row, slice(None)))[0]
            t = pl.load(t_ref, (pl.dslice(ti, 1), *row))[0, 0]
            v1, o1, tl1, e1, l1 = _tick_arrays(
                sA, sT, v, o, tl, params, ch, xx, t, circuit=circuit,
                clock_ns=clock_ns, out_eps=out_eps, spiking=spiking,
                vdd=vdd, annotate=False, known_out=None, layout=layout,
                skip=False)
            pl.store(os_ref, (pl.dslice(ti, 1), *row), o1[None])
            pl.store(es_ref, (pl.dslice(ti, 1), *row), e1[None])
            pl.store(ls_ref, (pl.dslice(ti, 1), *row), l1[None])
            return v1, o1, tl1

        v, o, tl = jax.lax.fori_loop(0, t_steps, body, (v0, o0, tl0))
        v_ref[...] = v
        o_ref[...] = o
        tl_ref[...] = tl

    return kernel


@functools.partial(jax.jit, static_argnames=tuple(
    s for s in _TICK_STATICS if s != "annotate"))
def network_tick_chunk(pack, v, o, t_last, params, changed_seq, x_seq,
                       t_seq, *, circuit, clock_ns, layout: PackLayout,
                       out_eps: float = 0.02, spiking: bool = True,
                       vdd: float = 1.5, block_n: int = 256,
                       interpret: bool | None = None):
    """A whole chunk of LASANA ticks as ONE time-looped ``pallas_call``.

    ``changed_seq`` (T, N) bool, ``x_seq`` (T, N, n_in), ``t_seq`` (T,)
    tick times. Circuit state never leaves VMEM between ticks; only the
    per-tick record sequences stream out. Returns
    ``(v', o', t_last', o_seq, e_seq, l_seq)``."""
    interpret = ops._interpret_default() if interpret is None else interpret
    n = v.shape[0]
    t_steps = changed_seq.shape[0]
    n_pad = ops._ceil_to(n, block_n)
    pp = _padded_pack(pack)
    f32 = jnp.float32
    inputs = (
        *[pp["a"][k] for k in _STACK_KEYS],
        *[pp["t"][k] for k in _STACK_KEYS],
        ops._pad_to(v, n_pad, 0),
        ops._pad_to(o, n_pad, 0),
        ops._pad_to(t_last, n_pad, 0),
        ops._pad_to(params, n_pad, 0),
        ops._pad_to(changed_seq.astype(f32), n_pad, 1),
        ops._pad_to(x_seq, n_pad, 1),
        jnp.reshape(jnp.asarray(t_seq, f32), (t_steps, 1)),
    )
    n_blk = pl.BlockSpec((block_n,), lambda i: (i,))
    seq_blk = pl.BlockSpec((t_steps, block_n), lambda i: (0, i))
    in_specs = [
        *[_resident(a) for a in inputs[:2 * _N_STACK]],
        n_blk, n_blk, n_blk,
        pl.BlockSpec((block_n, params.shape[1]), lambda i: (i, 0)),
        seq_blk,
        pl.BlockSpec((t_steps, block_n, x_seq.shape[2]),
                     lambda i: (0, i, 0)),
        pl.BlockSpec((t_steps, 1), lambda i: (0, 0)),
    ]
    kernel = _make_chunk_kernel(circuit, clock_ns, out_eps, spiking, vdd,
                                layout)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // block_n,),
        in_specs=in_specs,
        out_specs=[n_blk] * 3 + [seq_blk] * 3,
        out_shape=[jax.ShapeDtypeStruct((n_pad,), f32)] * 3
        + [jax.ShapeDtypeStruct((t_steps, n_pad), f32)] * 3,
        interpret=interpret,
    )(*inputs)
    v1, o1, tl1, o_seq, e_seq, l_seq = out
    return (v1[:n], o1[:n], tl1[:n],
            o_seq[:, :n], e_seq[:, :n], l_seq[:, :n])
