"""Golden LIF transient-integrator kernel — the "SPICE farm" hot loop.

Grid over circuit blocks; each invocation integrates one full digital clock
period (n_substeps exponential-Euler steps) for a block of neurons with the
(bn, 3) state, (bn, 3) stimulus and (bn, 4) knob tensors VMEM-resident.
The sub-step loop is a ``fori_loop`` over registers — zero HBM traffic
between sub-steps, vs. the pure-XLA scan that round-trips the carry.

Dataset generation maps this kernel over (runs x timesteps); it must match
``repro.core.circuits.LIFNeuron.step`` bit-for-bit in fp32 (tests sweep
shapes against that oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.circuits import LIFNeuron


def _period_math(circ: LIFNeuron, st, xx, pp):
    """Integrate ONE clock period for a block — the shared in-register
    body of both the single-period kernel and the time-looped chunk
    kernel (so the two can never drift numerically). Returns
    ``(new_state (bn, 3), out, energy, latency, spiked)``."""
    dt = circ.clock_ns / circ.n_substeps
    v0, adap0, ref0 = st[:, 0], st[:, 1], st[:, 2]
    w, x, n_spk = xx[:, 0], xx[:, 1], xx[:, 2]
    v_leak, v_th_knob, v_adap, v_ref = pp[:, 0], pp[:, 1], pp[:, 2], pp[:, 3]

    i_in = circ.g_syn * w * x * n_spk / 5.0
    leak_rate = (circ.i_leak0 / circ.c_mem) * jnp.exp(
        (v_leak - 0.5) / circ.ut) * 1e-9
    tau_ref_ns = 2.0 + 10.0 * (v_ref - 0.5)
    thresh = 0.8 + 1.0 * (v_th_knob - 0.5)
    adap_gain = 0.15 * (1.0 + 2.0 * (v_adap - 0.5))
    dv = (i_in / circ.c_mem) * 1e-9 * dt
    decay = jnp.exp(-leak_rate * dt)
    p_static_base = circ.g_static

    def sub(i, carry):
        v, adap, ref, out, energy, t_spk = carry
        in_ref = ref > 0.0
        v_new = jnp.where(in_ref, 0.0, (v + dv) * decay)
        v_new = jnp.clip(v_new, 0.0, circ.vdd)
        eff_th = thresh + adap * 1.0
        fire = (v_new >= eff_th) & (~in_ref)
        v_new = jnp.where(fire, 0.0, v_new)
        ref_new = jnp.where(fire, tau_ref_ns, jnp.maximum(ref - dt, 0.0))
        adap_new = adap * jnp.exp(-dt / 8.0) + jnp.where(fire, adap_gain, 0.0)
        out_new = jnp.where(fire, circ.vdd, out)
        t_now = (i + 1).astype(jnp.float32) * dt
        t_spk = jnp.where(fire & (t_spk < 0), t_now, t_spk)
        p_static = p_static_base * jnp.square(v_leak + v_new * 0.3)
        e_sub = p_static * dt * 1e-9
        e_sub = e_sub + jnp.abs(i_in) * jnp.abs(v_new) * dt * 1e-9 * 0.5
        e_spk = jnp.where(fire, circ.c_spike * circ.vdd ** 2, 0.0)
        return (v_new, adap_new, ref_new, out_new, energy + e_sub + e_spk,
                t_spk)

    zeros = jnp.zeros_like(v0)
    init = (v0, adap0, ref0, zeros, zeros, -jnp.ones_like(v0))
    v_end, adap_end, ref_end, out, energy, t_spk = jax.lax.fori_loop(
        0, circ.n_substeps, sub, init)
    spiked = t_spk > 0
    new_state = jnp.stack([v_end, adap_end, ref_end], axis=-1)
    latency = jnp.where(spiked, t_spk, circ.clock_ns)
    return new_state, out, energy, latency, spiked


def _make_kernel(circ: LIFNeuron):
    def kernel(state_ref, x_ref, p_ref, new_state_ref, out_ref, energy_ref,
               latency_ref, spiked_ref):
        st = state_ref[...].astype(jnp.float32)
        xx = x_ref[...].astype(jnp.float32)
        pp = p_ref[...].astype(jnp.float32)
        new_state, out, energy, latency, spiked = _period_math(circ, st, xx, pp)
        new_state_ref[...] = new_state
        out_ref[...] = out
        energy_ref[...] = energy
        latency_ref[...] = latency
        spiked_ref[...] = spiked

    return kernel


def _make_chunk_kernel(circ: LIFNeuron):
    def kernel(state_ref, xseq_ref, p_ref, new_state_ref, out_ref, energy_ref,
               latency_ref, spiked_ref):
        pp = p_ref[...].astype(jnp.float32)
        t_steps = xseq_ref.shape[0]

        def tick(t, st):
            xx = pl.load(
                xseq_ref, (pl.dslice(t, 1), slice(None), slice(None)),
            )[0].astype(jnp.float32)
            new_state, out, energy, latency, spiked = _period_math(
                circ, st, xx, pp)
            row = (pl.dslice(t, 1), slice(None))
            pl.store(out_ref, row, out[None])
            pl.store(energy_ref, row, energy[None])
            pl.store(latency_ref, row, latency[None])
            pl.store(spiked_ref, row, spiked[None])
            return new_state

        st = state_ref[...].astype(jnp.float32)
        new_state_ref[...] = jax.lax.fori_loop(0, t_steps, tick, st)

    return kernel


def lif_chunk(state, x_seq, params, *, circ: LIFNeuron | None = None,
              block_n: int = 256, interpret: bool = True):
    """T clock periods in ONE launch: the time-looped lif_scan variant.

    state (N, 3), x_seq (T, N, 3), params (N, 4). State lives in VMEM for
    the whole chunk — the outer tick loop nests around the substep loop,
    so nothing round-trips HBM between periods. Bit-for-bit identical to
    chaining ``lif_step`` T times (both loops call ``_period_math``).
    """
    circ = circ or LIFNeuron()
    t_steps, n = x_seq.shape[0], state.shape[0]
    assert n % block_n == 0, (n, block_n)
    kernel = _make_chunk_kernel(circ)
    seq_blk = pl.BlockSpec((t_steps, block_n), lambda i: (0, i))
    new_state, out, energy, latency, spiked = pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((t_steps, block_n, 3), lambda i: (0, i, 0)),
            pl.BlockSpec((block_n, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            seq_blk, seq_blk, seq_blk, seq_blk,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((t_steps, n), jnp.float32),
            jax.ShapeDtypeStruct((t_steps, n), jnp.float32),
            jax.ShapeDtypeStruct((t_steps, n), jnp.float32),
            jax.ShapeDtypeStruct((t_steps, n), jnp.bool_),
        ],
        interpret=interpret,
    )(state, x_seq, params)
    obs = {"output": out, "energy": energy, "latency": latency,
           "spiked": spiked}
    return new_state, obs


def lif_step(state, x, params, *, circ: LIFNeuron | None = None,
             block_n: int = 256, interpret: bool = True):
    """One clock period for N neurons. state (N,3), x (N,3), params (N,4)."""
    circ = circ or LIFNeuron()
    n = state.shape[0]
    assert n % block_n == 0, (n, block_n)
    kernel = _make_kernel(circ)
    new_state, out, energy, latency, spiked = pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.bool_),
        ],
        interpret=interpret,
    )(state, x, params)
    obs = {"output": out, "energy": energy, "latency": latency,
           "spiked": spiked}
    return new_state, obs
