"""Fused 3-layer MLP surrogate inference kernel (LASANA's inference hot spot).

One ``pallas_call`` evaluates an entire predictor over a block of circuits:
the (F,H1),(H1,H2),(H2,1) weight matrices live in VMEM for the whole grid
(they are a few hundred KB), activations never round-trip HBM, and both
ReLU layers fuse into the matmul epilogues. Block sizes are MXU-aligned
(inputs padded to multiples of 128 by ops.py).

This replaces the paper's five scikit-learn ``predict`` calls + Python
batching: on TPU, one kernel launch per predictor per tick, grid over
N/block circuits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...], 0.0)
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...], 0.0)
    out = jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32) \
        + b3_ref[...]
    o_ref[...] = out


def mlp_surrogate(x, w1, b1, w2, b2, w3, b3, *, block_n: int = 256,
                  interpret: bool = True):
    """x: (N, F) -> (N, 1). All dims should be 128-aligned on real TPUs."""
    n, f = x.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)


# --- multi-head variant (the fused inference hot path) --------------------------

def _mlp_heads_kernel(x_ref, xmu_ref, xsd_ref, ymu_ref, ysd_ref,
                      w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                      o_ref):
    """All P heads evaluated on one (block_n, F) input block.

    Head count P is static, so the head loop unrolls at trace time; every
    head's weights (and per-head input/output standardizers) sit in VMEM
    for the whole grid — one feature-block load serves all P predictors,
    and both ReLU layers fuse into the matmul epilogues exactly as in the
    single-head kernel."""
    x = x_ref[...].astype(jnp.float32)
    p = w1_ref.shape[0]
    for i in range(p):
        xs = (x - xmu_ref[i]) / xsd_ref[i]
        h1 = jnp.maximum(
            jnp.dot(xs, w1_ref[i], preferred_element_type=jnp.float32)
            + b1_ref[i], 0.0)
        h2 = jnp.maximum(
            jnp.dot(h1, w2_ref[i], preferred_element_type=jnp.float32)
            + b2_ref[i], 0.0)
        out = jnp.dot(h2, w3_ref[i], preferred_element_type=jnp.float32) \
            + b3_ref[i]
        o_ref[i] = out * ysd_ref[i] + ymu_ref[i]


def mlp_surrogate_heads(x, x_mu, x_sd, y_mu, y_sd, w1, b1, w2, b2, w3, b3,
                        *, block_n: int = 256, interpret: bool = True):
    """x: (N, F) + P stacked heads -> (P, N, 1) in physical target units.

    One ``pallas_call`` evaluates every predictor head over every circuit
    block: weights are (P, ...) stacks whose BlockSpecs load the FULL
    stack (index map pinned to 0) so they stay VMEM-resident across the
    grid, which iterates over N-blocks only. Per-head feature
    standardization ((x - x_mu) / x_sd) and target de-standardization
    (y * y_sd + y_mu) happen inside the kernel, so callers hand over raw
    augmented features once for all heads.

    ``n % block_n == 0`` is required here (the raw kernel is
    shape-strict); ``ops.mlp_surrogate_heads`` pads ragged N (and the
    F/H1/H2 dims to 128) before calling in.
    """
    n, f = x.shape
    p, _, h1 = w1.shape
    h2 = w2.shape[2]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    resident = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _mlp_heads_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            resident(p, f),             # x_mu
            resident(p, f),             # x_sd
            resident(p, 1),             # y_mu
            resident(p, 1),             # y_sd
            resident(p, f, h1),         # w1
            resident(p, h1),            # b1
            resident(p, h1, h2),        # w2
            resident(p, h2),            # b2
            resident(p, h2, 1),         # w3
            resident(p, 1),             # b3
        ],
        out_specs=pl.BlockSpec((p, block_n, 1), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n, 1), jnp.float32),
        interpret=interpret,
    )(x, x_mu, x_sd, y_mu, y_sd, w1, b1, w2, b2, w3, b3)
