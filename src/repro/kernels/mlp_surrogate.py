"""Fused 3-layer MLP surrogate inference kernel (LASANA's inference hot spot).

One ``pallas_call`` evaluates an entire predictor over a block of circuits:
the (F,H1),(H1,H2),(H2,1) weight matrices live in VMEM for the whole grid
(they are a few hundred KB), activations never round-trip HBM, and both
ReLU layers fuse into the matmul epilogues. Block sizes are MXU-aligned
(inputs padded to multiples of 128 by ops.py).

This replaces the paper's five scikit-learn ``predict`` calls + Python
batching: on TPU, one kernel launch per predictor per tick, grid over
N/block circuits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...], 0.0)
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...], 0.0)
    out = jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32) \
        + b3_ref[...]
    o_ref[...] = out


def mlp_surrogate(x, w1, b1, w2, b2, w3, b3, *, block_n: int = 256,
                  interpret: bool = True):
    """x: (N, F) -> (N, 1). All dims should be 128-aligned on real TPUs."""
    n, f = x.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)
