"""Causal flash-attention forward kernel (LM-zoo fast path).

Online-softmax over KV blocks with the (bq, d) query tile, running max/sum
and (bq, d) accumulator held in VMEM/registers; logits never touch HBM.
This is the kernel that collapses the dry-run's dominant memory term (the
fp32 (S, T) logit traffic of the XLA path — see EXPERIMENTS §Perf).

Layout: q, k, v are (B*H, S, D); grid is (BH, S/bq); the inner KV loop is a
``fori_loop`` bounded by the causal frontier of each query block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _make_kernel(bq: int, bk: int, s: int, d: int, scale: float):
    def kernel(q_ref, k_ref, v_ref, o_ref):
        qi = pl.program_id(1)
        q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

        def body(j, carry):
            acc, m_run, l_run = carry
            k = k_ref[pl.dslice(j * bk, bk), :]
            v = v_ref[pl.dslice(j * bk, bk), :]
            logits = q @ k.astype(jnp.float32).T            # (bq, bk)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            logits = jnp.where(k_pos <= q_pos, logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=1))
            p = jnp.exp(logits - m_new[:, None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=1)
            acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
            return acc, m_new, l_new

        n_kv = (qi + 1) * bq // bk                          # causal frontier
        acc0 = jnp.zeros((bq, d), jnp.float32)
        m0 = jnp.full((bq,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bq,), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
        o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)

    return kernel


def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q,k,v: (BH, S, D) -> (BH, S, D), causal. S % block_q == 0 required."""
    bh, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0 and bq % bk == 0, (s, bq, bk)
    scale = 1.0 / (d ** 0.5)
    kernel = _make_kernel(bq, bk, s, d, scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
