"""Stream checkpoint/resume: chunk-boundary carry snapshots.

A :class:`StreamCheckpoint` captures everything a killed streaming run
needs to continue *bit-identically*: the per-layer device carries and
previous-output buffers at a chunk boundary, the tick offset ``k0``,
and the accumulated record of every chunk already emitted (folded to
one partial :class:`~repro.core.network.NetworkRun`). Persistence is
one versioned ``.npz`` exactly like ``Surrogate.save`` — arrays plus a
JSON ``__manifest__`` — so checkpoints survive process death and move
between hosts.

The parity contract (tested in tests/test_resilience.py): kill a stream
at any checkpoint, ``lasana.resume`` it on a fresh engine, and the
merged record equals the uninterrupted monolithic run — discrete fields
bitwise, energy within rtol 1e-5 — with ZERO extra compiles on a warm
engine. That works because checkpoints only ever sit at chunk
boundaries: the resumed chunk shapes equal the uninterrupted tail's, so
the donated-carry chunk program (and the flush program, whose ``t_ends``
ride ``k0``) are reused as-is.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional

import numpy as np

from repro.core.network import NetworkRun

CKPT_FORMAT_VERSION = 1


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def spec_key_of(spec) -> str:
    """Content hash binding a checkpoint to its NetworkSpec."""
    # the serve layer already defines the canonical spec content key;
    # imported lazily so core/resilience never need serve at import time
    from repro.serve.buckets import spec_content_key
    return spec_content_key(spec)


@dataclasses.dataclass
class StreamCheckpoint:
    """Resumable snapshot of a streaming run at a chunk boundary.

    k0            ticks consumed when the snapshot was taken
    chunk_ticks   the stream's chunk size (resume must reuse it so the
                  tail re-chunks identically)
    batch         stimulus batch width
    spec_key      content hash of the NetworkSpec (resume validates it)
    backend/mode/record_hidden  engine configuration at snapshot time
    carry_leaves  flattened per-layer carry pytree leaves (host arrays)
    prev_ys       per-layer previous-output buffers (host arrays)
    acc_run       ticks ``[0, k0)`` folded to one partial NetworkRun
                  (its ``flush_energy`` is zero — flush charges once, at
                  the true stream end, on the resumed side)
    """

    k0: int
    chunk_ticks: int
    batch: int
    spec_key: str
    backend: str
    mode: str
    record_hidden: bool
    carry_leaves: List[np.ndarray]
    prev_ys: List[np.ndarray]
    acc_run: NetworkRun

    # --- persistence ----------------------------------------------------------

    def save(self, path: str) -> str:
        """Write one versioned ``.npz`` (path may omit the extension)."""
        path = _npz_path(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        run = self.acc_run
        arrays = {f"carry/{i}": np.asarray(a)
                  for i, a in enumerate(self.carry_leaves)}
        for i, p in enumerate(self.prev_ys):
            arrays[f"prev/{i}"] = np.asarray(p)
        arrays["acc/outputs"] = np.asarray(run.outputs)
        if run.out_spikes is not None:
            arrays["acc/out_spikes"] = np.asarray(run.out_spikes)
        if run.layer_spikes is not None:
            for i, h in enumerate(run.layer_spikes):
                arrays[f"acc/hidden/{i}"] = np.asarray(h)
        arrays["acc/energy"] = np.asarray(run.energy)
        arrays["acc/latency"] = np.asarray(run.latency)
        arrays["acc/events"] = np.asarray(run.events)
        arrays["acc/flush_energy"] = np.asarray(run.flush_energy)
        arrays["acc/n_circuits"] = np.asarray(run.n_circuits)
        manifest = {
            "format_version": CKPT_FORMAT_VERSION,
            "kind": "stream_checkpoint",
            "k0": int(self.k0),
            "chunk_ticks": int(self.chunk_ticks),
            "batch": int(self.batch),
            "spec_key": self.spec_key,
            "backend": self.backend,
            "mode": self.mode,
            "record_hidden": bool(self.record_hidden),
            "n_carry_leaves": len(self.carry_leaves),
            "n_layers": len(self.prev_ys),
            "n_hidden": (len(run.layer_spikes)
                         if run.layer_spikes is not None else -1),
            "has_out_spikes": run.out_spikes is not None,
            "circuits": list(run.circuits),
            "clock_ns": float(run.clock_ns),
            "wall_seconds": float(run.wall_seconds),
            "compile_seconds": float(run.compile_seconds),
        }
        arrays["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)
        np.savez_compressed(path, **arrays)
        return path

    @classmethod
    def load(cls, path: str) -> "StreamCheckpoint":
        """Load a checkpoint saved by :meth:`save` (extension optional).

        Raises ``FileNotFoundError`` naming every path tried, and
        ``ValueError`` on a format-version mismatch or a non-checkpoint
        artifact — never a silent reinterpretation of arrays."""
        if not os.path.isfile(path):
            alt = _npz_path(path)
            if alt == path or not os.path.isfile(alt):
                tried = sorted({path, alt})
                raise FileNotFoundError(
                    "no stream checkpoint at "
                    + " or ".join(repr(p) for p in tried)
                    + " (expected an .npz written by StreamCheckpoint.save)")
            path = alt
        with np.load(path) as z:
            if "__manifest__" not in z.files:
                raise ValueError(f"{path}: not a StreamCheckpoint artifact "
                                 "(missing __manifest__)")
            meta = json.loads(bytes(z["__manifest__"].tobytes()).decode())
            if meta.get("kind") != "stream_checkpoint":
                raise ValueError(f"{path}: artifact kind "
                                 f"{meta.get('kind')!r} is not a "
                                 "stream checkpoint")
            version = meta.get("format_version")
            if version != CKPT_FORMAT_VERSION:
                raise ValueError(
                    f"{path}: checkpoint format version {version!r} is not "
                    f"supported (this build reads version "
                    f"{CKPT_FORMAT_VERSION}); re-checkpoint the stream")
            carry = [np.asarray(z[f"carry/{i}"])
                     for i in range(meta["n_carry_leaves"])]
            prev = [np.asarray(z[f"prev/{i}"])
                    for i in range(meta["n_layers"])]
            hidden = None
            if meta["n_hidden"] >= 0:
                hidden = [np.asarray(z[f"acc/hidden/{i}"])
                          for i in range(meta["n_hidden"])]
            run = NetworkRun(
                backend=meta["backend"], mode=meta["mode"],
                outputs=np.asarray(z["acc/outputs"]),
                out_spikes=(np.asarray(z["acc/out_spikes"])
                            if meta["has_out_spikes"] else None),
                layer_spikes=hidden,
                energy=np.asarray(z["acc/energy"]),
                latency=np.asarray(z["acc/latency"]),
                events=np.asarray(z["acc/events"]),
                flush_energy=np.asarray(z["acc/flush_energy"]),
                n_circuits=np.asarray(z["acc/n_circuits"]),
                clock_ns=meta["clock_ns"],
                wall_seconds=meta["wall_seconds"],
                circuits=tuple(meta["circuits"]),
                compile_seconds=meta["compile_seconds"])
        return cls(
            k0=meta["k0"], chunk_ticks=meta["chunk_ticks"],
            batch=meta["batch"], spec_key=meta["spec_key"],
            backend=meta["backend"], mode=meta["mode"],
            record_hidden=meta["record_hidden"],
            carry_leaves=carry, prev_ys=prev, acc_run=run)

    # --- validation -----------------------------------------------------------

    def verify_engine(self, engine, spec) -> None:
        """Fail loudly when a checkpoint is resumed against the wrong
        spec or a differently-configured engine (silent mismatch would
        surface as bitwise divergence much later)."""
        key = spec_key_of(spec)
        if key != self.spec_key:
            raise ValueError(
                f"checkpoint was taken on spec {self.spec_key[:12]}…, "
                f"resume target is {key[:12]}… — not the same network")
        if engine.backend != self.backend or engine.mode != self.mode:
            raise ValueError(
                f"checkpoint backend/mode {self.backend}/{self.mode} != "
                f"engine {engine.backend}/{engine.mode}")
        if bool(engine.record_hidden) != bool(self.record_hidden):
            raise ValueError(
                f"checkpoint record_hidden={self.record_hidden} != engine "
                f"record_hidden={engine.record_hidden}: the resumed tail "
                "would record different fields than the prefix")
