"""Resilience layer: deterministic fault injection + recovery machinery.

Two halves, threaded through streaming (`core/network.py`), serving
(`repro.serve`), and the `repro.lasana` facade:

- :mod:`repro.resilience.faults` — seeded :class:`FaultPlan` schedules
  driving named host-side injection sites (`REPRO_FAULT_PLAN` or
  :func:`faults.use_plan`); every failure replayable from the seed.
- :mod:`repro.resilience.checkpoint` — :class:`StreamCheckpoint`
  chunk-boundary snapshots behind ``lasana.stream(checkpoint_every=)``
  and ``lasana.resume``.

See docs/resilience.md for the end-to-end semantics.
"""

from repro.resilience.checkpoint import CKPT_FORMAT_VERSION, StreamCheckpoint
from repro.resilience.faults import (FAULT_SITES, FaultInjected, FaultPlan,
                                     SiteSchedule, active_plan, use_plan)

__all__ = [
    "CKPT_FORMAT_VERSION",
    "FAULT_SITES",
    "FaultInjected",
    "FaultPlan",
    "SiteSchedule",
    "StreamCheckpoint",
    "active_plan",
    "use_plan",
]
