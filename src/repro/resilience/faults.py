"""Deterministic fault injection: seeded site -> trigger schedules.

Every recoverable failure mode the stack defends against has a *named
injection site* — a host-side hook at the exact layer where the real
fault would surface. A :class:`FaultPlan` maps sites to trigger
schedules (explicit invocation indices and/or a seeded Bernoulli rate),
so a failure observed once is replayable exactly: same seed + same
invocation order -> same fires.

Sites (see docs/resilience.md for the code locations):

==================  ==========================================================
``artifact.load``   surrogate artifact bytes corrupt on load
                    (``serve.store.load_artifact``)
``lane.step``       a serve lane's driver step raises mid-chunk
                    (``serve.scheduler.Lane.step``)
``surrogate.nan``   NaN/Inf burst in one request's surrogate head outputs
                    (host copy of the fetched lane-step records)
``chunk.stall``     a chunk dispatch stalls for ``stall_seconds``
                    (streaming ``_stream_gen`` and ``Lane.step``)
``callback.explode``  a consumer ``on_chunk`` callback raises
                    (``serve.scheduler.RequestHandle._push``)
==================  ==========================================================

All hooks live on the HOST side of the dispatch boundary: compiled
programs are never touched, so injection can never change program cache
keys or recompile anything.

The ambient plan comes from ``REPRO_FAULT_PLAN`` (a JSON file path,
resolved through :func:`repro.kernels.ops.fault_plan_path` — ops stays
the only env reader). Tests override it in-process with
:func:`use_plan`. With no plan active every hook is a cheap no-op.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
import zlib
from typing import Optional

import numpy as np

FAULT_SITES = (
    "artifact.load",
    "lane.step",
    "surrogate.nan",
    "chunk.stall",
    "callback.explode",
)

PLAN_FORMAT_VERSION = 1


class FaultInjected(RuntimeError):
    """Raised by a firing injection site (site name + fire ordinal)."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at site {site!r} "
                         f"(fire #{ordinal})")
        self.site = site
        self.ordinal = ordinal


@dataclasses.dataclass(frozen=True)
class SiteSchedule:
    """When one site fires: explicit invocation indices and/or a rate.

    ``at``        0-based invocation indices that always fire.
    ``rate``      additionally fire each invocation with this probability
                  (seeded per-site stream; deterministic given order).
    ``max_fires`` stop firing after this many fires (None = unbounded) —
                  bounds ambient disruption when a plan rides along an
                  entire test suite.
    """

    at: tuple = ()
    rate: float = 0.0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1]: {self.rate}")
        if any(int(i) < 0 for i in self.at):
            raise ValueError(f"'at' indices must be >= 0: {self.at}")


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    ``sites`` maps site names (from :data:`FAULT_SITES`) to
    :class:`SiteSchedule`s (or plain dicts with the same keys). Each
    site owns an independent ``numpy`` Generator derived from
    ``(seed, crc32(site))``, consuming exactly one draw per invocation —
    firing is a pure function of the seed and the per-site invocation
    ordinal, never of wall clock or cross-site interleaving.

    Thread-safe: serve drivers, stream generators, and client threads
    hit sites concurrently; counters advance under one lock.
    """

    def __init__(self, seed: int = 0, sites=None, *,
                 stall_seconds: float = 0.02):
        self.seed = int(seed)
        self.stall_seconds = float(stall_seconds)
        self.sites = {}
        for name, sched in dict(sites or {}).items():
            if name not in FAULT_SITES:
                raise ValueError(f"unknown fault site {name!r}; known "
                                 f"sites: {FAULT_SITES}")
            if isinstance(sched, dict):
                sched = SiteSchedule(
                    at=tuple(int(i) for i in sched.get("at", ())),
                    rate=float(sched.get("rate", 0.0)),
                    max_fires=sched.get("max_fires"))
            self.sites[name] = sched
        self._lock = threading.Lock()
        self._rngs = {name: np.random.default_rng(
            [self.seed, zlib.crc32(name.encode())])
            for name in self.sites}
        self.calls = {name: 0 for name in FAULT_SITES}
        self.fired = {name: 0 for name in FAULT_SITES}

    def should_fire(self, site: str) -> bool:
        """Consume one invocation at ``site``; True if the fault fires."""
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}")
        sched = self.sites.get(site)
        with self._lock:
            n = self.calls[site]
            self.calls[site] += 1
            if sched is None:
                return False
            # the rate draw is consumed unconditionally so explicit 'at'
            # hits never shift the stream — schedules stay independent
            u = self._rngs[site].random() if sched.rate > 0.0 else 1.0
            fire = n in sched.at or u < sched.rate
            if fire and sched.max_fires is not None \
                    and self.fired[site] >= sched.max_fires:
                fire = False
            if fire:
                self.fired[site] += 1
            return fire

    def draw(self, site: str) -> float:
        """One extra uniform from ``site``'s stream (victim selection)."""
        with self._lock:
            rng = self._rngs.get(site)
            if rng is None:
                rng = self._rngs[site] = np.random.default_rng(
                    [self.seed, zlib.crc32(site.encode())])
            return float(rng.random())

    # --- (de)serialization ----------------------------------------------------

    def to_json(self) -> dict:
        sites = {}
        for name, s in self.sites.items():
            d = {}
            if s.at:
                d["at"] = list(s.at)
            if s.rate:
                d["rate"] = s.rate
            if s.max_fires is not None:
                d["max_fires"] = s.max_fires
            sites[name] = d
        return {"format_version": PLAN_FORMAT_VERSION, "seed": self.seed,
                "stall_seconds": self.stall_seconds, "sites": sites}

    @classmethod
    def from_json(cls, obj: dict) -> "FaultPlan":
        version = obj.get("format_version", PLAN_FORMAT_VERSION)
        if version > PLAN_FORMAT_VERSION:
            raise ValueError(f"fault plan format v{version} is newer than "
                             f"supported v{PLAN_FORMAT_VERSION}")
        return cls(seed=obj.get("seed", 0), sites=obj.get("sites"),
                   stall_seconds=obj.get("stall_seconds", 0.02))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


# --- the active plan ----------------------------------------------------------
#
# Resolution order: an in-process override (use_plan — tests, benchmarks)
# shadows the ambient env plan (REPRO_FAULT_PLAN via ops.fault_plan_path).
# The env plan is loaded once per path and kept as a live singleton so
# fire counters accumulate across an entire suite run.

_STATE_LOCK = threading.Lock()
_OVERRIDE: Optional[FaultPlan] = None
_OVERRIDE_ACTIVE = False
_ENV_PLAN: Optional[FaultPlan] = None
_ENV_PATH: Optional[str] = None


def active_plan() -> Optional[FaultPlan]:
    """The plan injection sites consult right now (or None)."""
    global _ENV_PLAN, _ENV_PATH
    with _STATE_LOCK:
        if _OVERRIDE_ACTIVE:
            return _OVERRIDE
        from repro.kernels import ops
        path = ops.fault_plan_path()
        if path != _ENV_PATH:
            _ENV_PLAN = FaultPlan.load(path) if path else None
            _ENV_PATH = path
        return _ENV_PLAN


@contextlib.contextmanager
def use_plan(plan: Optional[FaultPlan]):
    """Scope an in-process plan override (``None`` disables injection
    entirely inside the scope, shadowing any ambient env plan)."""
    global _OVERRIDE, _OVERRIDE_ACTIVE
    with _STATE_LOCK:
        prev, prev_active = _OVERRIDE, _OVERRIDE_ACTIVE
        _OVERRIDE, _OVERRIDE_ACTIVE = plan, True
    try:
        yield plan
    finally:
        with _STATE_LOCK:
            _OVERRIDE, _OVERRIDE_ACTIVE = prev, prev_active


# --- site hooks (what instrumented code calls) --------------------------------


def should_fire(site: str) -> bool:
    """Does ``site`` fire on this invocation? No-op False with no plan."""
    plan = active_plan()
    return plan is not None and plan.should_fire(site)


def check(site: str) -> None:
    """Raise :class:`FaultInjected` when ``site`` fires (exception sites:
    ``lane.step``, ``callback.explode``, ``artifact.load``)."""
    plan = active_plan()
    if plan is not None and plan.should_fire(site):
        raise FaultInjected(site, plan.fired[site])


def stall(site: str = "chunk.stall") -> float:
    """Sleep ``stall_seconds`` when ``site`` fires; returns the stall."""
    plan = active_plan()
    if plan is not None and plan.should_fire(site):
        time.sleep(plan.stall_seconds)
        return plan.stall_seconds
    return 0.0


def draw(site: str) -> float:
    """Deterministic uniform from the active plan's ``site`` stream."""
    plan = active_plan()
    return plan.draw(site) if plan is not None else 0.0
