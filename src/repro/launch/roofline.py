"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all **per device** (the
partitioned HLO module this backend emits is already per-device, verified
against hand-computed shard sizes):

    compute    = HLO_FLOPs            / PEAK_FLOPS
    memory     = HLO_bytes_accessed   / HBM_BW
    collective = bytes_on_wire        / ICI_BW

``bytes_on_wire`` comes from parsing the partitioned HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its ring-algorithm wire traffic (derived from the op's
output shape and replica-group size — see _WIRE_FACTORS).

Hardware model (TPU v5e-like, constants per the assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (single-link conservative)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# Ring-algorithm bytes each device puts on the wire, as a multiple of the
# op's per-device OUTPUT bytes (n = replica-group size):
#   all-gather:       out*(n-1)/n           (~1x output)
#   all-reduce:       2*out*(n-1)/n         (~2x: reduce-scatter + all-gather)
#   reduce-scatter:   input*(n-1)/n = out*(n-1)  (input = out*n)
#   all-to-all:       out*(n-1)/n
#   collective-permute: out
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE_LIST = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_output_bytes(line: str) -> int:
    """Sum the bytes of the op's output shape(s) (handles tuple outputs)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # output shapes appear before the op name
    opname_idx = min((rhs.find(c) for c in _COLLECTIVES if c in rhs),
                     default=-1)
    head = rhs[:opname_idx] if opname_idx > 0 else rhs
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUP_RE_LIST.search(line)
    if m:
        first = m.group(1).split("}", 1)[0].split("{")[-1]
        return max(len([t for t in first.split(",") if t.strip() != ""]), 1)
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    operand_bytes: dict       # per kind, per-device operand bytes
    wire_bytes: float         # per-device ring-traffic bytes

    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    operand = {k: 0.0 for k in _COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        kind = next((k for k in _COLLECTIVES
                     if f" {k}(" in s or f"{k}(" in s.split(" = ", 1)[1][:64]
                     or f"{k}-start(" in s), None)
        if kind is None:
            continue
        # skip the -done halves of async pairs (avoid double counting)
        if f"{kind}-done" in s:
            continue
        out_b = _line_output_bytes(s)
        if out_b <= 0:
            continue
        n = _group_size(s)
        counts[kind] += 1
        if kind == "all-gather":
            operand[kind] += out_b / n
            wire += out_b * (n - 1) / n
        elif kind == "all-reduce":
            operand[kind] += out_b
            wire += 2 * out_b * (n - 1) / n
        elif kind == "reduce-scatter":
            operand[kind] += out_b * n
            wire += out_b * (n - 1)
        elif kind == "all-to-all":
            operand[kind] += out_b
            wire += out_b * (n - 1) / n
        else:  # collective-permute
            operand[kind] += out_b
            wire += out_b
    return CollectiveStats(counts=counts, operand_bytes=operand, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops: float              # per-device
    hbm_bytes: float          # per-device
    wire_bytes: float         # per-device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_ratio: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(cost_analysis: dict, colls: CollectiveStats, *,
             model_flops_total: float, n_devices: int) -> Roofline:
    flops = float(cost_analysis.get("flops", 0.0))
    hbm = float(cost_analysis.get("bytes accessed", 0.0))
    wire = colls.wire_bytes
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = wire / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
              key=lambda kv: kv[1])[0]
    mf = model_flops_total / n_devices
    return Roofline(
        flops=flops, hbm_bytes=hbm, wire_bytes=wire,
        compute_s=t_c, memory_s=t_m, collective_s=t_n, dominant=dom,
        model_flops_per_device=mf,
        useful_ratio=(mf / flops) if flops else 0.0,
    )


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active params."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token per seq
