import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell from
ShapeDtypeStructs — no allocation — and record memory/cost/collective
analysis for the roofline.

MUST be run as its own process (the two lines above must execute before any
jax import anywhere); ``python -m repro.launch.dryrun --arch X --shape Y
[--multi-pod]`` does one cell and writes results/dryrun/<cell>.json.
``--all`` iterates every applicable cell (skipping cached JSONs).
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.configs.shapes import skip_reason
from repro.launch import hlo_cost
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.models.model import Model
from repro.optim import AdamW, AdamWConfig
from repro.sharding import train_rules
from repro.train import step as step_mod


def _opt_for(cfg) -> AdamW:
    # >=100B-param models: bf16 optimizer state (HBM ceiling; see EXPERIMENTS).
    import jax.numpy as jnp
    big = cfg.param_count() > 100e9
    return AdamW(AdamWConfig(state_dtype=jnp.bfloat16 if big else jnp.float32))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               rule_opts: dict | None = None):
    """Build the jitted step for one cell and lower it. Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.kernels import ops
    mb_override = ops.microbatches_override()
    if mb_override and shape.kind == "train":
        import dataclasses as _dc
        shape = _dc.replace(shape, num_microbatches=mb_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = train_rules(mesh, **(rule_opts or {}))
    model = Model(cfg, mesh=mesh, rules=rules)
    n_dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n_dp *= mesh.devices.shape[mesh.axis_names.index(ax)]

    with mesh:
        if shape.kind == "train":
            opt = _opt_for(cfg)
            jitted = step_mod.jit_train_step(model, opt, mesh, rules, shape,
                                             n_moe_groups=n_dp)
            state = step_mod.abstract_train_state(model, opt)
            inputs = model.input_specs(shape)
            lowered = jitted.lower(state, inputs)
        elif shape.kind == "prefill":
            jitted = step_mod.jit_prefill(model, mesh, rules, shape)
            inputs = model.input_specs(shape)
            lowered = jitted.lower(model.abstract_params(), inputs)
        else:  # decode
            jitted = step_mod.jit_decode_step(model, mesh, rules, shape)
            cache = model.cache_specs(shape.global_batch, shape.seq_len)
            tokens = model.input_specs(shape)["tokens"]
            lowered = jitted.lower(model.abstract_params(), cache, tokens)
    return lowered, {"mesh": mesh_info(mesh), "cfg": cfg, "shape": shape}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             force: bool = False, rule_opts: dict | None = None,
             tag: str = "") -> dict:
    mesh_tag = ("multipod" if multi_pod else "singlepod") + tag
    cell = f"{arch}__{shape_name}__{mesh_tag}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    rec = {"cell": cell, "arch": arch, "shape": shape_name,
           "mesh": mesh_tag, "status": "skip", "skip_reason": reason}
    if reason is not None:
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                   rule_opts=rule_opts)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # Loop-aware cost model: XLA's cost_analysis counts while bodies once,
        # so scanned layers/microbatches/chunks would be undercounted (see
        # launch/hlo_cost.py; parity-validated on loop-free programs).
        totals = hlo_cost.analyze(hlo)
        n_dev = meta["mesh"]["n_devices"]
        shape = meta["shape"]
        mf = rf.model_flops(cfg, shape)
        roof = rf.roofline(
            {"flops": totals.flops, "bytes accessed": totals.bytes},
            rf.CollectiveStats(counts=totals.collective_counts,
                               operand_bytes={}, wire_bytes=totals.wire_bytes),
            model_flops_total=mf, n_devices=n_dev)
        print(compiled.memory_analysis())     # proves it fits
        print({"flops": totals.flops, "bytes": totals.bytes,
               "wire": totals.wire_bytes})
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_devices": n_dev,
            "memory": {
                "argument_bytes_per_device": mem.argument_size_in_bytes,
                "output_bytes_per_device": mem.output_size_in_bytes,
                "temp_bytes_per_device": mem.temp_size_in_bytes,
                "alias_bytes_per_device": mem.alias_size_in_bytes,
                "peak_live_bytes_per_device": (
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
            },
            "cost": {
                "flops_per_device": totals.flops,
                "bytes_per_device": totals.bytes,
                "transcendentals_per_device": totals.transcendentals,
                "xla_flops_uncorrected": float(cost.get("flops", 0.0)),
                "xla_bytes_uncorrected": float(cost.get("bytes accessed", 0.0)),
            },
            "collectives": {
                "counts": totals.collective_counts,
                "wire_bytes_per_device": totals.wire_bytes,
            },
            "roofline": roof.as_dict(),
            "model_flops_total": mf,
        })
    except Exception as e:  # record the failure; the sweep continues
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] {cell} FAILED: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    dur = time.time() - t0
    print(f"[dryrun] {cell}: {rec['status']} in {dur:.1f}s")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--rule-opt", action="append", default=[],
                    help="sharding-rule switches for perf iterations, e.g. "
                         "kv_seq_sharding / seq_parallel_attn / qk_dim_fallback")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()
    rule_opts = {k: True for k in args.rule_opt}

    if args.all:
        for mp in (False, True):
            for arch in ARCH_IDS:
                cfg = get_config(arch)
                for shape_name in applicable_shapes(cfg):
                    run_cell(arch, shape_name, multi_pod=mp, out_dir=args.out,
                             force=args.force)
        return
    if not args.arch or not args.shape:
        ap.error("need --arch and --shape (or --all)")
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             out_dir=args.out, force=args.force, rule_opts=rule_opts,
             tag=args.tag)


if __name__ == "__main__":
    main()
