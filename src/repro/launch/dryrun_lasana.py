import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""LASANA-at-scale dry-run: lower + compile one Algorithm-1 simulation tick
for N circuits shard_mapped over the full production mesh, and derive its
roofline terms — the paper's §V-D scaling study taken to pod scale.

    PYTHONPATH=src python -m repro.launch.dryrun_lasana [--n 1048576]
                                                        [--multi-pod]
"""

import argparse
import json
import time

import jax

import repro.lasana as lasana
from repro.core.distributed import lower_distributed_step
from repro.launch import hlo_cost
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, mesh_info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2 ** 20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--families", default="mlp",
                    help="comma list of model families for the bank")
    ap.add_argument("--bank-runs", type=int, default=200)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    print(f"[lasana-dryrun] training surrogate ({args.families}) ...")
    surrogate = lasana.train("lif", lasana.TrainConfig(
        n_runs=args.bank_runs, n_steps=80,
        families=tuple(args.families.split(","))))

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_dev = mesh_info(mesh)["n_devices"]
    print(f"[lasana-dryrun] lowering one tick: {args.n:,} circuits on "
          f"{n_dev} devices ...")
    t0 = time.time()
    lowered = lower_distributed_step(surrogate, mesh, args.n, 3, 4,
                                     clock_ns=5.0, spiking=True)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    totals = hlo_cost.analyze(compiled.as_text())
    # "useful" flops: 5 predictor MLPs x (F*H1 + H1*H2 + H2) MACs per circuit
    mlp_flops = 2 * (41 * 100 + 100 * 50 + 50)
    useful = 7 * mlp_flops * args.n            # 7 predictor invocations/tick
    roof = rf.roofline(
        {"flops": totals.flops, "bytes accessed": totals.bytes},
        rf.CollectiveStats(counts=totals.collective_counts, operand_bytes={},
                           wire_bytes=totals.wire_bytes),
        model_flops_total=useful, n_devices=n_dev)
    rec = {
        "cell": f"lasana-lif-sim__n{args.n}__"
                + ("multipod" if args.multi_pod else "singlepod"),
        "status": "ok",
        "n_circuits": args.n,
        "n_devices": n_dev,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
        },
        "cost": {"flops_per_device": totals.flops,
                 "bytes_per_device": totals.bytes},
        "collectives": {"counts": totals.collective_counts,
                        "wire_bytes_per_device": totals.wire_bytes},
        "roofline": roof.as_dict(),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, rec["cell"] + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[lasana-dryrun] ok in {t_compile:.1f}s -> {path}")
    print(f"  per-device: flops {totals.flops:.3e}  bytes {totals.bytes:.3e}"
          f"  wire {totals.wire_bytes:.3e}")
    print(f"  terms: compute {roof.compute_s * 1e6:.1f}us  memory "
          f"{roof.memory_s * 1e6:.1f}us  collective "
          f"{roof.collective_s * 1e6:.3f}us  dominant={roof.dominant}")


if __name__ == "__main__":
    main()
