"""Loop-aware cost analysis over optimized (partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` over 95 layers reports 1/95th of the real FLOPs (verified in
this container). Since the dry-run programs are loop-heavy by design
(scan over layers, microbatches, attention chunks), we re-derive costs from
the HLO text with while-loop trip multiplication:

  cost(computation) = sum(op costs) + sum(called computation costs)
  cost(while)       = trips * (cost(body) + cost(cond))

Trip counts are parsed from the loop condition (compare against an s32
constant — the shape jax.lax.scan emits for both forward and transposed
backward loops).

Covered costs:
  flops  — dot (2*M*N*K incl. batch dims), convolution (approx), elementwise
           (1 flop/output element for arithmetic ops)
  bytes  — per *top-level* op: operand bytes + output bytes (post-fusion
           HLO, so this models one HBM round-trip per fused kernel)
  wire   — collective ring traffic (same model as roofline.parse_collectives)
           multiplied by enclosing trip counts

Validated against cost_analysis() on loop-free programs (parity within a few
%% — see tests/test_hlo_cost.py) and against hand-counted scans.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
    "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_CFG = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_BRANCHES = re.compile(
    r"(?:branch_computations=\{([^}]*)\}|"
    r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE_LIST = re.compile(r"replica_groups=\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((-?\d+)\)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "negate", "tanh", "rsqrt", "sqrt", "sine",
    "cosine", "logistic", "abs", "floor", "ceil", "round-nearest-afz",
    "expm1", "log-plus-one", "atan2", "cbrt", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    return [(d, tuple(int(x) for x in dims.split(",")) if dims else ())
            for d, dims in _SHAPE_RE.findall(text)]


def _shape_bytes(text: str) -> int:
    total = 0
    for d, dims in _parse_shapes(text):
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES.get(d, 4)
    return total


def _first_shape(text: str) -> Optional[tuple[str, tuple[int, ...]]]:
    shapes = _parse_shapes(text)
    return shapes[0] if shapes else None


@dataclasses.dataclass
class OpInfo:
    name: str
    op: str
    out_text: str          # type/shape portion of the line
    rest: str              # args + attributes


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict           # %name -> output shape text


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    transcendentals: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __add__(self, o):
        cc = {k: self.collective_counts[k] + o.collective_counts[k]
              for k in self.collective_counts}
        return CostTotals(self.flops + o.flops, self.bytes + o.bytes,
                          self.wire_bytes + o.wire_bytes,
                          self.transcendentals + o.transcendentals, cc)

    def scaled(self, k: float):
        return CostTotals(self.flops * k, self.bytes * k, self.wire_bytes * k,
                          self.transcendentals * k,
                          {c: v * k for c, v in self.collective_counts.items()})


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s:
                m = _COMP_HDR.match(s)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> <op>(<args>), attrs"; the op is the first bare
        # word immediately followed by "(" (shapes/dtypes never match:
        # "f32[...]{1,0}" has no word-paren, tuples "(f32..." have no word).
        om = _OPNAME.search(rhs)
        if om is None:
            continue
        op = om.group(1)
        split = om.start(1)
        out_text = rhs[:split]
        rest = rhs[split:]
        cur.ops.append(OpInfo(name=name, op=op, out_text=out_text, rest=rest))
        cur.shapes["%" + name] = out_text
    return comps


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out = _first_shape(op.out_text)
    if out is None:
        return 0.0
    _, out_dims = out
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs operand shape
    args = re.findall(r"%[\w\.\-]+", op.rest.split(")", 1)[0])
    csize = 1
    m = _CONTRACT.search(op.rest)
    if m and args:
        lhs_shape = comp.shapes.get(args[0])
        if lhs_shape:
            sh = _first_shape(lhs_shape)
            if sh:
                dims = sh[1]
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(dims):
                        csize *= dims[i]
    return 2.0 * out_elems * csize


def _group_size(rest: str) -> int:
    m = _GROUP_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUP_RE_LIST.search(rest)
    if m:
        first = m.group(1).split("}", 1)[0].split("{")[-1]
        return max(len([t for t in first.split(",") if t.strip() != ""]), 1)
    return 1


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition's s32 constants.

    jax scans lower to `compare(counter, constant(N)), direction=LT` with the
    counter starting at 0 (forward and transposed loops alike). We take the
    max positive s32 constant in the condition; if none, assume 1.
    """
    consts = []
    for op in cond.ops:
        for m in _CONST_S32.finditer(op.out_text + op.rest):
            consts.append(int(m.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self._memo: dict[str, CostTotals] = {}
        entry = None
        for name in self.comps:
            if ".clone" in name:
                continue
        # ENTRY computation: the one named like main / with most ops at top level
        # HLO text marks it with "ENTRY" which _COMP_HDR strips; recover by
        # scanning the raw text.
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
        self.entry = m.group(1) if m else next(iter(self.comps))
        # computations reached via fusion/call are *counted within* their
        # caller; track which are called so we never double count.

    def _op_cost(self, op: OpInfo, comp: Computation) -> CostTotals:
        t = CostTotals()
        o = op.op
        if o in ("parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "copy", "after-all", "partition-id"):
            return t
        if o == "dot":
            t.flops += _dot_flops(op, comp)
            t.bytes += _shape_bytes(op.out_text) + self._arg_bytes(op, comp)
            return t
        if o == "convolution":
            # approx: 2 * output elems * (kernel elems) — kernel shape is arg1
            out_b = _shape_bytes(op.out_text)
            t.flops += 2.0 * out_b  # coarse; convs are negligible here
            t.bytes += out_b + self._arg_bytes(op, comp)
            return t
        if o in ("fusion", "call", "async-start"):
            m = _CALLS.search(op.rest)
            inner_name = m.group(1) if (m and m.group(1) in self.comps) else None
            if inner_name:
                inner = self._comp_cost(inner_name)
                # fusion internals never touch HBM: take flops/wire, not bytes
                t.flops += inner.flops
                t.transcendentals += inner.transcendentals
                t.wire_bytes += inner.wire_bytes
                for k in t.collective_counts:
                    t.collective_counts[k] += inner.collective_counts[k]
            # HBM model (TPU-faithful; see module docstring):
            #  * fusions containing dynamic-update-slice alias their big
            #    operand in place -> traffic is 2x the non-aliased operands
            #    (read update, write slice), not a full-buffer round trip;
            #  * movement-only fusions (copy/transpose/convert chains) are
            #    fused into consumers on TPU -> one pass over the data.
            kindcls = self._fusion_class(inner_name)
            out_b = _shape_bytes(op.out_text)
            args = self._arg_bytes_list(op, comp)
            if kindcls == "dus" and args:
                big = max(args)
                t.bytes += 2.0 * (sum(args) - big)
            elif kindcls == "movement" and args:
                t.bytes += max(out_b, max(args))
            else:
                t.bytes += out_b + sum(args)
            return t
        if o == "while":
            m = _WHILE.search(op.rest)
            if m:
                cond_name, body_name = m.group(1), m.group(2)
                tc = _TRIP_CFG.search(op.rest)
                if tc:
                    trips = int(tc.group(1))
                else:
                    trips = (_trip_count(self.comps[cond_name])
                             if cond_name in self.comps else 1)
                inner = CostTotals()
                if body_name in self.comps:
                    inner = inner + self._comp_cost(body_name)
                if cond_name in self.comps:
                    inner = inner + self._comp_cost(cond_name)
                t = t + inner.scaled(max(trips, 1))
            return t
        if o == "conditional":
            m = _COND_BRANCHES.search(op.rest)
            if m:
                if m.group(1) is not None:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                else:
                    branches = [m.group(2), m.group(3)]
                costs = [self._comp_cost(b) for b in branches if b in self.comps]
                if costs:  # worst-case branch
                    t = t + max(costs, key=lambda c: c.flops + c.bytes)
            return t
        if any(o.startswith(c) for c in _COLLECTIVES):
            if o.endswith("-done"):
                return t
            out_b = _shape_bytes(op.out_text)
            n = _group_size(op.rest)
            kind = next(c for c in _COLLECTIVES if o.startswith(c))
            t.collective_counts[kind] += 1
            if kind == "all-gather":
                t.wire_bytes += out_b * (n - 1) / n
            elif kind == "all-reduce":
                t.wire_bytes += 2 * out_b * (n - 1) / n
            elif kind == "reduce-scatter":
                t.wire_bytes += out_b * (n - 1)
            elif kind == "all-to-all":
                t.wire_bytes += out_b * (n - 1) / n
            else:
                t.wire_bytes += out_b
            t.bytes += out_b
            return t
        if o in ("custom-call",):
            t.bytes += _shape_bytes(op.out_text) + self._arg_bytes(op, comp)
            return t
        if o == "dynamic-update-slice":
            args = self._arg_bytes_list(op, comp)
            if args:
                big = max(args)
                t.bytes += 2.0 * (sum(args) - big)
            return t
        if o in ("transpose", "reshape", "broadcast", "slice", "convert"):
            out_b = _shape_bytes(op.out_text)
            args = self._arg_bytes_list(op, comp)
            t.bytes += max(out_b, max(args) if args else 0)
            return t
        # reductions / elementwise / data movement
        out_b = _shape_bytes(op.out_text)
        if o in _ELEMENTWISE or o in ("reduce", "compare", "select", "clamp",
                                      "convert", "reduce-window"):
            elems = 0
            sh = _first_shape(op.out_text)
            if sh:
                e = 1
                for d in sh[1]:
                    e *= d
                elems = e
            if o == "reduce":
                # count input elements (the actual adds)
                elems = max(elems, self._arg_elems(op, comp))
            if o in ("exponential", "log", "tanh", "logistic", "power",
                     "sine", "cosine", "rsqrt", "sqrt", "erf"):
                t.transcendentals += elems
            t.flops += float(elems)
        t.bytes += out_b + self._arg_bytes(op, comp)
        return t

    def _arg_bytes(self, op: OpInfo, comp: Computation) -> float:
        return sum(self._arg_bytes_list(op, comp))

    def _arg_bytes_list(self, op: OpInfo, comp: Computation) -> list:
        out = []
        arglist = op.rest.split(")", 1)[0]
        for a in re.findall(r"%[\w\.\-]+", arglist):
            sh = comp.shapes.get(a)
            if sh:
                out.append(_shape_bytes(sh))
        return out

    _MOVEMENT_OPS = {"copy", "transpose", "convert", "bitcast", "broadcast",
                     "reshape", "parameter", "constant", "slice", "iota",
                     "get-tuple-element", "tuple", "concatenate", "reverse",
                     "pad"}

    def _fusion_class(self, inner_name: Optional[str]) -> str:
        """'dus' | 'movement' | 'compute' for a fused computation."""
        if inner_name is None:
            return "compute"
        if not hasattr(self, "_fusion_cls_memo"):
            self._fusion_cls_memo = {}
        if inner_name in self._fusion_cls_memo:
            return self._fusion_cls_memo[inner_name]
        comp = self.comps[inner_name]
        ops = {o.op for o in comp.ops}
        if "dynamic-update-slice" in ops:
            cls = "dus"
        elif ops <= self._MOVEMENT_OPS:
            cls = "movement"
        else:
            cls = "compute"
        self._fusion_cls_memo[inner_name] = cls
        return cls

    def _arg_elems(self, op: OpInfo, comp: Computation) -> int:
        arglist = op.rest.split(")", 1)[0]
        total = 0
        for a in re.findall(r"%[\w\.\-]+", arglist):
            sh = comp.shapes.get(a)
            if sh:
                s = _first_shape(sh)
                if s:
                    e = 1
                    for d in s[1]:
                        e *= d
                    total += e
        return total

    def _comp_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps[name]
        # memo placeholder to break cycles (shouldn't occur in HLO)
        self._memo[name] = CostTotals()
        total = CostTotals()
        for op in comp.ops:
            total = total + self._op_cost(op, comp)
        self._memo[name] = total
        return total

    def entry_cost(self) -> CostTotals:
        return self._comp_cost(self.entry)


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()
