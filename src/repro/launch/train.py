"""Production training driver.

``python -m repro.launch.train --arch starcoder2-3b --reduced --steps 50``

Wires together: config registry -> model -> mesh/rules -> jit train step ->
synthetic data pipeline (prefetched) -> AdamW -> checkpoint manager (async,
auto-resume) -> watchdog -> elastic restart on failure. The same driver runs
the reduced configs on this CPU container and the full configs on a real
pod (the only difference is the mesh the launcher finds).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.shapes import ShapeConfig
from repro.data.lm_data import Prefetcher, SyntheticCorpus, make_train_batch
from repro.ft.elastic import plan_mesh, resume_state
from repro.ft.watchdog import StepWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.optim import AdamW, AdamWConfig
from repro.train import step as step_mod


def build(args):
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    plan = plan_mesh(model_size=args.model_parallel)
    model = Model(cfg, mesh=plan.mesh, rules=plan.rules)
    opt = AdamW(AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                            total_steps=args.steps,
                            compress_grads=args.compress_grads))
    shape = ShapeConfig("cli", args.seq, args.batch, "train",
                        num_microbatches=args.microbatches)
    jitted = step_mod.jit_train_step(model, opt, plan.mesh, plan.rules, shape,
                                     n_moe_groups=plan.data_size)
    return cfg, plan, model, opt, shape, jitted


def train(args) -> dict:
    cfg, plan, model, opt, shape, jitted = build(args)
    ckpt = CheckpointManager(args.ckpt_dir, keep=args.keep)
    corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)

    abstract = step_mod.abstract_train_state(model, opt)
    start_step = 0
    resumed = resume_state(
        ckpt, abstract, plan,
        lambda mesh, rules: step_mod.train_state_shardings(model, opt, mesh,
                                                           rules))
    if resumed is not None:
        start_step, state = resumed
        print(f"[train] resumed from step {start_step} on "
              f"{plan.n_devices} devices")
    else:
        with plan.mesh:
            state = step_mod.init_train_state(model, opt,
                                              jax.random.PRNGKey(args.seed))

    def make_batch(step):
        return make_train_batch(corpus, step, global_batch=shape.global_batch,
                                seq=shape.seq_len,
                                num_microbatches=shape.num_microbatches)

    prefetch = Prefetcher(make_batch, depth=2, start_step=start_step)
    watchdog = StepWatchdog(hang_timeout=args.hang_timeout)
    losses = []
    try:
        with plan.mesh:
            for step in range(start_step, args.steps):
                _, batch = prefetch.next()
                if args.fail_at_step is not None and step == args.fail_at_step:
                    raise RuntimeError("injected failure (test)")
                watchdog.step_begin()
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])
                wd = watchdog.step_end(step)
                losses.append(loss)
                if step % args.log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} "
                          f"({wd['step_seconds']:.2f}s)")
                if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                    ckpt.save(step + 1, state, blocking=False,
                              metadata={"loss": loss, "arch": cfg.name})
    finally:
        prefetch.close()
        ckpt.wait()
    return {"losses": losses, "stragglers": watchdog.stragglers,
            "final_step": args.steps}


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--hang-timeout", type=float, default=1800.0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    return ap.parse_args(argv)


def main():
    args = parse_args()
    out = train(args)
    print(f"[train] done: final loss {out['losses'][-1]:.4f}, "
          f"{out['stragglers']} straggler events")


if __name__ == "__main__":
    main()
