"""Batched serving driver: prefill + decode loop over the zoo.

``python -m repro.launch.serve --arch starcoder2-3b --reduced --batch 4
--prompt-len 64 --gen 32``

Builds the jitted prefill and decode steps with the serving rule table
(sequence-sharded KV caches — the EXPERIMENTS §Perf Cell-3 configuration),
runs a batch of synthetic prompts to completion, and reports tokens/s plus
per-phase walltime. The same driver serves full configs on a real pod.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.shapes import ShapeConfig
from repro.data.lm_data import SyntheticCorpus
from repro.ft.elastic import plan_mesh
from repro.models.model import Model
from repro.sharding import serve_rules
from repro.train import step as step_mod


def serve(args) -> dict:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    plan = plan_mesh(model_size=args.model_parallel)
    rules = serve_rules(plan.mesh, kv_seq_sharding=args.kv_seq)
    model = Model(cfg, mesh=plan.mesh, rules=rules)
    max_seq = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_seq, args.batch, "decode")

    with plan.mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)
        prompts = jnp.asarray(corpus.batch(0, args.batch, args.prompt_len))
        batch = {"tokens": prompts}
        if cfg.encdec is not None:
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.n_frontend_tokens:
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)

        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
        decode = jax.jit(model.decode)

        t0 = time.time()
        logits, cache = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out_tokens = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    toks_per_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: batch {args.batch}, prompt {args.prompt_len}, "
          f"gen {args.gen}")
    print(f"[serve] prefill {t_prefill:.2f}s | decode {t_decode:.2f}s "
          f"({toks_per_s:.1f} tok/s incl. compile of first step)")
    print(f"[serve] sample continuation: {gen[0, :16].tolist()}")
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens_per_s": toks_per_s, "generated": gen}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--kv-seq", action="store_true",
                    help="sequence-sharded KV caches (EXPERIMENTS Cell 3)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
