"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state. The dry-run forces 512
host devices via XLA_FLAGS *before* importing jax; tests and benches see the
real single CPU device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod or 2x16x16 multi-pod production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes) -> Mesh:
    """General mesh helper used by tests/examples (auto axis types)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(*, model: int = 1) -> Mesh:
    """Best-effort mesh over whatever devices exist (CPU tests, small runs)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_info(mesh: Mesh) -> dict:
    return {
        "shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(np.prod(mesh.devices.shape)),
        "axis_names": list(mesh.axis_names),
    }
