"""Trace-time program auditor for the LASANA hot paths.

Every invariant the benchmarks enforce dynamically has a static shadow
here, checked from the *traced program* before anything compiles or runs:

  * **dispatch budgets** — ``Surrogate.predict`` / ``predict_heads`` and
    the whole-tick megakernel report each surrogate dispatch through
    ``ops.record_dispatch`` at trace time; scan bodies trace once, so the
    per-trace count is the per-tick dispatch count. Architectural
    ceilings (fused <= 3, annotation/megakernel == 1, per-call == 7) are
    hard-coded per entrypoint and cannot be regenerated away.
  * **dot/scan/pallas counts** — a recursive jaxpr walk (descending into
    ``pjit``/``scan``/``cond`` sub-jaxprs) frozen per entrypoint in
    ``tests/data/program_budgets.json`` (the ``check_api.py`` pattern:
    drift fails, ``--regen`` accepts).
  * **donation discipline** — donating programs are lowered and every
    ``donate_argnums`` leaf must surface as a ``tf.aliasing_output``
    marker; a "donated buffers were not usable" warning is a failure.
  * **dtype/callback hygiene** — no fp64/complex128 aval anywhere in the
    traced body, no host-callback/infeed primitive (worst inside a scan
    body, where it would sync every tick).
  * **cache-key completeness** — a registry of every engine/program cache
    whose key function must mention its declared discriminators and must
    never call ``id(...)`` (the class of bug behind the PR 6 mesh-cache
    and PR 8 lane-identity fixes), plus a *dynamic* sensitivity check
    that flips each knob and asserts the network program key changes.
  * **environment discipline** — ``kernels/ops.py`` is the single module
    allowed to *read* ``os.environ`` under ``src/repro``/``benchmarks``
    (writes, e.g. the dry-run launchers pinning ``XLA_FLAGS``, are fine).

Entrypoints are built from **synthetic surrogates** (zero-weight MLP
heads of the production 3-layer shape): structure — and therefore every
metric here — is exactly that of a trained artifact, with none of the
training cost or cross-platform fit variance.
"""

from __future__ import annotations

import ast
import collections
import contextlib
import dataclasses
import inspect
import json
import os
import pathlib
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

# primitives that escape to the host (a hidden sync per dispatch — fatal
# inside a tick scan, unacceptable anywhere on the hot path)
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})
WIDE_DTYPES = ("float64", "complex128")
DONATION_MARKER = "tf.aliasing_output"
DONATION_WARNING = "donated buffers were not usable"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One auditor violation: the check that fired, on what, and why."""

    check: str     # e.g. "dispatch-budget", "donation", "cache-key"
    entry: str     # entrypoint / cache / file the finding names
    message: str

    def __str__(self):
        return f"[{self.check}] {self.entry}: {self.message}"


# --- jaxpr walking ------------------------------------------------------------

@dataclasses.dataclass
class ProgramMetrics:
    """Static shape of one traced entrypoint (the frozen-budget row)."""

    dispatches: dict = dataclasses.field(default_factory=dict)
    dots: int = 0
    scans: int = 0
    pallas_calls: int = 0
    donated: int = 0                   # tf.aliasing_output markers
    callbacks: list = dataclasses.field(default_factory=list)
    wide_dtypes: list = dataclasses.field(default_factory=list)

    def budget_row(self) -> dict:
        """The JSON-stable slice frozen in program_budgets.json."""
        return {"dispatches": dict(sorted(self.dispatches.items())),
                "dots": self.dots, "scans": self.scans,
                "pallas_calls": self.pallas_calls, "donated": self.donated}


def _iter_sub_jaxprs(params):
    """Yield every (Closed)Jaxpr nested in an eqn's params (pjit bodies,
    scan bodies, cond branches, custom_* funs)."""
    stack = list(params.values())
    while stack:
        x = stack.pop()
        if hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
            yield x.jaxpr                            # ClosedJaxpr
        elif hasattr(x, "eqns"):                     # Jaxpr
            yield x
        elif isinstance(x, (tuple, list)):
            stack.extend(x)


def _check_aval(var, metrics, in_scan, seen):
    aval = getattr(var, "aval", None)
    dtype = getattr(aval, "dtype", None)
    if dtype is not None and str(dtype) in WIDE_DTYPES:
        key = (str(aval), in_scan)
        if key not in seen:
            seen.add(key)
            metrics.wide_dtypes.append(key)


def walk_jaxpr(jaxpr, metrics: ProgramMetrics, *, in_scan: bool = False,
               _seen=None) -> ProgramMetrics:
    """Accumulate dot/scan/callback/dtype metrics over ``jaxpr`` and every
    nested sub-jaxpr (the traced body of each pjit/scan/cond eqn)."""
    seen = set() if _seen is None else _seen
    for var in (*jaxpr.invars, *jaxpr.constvars, *jaxpr.outvars):
        _check_aval(var, metrics, in_scan, seen)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            metrics.dots += 1
        elif name == "scan":
            metrics.scans += 1
        elif "pallas" in name:
            metrics.pallas_calls += 1
        if name in CALLBACK_PRIMITIVES:
            metrics.callbacks.append((name, in_scan))
        for var in eqn.outvars:
            _check_aval(var, metrics, in_scan, seen)
        inner_scan = in_scan or name in ("scan", "while")
        for sub in _iter_sub_jaxprs(eqn.params):
            walk_jaxpr(sub, metrics, in_scan=inner_scan, _seen=seen)
    return metrics


# --- synthetic surrogates -----------------------------------------------------

def synthetic_surrogate(circuit_name: str, *, family: str = "mlp",
                        hidden: tuple = (8, 4)):
    """A structurally-production :class:`Surrogate` with zero weights.

    Carries all five Algorithm-1 predictors as ``family`` heads sized to
    the circuit's augmented feature widths (so the megakernel pack
    eligibility, head stacking, and program cache keys behave exactly as
    for a trained artifact) — without golden simulation or fitting, and
    with bitwise-identical *structure* on every platform. Budgets frozen
    from these surrogates are therefore deterministic."""
    from repro.core.circuits import augment_features, get_circuit
    from repro.core.surrogate import (FORMAT_VERSION, Manifest, Surrogate,
                                      _feature_names)
    circ = get_circuit(circuit_name)
    f_raw = circ.n_inputs + 2 + circ.n_params
    f_aug = int(augment_features(
        circ, jnp.zeros((1, f_raw), jnp.float32)).shape[1])
    f_tr = int(augment_features(
        circ, jnp.zeros((1, f_raw + 2), jnp.float32)).shape[1])
    h1, h2 = hidden
    predictors = ("M_ED", "M_ES", "M_L", "M_O", "M_V")
    transition = ("M_ED", "M_L")

    def head(f):
        if family == "linear":
            return {"mu": jnp.zeros((f,), jnp.float32),
                    "sd": jnp.ones((f,), jnp.float32),
                    "w": jnp.zeros((f + 1,), jnp.float32)}
        if family == "mlp":
            return {"x_mu": jnp.zeros((f,), jnp.float32),
                    "x_sd": jnp.ones((f,), jnp.float32),
                    "y_mu": jnp.zeros((1,), jnp.float32),
                    "y_sd": jnp.ones((1,), jnp.float32),
                    "w0": jnp.zeros((f, h1), jnp.float32),
                    "b0": jnp.zeros((h1,), jnp.float32),
                    "w1": jnp.zeros((h1, h2), jnp.float32),
                    "b1": jnp.zeros((h2,), jnp.float32),
                    "w2": jnp.zeros((h2, 1), jnp.float32),
                    "b2": jnp.zeros((1,), jnp.float32)}
        raise ValueError(f"unsupported synthetic family: {family!r}")

    params = {p: head(f_tr if p in transition else f_aug)
              for p in predictors}
    manifest = Manifest(
        circuit=circuit_name, format_version=FORMAT_VERSION,
        families=tuple((p, family) for p in predictors),
        scales=tuple((p, 1.0) for p in predictors),
        features=_feature_names(circuit_name))
    return Surrogate(manifest=manifest, params=params, fit_info=None)


# --- the entrypoint registry --------------------------------------------------

@dataclasses.dataclass
class TracedEntry:
    """What one registered builder hands the auditor: a traceable callable,
    example args, its declared donation, and hard dispatch ceilings."""

    fn: object
    args: tuple
    donate: tuple = ()
    max_dispatch: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AuditContext:
    """Shared fixtures every entrypoint builder draws from."""

    lif: object                        # synthetic lif Surrogate
    xbar: object                       # synthetic crossbar Surrogate
    spec: object                       # tiny 2-layer LIF NetworkSpec
    b: int = 2
    chunk: int = 3


def build_context() -> AuditContext:
    from repro.core.network import snn_spec
    w1 = np.linspace(-1.0, 1.0, 6, dtype=np.float32).reshape(2, 3)
    w2 = np.linspace(1.0, -1.0, 6, dtype=np.float32).reshape(3, 2)
    params = [np.asarray([0.58, 0.5, 0.5, 0.5], np.float32)] * 2
    return AuditContext(lif=synthetic_surrogate("lif"),
                        xbar=synthetic_surrogate("crossbar"),
                        spec=snn_spec([w1, w2], params))


def _tick_args(circuit_name: str, n: int = 4):
    from repro.core.circuits import get_circuit
    from repro.core.wrapper import init_state
    circ = get_circuit(circuit_name)
    state = init_state(n, jnp.zeros((n, circ.n_params), jnp.float32))
    changed = jnp.ones((n,), bool)
    x = jnp.zeros((n, circ.n_inputs), jnp.float32)
    t = jnp.float32(3 * circ.clock_ns)
    return circ, state, changed, x, t


@ops.register_entrypoint("tick_fused_standalone")
def _entry_tick_fused(ctx: AuditContext) -> TracedEntry:
    """Single-bank Algorithm-1 tick, fused predict_heads path (PR 5)."""
    from repro.core import wrapper
    circ, state, changed, x, t = _tick_args("lif")

    def fn(sur, state, changed, x, t):
        return wrapper.lasana_step(sur, state, changed, x, t, circ.clock_ns,
                                   spiking=True, fused=True,
                                   fused_kernel=False)
    return TracedEntry(fn=fn, args=(ctx.lif, state, changed, x, t),
                       max_dispatch={"predict_heads": 3, "predict": 0,
                                     "megakernel_step": 0})


@ops.register_entrypoint("tick_fused_annotation")
def _entry_tick_annotation(ctx: AuditContext) -> TracedEntry:
    """Annotation-mode tick: no data dependencies -> ONE stacked pass."""
    from repro.core import wrapper
    circ, state, changed, x, t = _tick_args("lif")

    def fn(sur, state, changed, x, t, known):
        return wrapper.lasana_step(sur, state, changed, x, t, circ.clock_ns,
                                   spiking=True, known_out=known,
                                   fused=True, fused_kernel=False)
    known = jnp.zeros(state.v.shape, jnp.float32)
    return TracedEntry(fn=fn, args=(ctx.lif, state, changed, x, t, known),
                       max_dispatch={"predict_heads": 1, "predict": 0})


@ops.register_entrypoint("tick_percall")
def _entry_tick_percall(ctx: AuditContext) -> TracedEntry:
    """Per-predict baseline: seven dispatches, the A/B comparison arm."""
    from repro.core import wrapper
    circ, state, changed, x, t = _tick_args("lif")

    def fn(sur, state, changed, x, t):
        return wrapper.lasana_step(sur, state, changed, x, t, circ.clock_ns,
                                   spiking=True, fused=False)
    return TracedEntry(fn=fn, args=(ctx.lif, state, changed, x, t),
                       max_dispatch={"predict": 7, "predict_heads": 0})


@ops.register_entrypoint("tick_megakernel")
def _entry_tick_megakernel(ctx: AuditContext) -> TracedEntry:
    """Whole-tick megakernel (PR 7): the entire tick is ONE dispatch."""
    from repro.core import wrapper
    circ, state, changed, x, t = _tick_args("lif")

    def fn(sur, state, changed, x, t):
        return wrapper.lasana_step(sur, state, changed, x, t, circ.clock_ns,
                                   spiking=True, fused=True,
                                   fused_kernel=True)
    return TracedEntry(fn=fn, args=(ctx.lif, state, changed, x, t),
                       max_dispatch={"megakernel_step": 1,
                                     "predict_heads": 0, "predict": 0})


@ops.register_entrypoint("tick_xbar_fused")
def _entry_tick_xbar(ctx: AuditContext) -> TracedEntry:
    """Crossbar-bank tick on the fused path (mixed-graph second kind)."""
    from repro.core import wrapper
    circ, state, changed, x, t = _tick_args("crossbar")

    def fn(sur, state, changed, x, t):
        return wrapper.lasana_step(sur, state, changed, x, t, circ.clock_ns,
                                   spiking=False, fused=True,
                                   fused_kernel=False)
    return TracedEntry(fn=fn, args=(ctx.xbar, state, changed, x, t),
                       max_dispatch={"predict_heads": 3, "predict": 0})


@ops.register_entrypoint("explore_pricing")
def _entry_explore(ctx: AuditContext) -> TracedEntry:
    """The DSE sweep's vectorized pricing pass (PR 6): two fused passes
    (act: M_O, then tr: M_ED/M_L chained on the resolved output)."""
    from repro.core.explore import DSEEngine
    eng = DSEEngine(n_samples=8)

    def fn(sur, v_dd, tile):
        return eng._tile_eval(sur, v_dd, tile)
    return TracedEntry(
        fn=fn, args=(ctx.xbar, jnp.full((4,), 1.5, jnp.float32),
                     jnp.full((4,), 32, jnp.int32)),
        max_dispatch={"predict_heads": 2, "predict": 0})


def _network_engine(ctx: AuditContext):
    from repro.core.network import NetworkEngine
    return NetworkEngine(ctx.spec, backend="lasana", record_hidden=False)


def _network_state(eng, ctx):
    banks = eng._runtime_banks(ctx.lif)
    carries = [eng._init_carry(i, ctx.b)
               for i in range(ctx.spec.n_layers)]
    prev0 = [jnp.zeros((ctx.b, l.n_out), jnp.float32)
             for l in ctx.spec.layers]
    x_seq = jnp.zeros((ctx.chunk, ctx.b, ctx.spec.layers[0].fan_in),
                      jnp.float32)
    return banks, carries, prev0, x_seq


@ops.register_entrypoint("network_mono")
def _entry_network_mono(ctx: AuditContext) -> TracedEntry:
    """The monolithic tick-scan network program (lasana.simulate)."""
    eng = _network_engine(ctx)
    banks, carries, prev0, x_seq = _network_state(eng, ctx)
    L = ctx.spec.n_layers
    # the monolithic program ends with the idle-energy flush: one
    # per-predict M_ES pass per layer on top of the fused tick scan
    return TracedEntry(fn=eng._build_sim(ctx.b, banks),
                       args=(x_seq, carries, prev0, banks),
                       max_dispatch={"predict_heads": 3 * L, "predict": L})


@ops.register_entrypoint("network_stream_chunk")
def _entry_stream_chunk(ctx: AuditContext) -> TracedEntry:
    """The donated-carry streaming chunk program (lasana.stream)."""
    eng = _network_engine(ctx)
    banks, carries, prev0, x_seq = _network_state(eng, ctx)
    L = ctx.spec.n_layers
    return TracedEntry(fn=eng._build_stream_step(ctx.b, banks),
                       args=(x_seq, jnp.float32(0.0), carries, prev0,
                             banks),
                       donate=(2, 3, 4),
                       max_dispatch={"predict_heads": 3 * L, "predict": 0})


@ops.register_entrypoint("network_stream_flush")
def _entry_stream_flush(ctx: AuditContext) -> TracedEntry:
    """End-of-stream idle-energy flush (one M_ES pass per LIF layer)."""
    eng = _network_engine(ctx)
    banks, carries, _, _ = _network_state(eng, ctx)
    L = ctx.spec.n_layers
    t_ends = jnp.zeros((L,), jnp.float32)
    return TracedEntry(fn=eng._build_flush(ctx.b, banks),
                       args=(carries, t_ends, banks),
                       max_dispatch={"predict": L, "predict_heads": 0})


@ops.register_entrypoint("serve_slot_step")
def _entry_slot_step(ctx: AuditContext) -> TracedEntry:
    """The serving layer's slot-masked chunk program (Lane.step)."""
    eng = _network_engine(ctx)
    banks, carries, prev0, x_seq = _network_state(eng, ctx)
    L = ctx.spec.n_layers
    end_ks = jnp.zeros((ctx.b,), jnp.float32)
    return TracedEntry(fn=eng._build_slot_step(ctx.b, banks),
                       args=(x_seq, jnp.float32(0.0), end_ks, carries,
                             prev0, banks),
                       donate=(3, 4, 5),
                       max_dispatch={"predict_heads": 3 * L, "predict": 0})


@ops.register_entrypoint("serve_slot_flush")
def _entry_slot_flush(ctx: AuditContext) -> TracedEntry:
    """Per-slot leave-time flush (Lane leavers' trailing idle energy)."""
    eng = _network_engine(ctx)
    banks, carries, _, _ = _network_state(eng, ctx)
    L = ctx.spec.n_layers
    t_ends = jnp.zeros((L, ctx.b), jnp.float32)
    return TracedEntry(fn=eng._build_slot_flush(ctx.b, banks),
                       args=(carries, t_ends, banks),
                       max_dispatch={"predict": L, "predict_heads": 0})


@ops.register_entrypoint("serve_slot_step_behavioral")
def _entry_slot_step_behavioral(ctx: AuditContext) -> TracedEntry:
    """Graceful-degradation slot chunk: the behavioral-backend lane the
    server falls back to after repeated surrogate faults. No surrogate
    banks — zero predict dispatches is the ceiling AND the point."""
    from repro.core.network import NetworkEngine
    eng = NetworkEngine(ctx.spec, backend="behavioral",
                        record_hidden=False)
    banks = eng._runtime_banks(None)
    carries = [eng._init_carry(i, ctx.b)
               for i in range(ctx.spec.n_layers)]
    prev0 = [jnp.zeros((ctx.b, l.n_out), jnp.float32)
             for l in ctx.spec.layers]
    x_seq = jnp.zeros((ctx.chunk, ctx.b, ctx.spec.layers[0].fan_in),
                      jnp.float32)
    end_ks = jnp.zeros((ctx.b,), jnp.float32)
    return TracedEntry(fn=eng._build_slot_step(ctx.b, banks),
                       args=(x_seq, jnp.float32(0.0), end_ks, carries,
                             prev0, banks),
                       donate=(3, 4, 5),
                       max_dispatch={"predict_heads": 0, "predict": 0})


@ops.register_entrypoint("serve_slot_join")
def _entry_slot_join(ctx: AuditContext) -> TracedEntry:
    """Masked slot (re)initialization at a chunk boundary (Lane.admit)."""
    eng = _network_engine(ctx)
    _, carries, prev0, _ = _network_state(eng, ctx)
    mask = jnp.zeros((ctx.b,), bool)
    return TracedEntry(fn=eng._build_slot_join(ctx.b),
                       args=(carries, prev0, mask, jnp.float32(0.0)),
                       donate=(0, 1),
                       max_dispatch={"predict": 0, "predict_heads": 0})


# --- auditing one entrypoint --------------------------------------------------

def audit_entry(name: str, entry: TracedEntry):
    """-> (ProgramMetrics, [Finding]) for one traced entrypoint."""
    findings = []
    with ops.dispatch_scope() as log:
        closed = jax.make_jaxpr(entry.fn)(*entry.args)
    metrics = ProgramMetrics(
        dispatches=dict(collections.Counter(log)))
    walk_jaxpr(closed.jaxpr, metrics)

    for counter, ceiling in sorted(entry.max_dispatch.items()):
        got = metrics.dispatches.get(counter, 0)
        if got > ceiling:
            findings.append(Finding(
                "dispatch-budget", name,
                f"{got} {counter} dispatches per tick traced; the "
                f"architectural ceiling is {ceiling} (a frozen-budget "
                "regen cannot lift this — the program structure "
                "regressed)"))

    for prim, in_scan in metrics.callbacks:
        where = "inside a scan body" if in_scan else "in the traced body"
        findings.append(Finding(
            "host-callback", name,
            f"host-sync primitive '{prim}' {where}: every dispatch would "
            "stall on a host round-trip"))

    for aval, in_scan in metrics.wide_dtypes:
        where = " inside a scan body" if in_scan else ""
        findings.append(Finding(
            "fp64-promotion", name,
            f"wide dtype {aval}{where}: the hot path is fp32-only "
            "(an fp64 leak doubles bandwidth and silently changes "
            "records)"))

    if entry.donate:
        expected = len(jax.tree.leaves(
            tuple(entry.args[i] for i in entry.donate)))
        lower = getattr(entry.fn, "lower", None)
        if lower is None:
            findings.append(Finding(
                "donation", name,
                f"declares donate_argnums={entry.donate} but the built "
                "program is not a jitted function — nothing is donated"))
        else:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                lowered = lower(*entry.args)
            for w in caught:
                if DONATION_WARNING in str(w.message):
                    findings.append(Finding(
                        "donation", name,
                        f"dropped donation: {w.message}"))
            metrics.donated = lowered.as_text().count(DONATION_MARKER)
            if metrics.donated != expected:
                findings.append(Finding(
                    "donation", name,
                    f"{metrics.donated} of {expected} declared donated "
                    f"leaves (donate_argnums={entry.donate}) are aliased "
                    "in the lowered program — the rest silently copy "
                    "every chunk"))
    return metrics, findings


# --- frozen budgets -----------------------------------------------------------

BUDGETS_PATH = REPO_ROOT / "tests" / "data" / "program_budgets.json"


@contextlib.contextmanager
def pinned_env():
    """Pin the knobs that select traced bodies, so budgets are
    reproducible regardless of the caller's environment (the megakernel
    entrypoint opts in explicitly via ``fused_kernel=True``)."""
    pins = {"REPRO_FUSED_KERNEL": "0", "REPRO_TICK_PALLAS": "0",
            "REPRO_PALLAS_INTERPRET": "1",
            # fault injection must never perturb traced programs or
            # their budgets ("" reads as no plan via fault_plan_path)
            "REPRO_FAULT_PLAN": ""}
    saved = {k: os.environ.get(k) for k in pins}
    os.environ.update(pins)
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def collect_budgets() -> dict:
    """Trace every registered entrypoint -> {name: budget row}."""
    with pinned_env():
        ctx = build_context()
        rows = {}
        for name, builder in sorted(ops.registered_entrypoints().items()):
            metrics, _ = audit_entry(name, builder(ctx))
            rows[name] = metrics.budget_row()
    return rows


def load_budgets(path=BUDGETS_PATH) -> dict:
    with open(path) as f:
        return json.load(f)["entries"]


def save_budgets(rows: dict, path=BUDGETS_PATH) -> None:
    payload = {
        "_comment": [
            "Frozen per-entrypoint program budgets (dispatches per tick,",
            "dot_general/scan/pallas_call counts, donated leaf count).",
            "Checked by tools/check_programs.py; regenerate an",
            "intentional change with:",
            "  PYTHONPATH=src python tools/check_programs.py --regen",
            "Architectural ceilings (fused <= 3 dispatches, megakernel",
            "== 1) are hard-coded in repro/analysis/jaxpr_audit.py and",
            "cannot be regenerated away.",
        ],
        "entries": {k: rows[k] for k in sorted(rows)},
    }
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def compare_budgets(rows: dict, frozen: dict) -> list:
    findings = []
    for name in sorted(set(rows) | set(frozen)):
        if name not in frozen:
            findings.append(Finding(
                "program-budget", name,
                "entrypoint has no frozen budget — run tools/"
                "check_programs.py --regen and review the new row"))
        elif name not in rows:
            findings.append(Finding(
                "program-budget", name,
                "frozen budget exists but the entrypoint is no longer "
                "registered — regen to drop it"))
        elif rows[name] != frozen[name]:
            findings.append(Finding(
                "program-budget", name,
                f"traced program drifted from the frozen budget: "
                f"now {rows[name]}, frozen {frozen[name]} (intentional? "
                "regen with tools/check_programs.py --regen)"))
    return findings


# --- cache-key completeness ---------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheKeySpec:
    """One registered cache: where its key is built and what the key must
    discriminate on."""

    name: str
    module: str
    qualname: str
    required: tuple


CACHE_KEY_REGISTRY = (
    CacheKeySpec(
        "engine-cache", "repro.lasana", "engine",
        required=("backend", "mode", "mesh", "record_hidden", "fused",
                  "fused_kernel")),
    CacheKeySpec(
        "network-program-cache", "repro.core.network",
        "NetworkEngine._program_key",
        required=("kind", "fused", "fused_kernel_enabled",
                  "tick_pallas_enabled", "b", "t_steps", "structure_key")),
    CacheKeySpec(
        "dse-program-cache", "repro.core.explore",
        "DSEEngine._compiled_tile_eval",
        required=("c", "n_samples", "structure_key")),
    CacheKeySpec(
        "serve-lane-table", "repro.serve.server", "SimServer._lane_for",
        required=("bucket", "sur_token", "mode", "degraded")),
)


def check_cache_key_source(src: str, required, name: str) -> list:
    """AST-check one cache-key function's source: every declared
    discriminator must appear, and ``id(...)`` must never be called —
    object identity is not value equality, and a recycled address aliases
    the cache onto the wrong entry (the PR 6 mesh bug)."""
    findings = []
    tree = ast.parse(textwrap.dedent(src))
    seen = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            seen.add(node.id)
        elif isinstance(node, ast.Attribute):
            seen.add(node.attr)
        elif isinstance(node, ast.arg):
            seen.add(node.arg)
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "id"):
            findings.append(Finding(
                "cache-key", name,
                f"id(...) used in a cache-key expression (line "
                f"{node.lineno}): identity keys alias recycled objects — "
                "key by value/structure instead"))
    for field in required:
        if field not in seen:
            findings.append(Finding(
                "cache-key", name,
                f"declared key field '{field}' does not appear in the "
                "key-building function — the cache cannot discriminate "
                "on it (stale-program aliasing)"))
    return findings


def check_cache_keys() -> list:
    import importlib
    findings = []
    for spec in CACHE_KEY_REGISTRY:
        obj = importlib.import_module(spec.module)
        for part in spec.qualname.split("."):
            obj = getattr(obj, part)
        src = inspect.getsource(obj)
        findings.extend(check_cache_key_source(src, spec.required,
                                               f"{spec.module}."
                                               f"{spec.qualname}"))
    return findings


def check_program_key_sensitivity(ctx: AuditContext) -> list:
    """Dynamic completeness check on the network program cache: flip each
    knob that selects a different traced body and assert the key moves.
    This is the static registry's runtime shadow — an AST check can see a
    name, only this proves the key actually discriminates."""
    from repro.core.network import NetworkEngine
    findings = []
    banks = _network_engine(ctx)._runtime_banks(ctx.lif)
    small = _network_engine(ctx)._runtime_banks(
        synthetic_surrogate("lif", hidden=(6, 3)))

    def key(*, fused=True, fused_kernel=False, b=2, t_steps=3,
            kind="stream", banks=banks, env=None):
        saved = {}
        for k, v in (env or {}).items():
            saved[k] = os.environ.get(k)
            os.environ[k] = v
        try:
            eng = NetworkEngine(ctx.spec, backend="lasana", fused=fused,
                                fused_kernel=fused_kernel,
                                record_hidden=False)
            return eng._program_key(kind, b, t_steps, banks)
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v

    base = key()
    knobs = {
        "fused": key(fused=False),
        "fused_kernel": key(fused_kernel=True),
        "tick_pallas": key(env={"REPRO_TICK_PALLAS": "1"}),
        "batch": key(b=4),
        "t_steps": key(t_steps=5),
        "kind": key(kind="slot"),
        "surrogate-structure": key(banks=small),
    }
    for knob, other in knobs.items():
        if other == base:
            findings.append(Finding(
                "cache-key", "NetworkEngine._program_key",
                f"flipping '{knob}' does not change the program cache "
                "key — the stale compiled program would be silently "
                "reused"))
    return findings


# --- environment-read discipline ----------------------------------------------

ENV_READ_ALLOWLIST = (
    "src/repro/kernels/ops.py",
    # the auditor itself: pins/restores knobs around tracing and flips
    # them for the cache-key sensitivity check — not configuration reads
    "src/repro/analysis/jaxpr_audit.py",
)


def _env_read_violations(tree: ast.AST, rel: str) -> list:
    """Flag os.environ/os.getenv READS (writes — e.g. the dry-run
    launchers pinning XLA_FLAGS — are allowed anywhere)."""
    findings = []

    def is_environ(node):
        return (isinstance(node, ast.Attribute) and node.attr == "environ"
                and isinstance(node.value, ast.Name)
                and node.value.id == "os")

    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "getenv"
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "os"):
                hit = "os.getenv(...)"
            elif (isinstance(f, ast.Attribute) and f.attr == "get"
                    and is_environ(f.value)):
                hit = "os.environ.get(...)"
        elif (isinstance(node, ast.Subscript) and is_environ(node.value)
                and isinstance(node.ctx, ast.Load)):
            hit = "os.environ[...]"
        if hit:
            findings.append(Finding(
                "env-discipline", rel,
                f"{hit} at line {node.lineno}: configuration reads go "
                "through a kernels/ops.py accessor (the auditor's single "
                "choke point)"))
    return findings


def check_env_discipline(root=REPO_ROOT) -> list:
    root = pathlib.Path(root)
    findings = []
    scan_dirs = [root / "src" / "repro", root / "benchmarks"]
    for base in scan_dirs:
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in ENV_READ_ALLOWLIST:
                continue
            tree = ast.parse(path.read_text())
            findings.extend(_env_read_violations(tree, rel))
    return findings


# --- the whole audit ----------------------------------------------------------

def run_audit(budgets: dict | None = None) -> list:
    """Run every pass; returns the (possibly empty) list of findings.

    ``budgets``: frozen rows to diff traced programs against (pass
    ``load_budgets()``; None skips the frozen comparison — ceilings,
    donation, dtype/callback, cache-key, and env checks still run)."""
    findings = []
    with pinned_env():
        ctx = build_context()
        rows = {}
        for name, builder in sorted(ops.registered_entrypoints().items()):
            metrics, entry_findings = audit_entry(name, builder(ctx))
            rows[name] = metrics.budget_row()
            findings.extend(entry_findings)
        if budgets is not None:
            findings.extend(compare_budgets(rows, budgets))
        findings.extend(check_program_key_sensitivity(ctx))
    findings.extend(check_cache_keys())
    findings.extend(check_env_discipline())
    return findings
