"""AST concurrency lint for the threaded serve subsystem.

The serve layer (PR 8) has exactly one interesting concurrency contract:
request threads enqueue under ``SimServer._lock`` while a single driver
thread owns all JAX state, and nothing slow or user-visible may ever run
while the lock is held. That contract lives in per-class
locking-discipline tables (:data:`LINT_TABLE`): every ``self.<field>`` of
an annotated class is declared *locked* (touch only under ``with
self._lock``), *driver* (driver-thread methods only), *driver_write*
(driver writes, racy reads tolerated for observability), *init*
(immutable after ``__init__``), *control* (lifecycle methods only), or
*safe* (internally synchronized, e.g. ``ServerMetrics``).

The lint walks each annotated class method-by-method, tracking lock
depth through ``with self._lock:`` / ``with self._wake:`` (a Condition
wraps the same lock), and flags:

  * guarded-state access outside the lock (or any *unannotated* field —
    the table must stay complete, so a new field without a category is
    itself an error);
  * blocking work under the lock — compiles/lowers, device syncs,
    ``time.sleep``/``join``/``result``, lane construction — which would
    stall every request thread on one admission;
  * user-callback invocation under the lock (``RequestHandle._push``
    fires ``on_chunk``; user code re-entering ``submit`` would deadlock);
  * cross-object violations: writing another object's driver-only field,
    or calling another annotated class's driver-thread method, from a
    method not itself annotated as driver-side.

Known blind spots (documented, deliberate — this is a lint, not an
escape analysis): aliasing guarded state into a local and mutating the
alias, and ``driver_write`` mutations spelled as method calls
(``lane.active.append(...)`` parses as a Load).
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib

from repro.analysis.jaxpr_audit import Finding, REPO_ROOT

# Calls that stall the calling thread: XLA compiles/lowers, device syncs,
# host transfers, sleeps/joins, program-set construction, and the user
# chunk callback. None may run while holding a server/store lock.
BLOCKING_CALLS = frozenset({
    "compile", "lower", "block_until_ready", "device_get",
    "slot_programs", "sleep", "join", "result", "_push", "wait",
    "load_artifact", "stall",
})
# Constructing a Lane compiles its engine programs — same ban.
BLOCKING_CONSTRUCTORS = frozenset({"Lane"})


@dataclasses.dataclass(frozen=True)
class ClassDiscipline:
    """The locking table for one class: which lock guards it, and the
    category of every ``self.<field>`` it owns."""

    lock: str = "_lock"
    # context managers that imply the lock (a Condition wrapping it)
    lock_aliases: frozenset = frozenset()
    locked: frozenset = frozenset()        # only under the lock
    driver: frozenset = frozenset()        # driver methods only (strict)
    driver_write: frozenset = frozenset()  # driver stores; racy loads ok
    init: frozenset = frozenset()          # stores in __init__ only
    control: frozenset = frozenset()       # lifecycle methods only
    safe: frozenset = frozenset()          # internally synchronized
    driver_methods: frozenset = frozenset()
    control_methods: frozenset = frozenset()
    # methods whose contract is "caller already holds the lock"
    lock_held_methods: frozenset = frozenset()

    def all_fields(self):
        return (self.locked | self.driver | self.driver_write | self.init
                | self.control | self.safe | {self.lock}
                | self.lock_aliases)


LINT_TABLE = {
    "src/repro/serve/server.py": {
        "SimServer": ClassDiscipline(
            lock="_lock",
            lock_aliases=frozenset({"_wake"}),
            locked=frozenset({"_queues", "_specs", "_spec_names",
                              "_lanes", "_in_flight", "_next_id",
                              "_fault_counts", "_degraded", "_hung"}),
            init=frozenset({"config", "policy", "store", "metrics",
                            "_watchdog"}),
            control=frozenset({"_thread"}),
            safe=frozenset({"_stop", "_closed"}),
            # _stepping_lane: driver stores the key around each lane.step;
            # the watchdog timer thread's racy read is tolerated by design
            # (worst case it misses one borderline hang, never fingers a
            # wrong lane — the key is popped + re-checked under the lock)
            driver=frozenset({"_step_count"}),
            driver_write=frozenset({"_stepping_lane"}),
            driver_methods=frozenset({"_lane_for", "_admit", "step",
                                      "run_until_idle", "_drive",
                                      "_fail_all", "_requeue",
                                      "_note_fault"}),
            control_methods=frozenset({"start", "close",
                                       "run_until_idle"}),
            lock_held_methods=frozenset({"_canonical"}),
        ),
    },
    "src/repro/serve/scheduler.py": {
        "Lane": ClassDiscipline(
            lock="_lock",
            init=frozenset({"engine", "spec", "bucket", "width",
                            "chunk_ticks", "metrics", "surrogates",
                            "programs", "_clocks", "_last_lif",
                            "degraded"}),
            driver=frozenset({"_banks", "_carries", "_prev", "_end_ks"}),
            driver_write=frozenset({"g", "free", "active", "idle_rounds",
                                    "sur_token"}),
            safe=frozenset({"_poison"}),   # threading.Event: watchdog
                                           # timer thread sets, driver reads
            driver_methods=frozenset({"admit", "step", "_slice",
                                      "_quarantine"}),
        ),
    },
    "src/repro/serve/store.py": {
        "ArtifactStore": ClassDiscipline(
            lock="_lock",
            locked=frozenset({"_artifacts"}),
        ),
    },
}


def _self_attr(node):
    """'field' if node is ``self.field``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _class_level_names(cls_node: ast.ClassDef):
    """Names defined on the class body (methods, properties, class vars)
    — ``self.<name>`` hitting one of these is a method/property access,
    not instance state."""
    names = set()
    for stmt in cls_node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(stmt, ast.AnnAssign):
            if isinstance(stmt.target, ast.Name):
                names.add(stmt.target.id)
    return names


class _MethodLinter(ast.NodeVisitor):
    def __init__(self, cls_name, method, disc: ClassDiscipline,
                 table, rel, class_names, findings):
        self.cls = cls_name
        self.method = method.name
        self.disc = disc
        self.table = table      # merged {class -> discipline} over files
        self.rel = rel
        self.class_names = class_names
        self.findings = findings
        self.lock_depth = 1 if method.name in disc.lock_held_methods else 0
        self.in_init = method.name == "__init__"
        self.is_driver = (self.in_init
                          or method.name in disc.driver_methods)
        self.is_control = (self.in_init
                           or method.name in disc.control_methods)

    def _flag(self, check, node, msg):
        self.findings.append(Finding(
            check, f"{self.rel}:{self.cls}.{self.method}",
            f"line {node.lineno}: {msg}"))

    # -- lock tracking ---------------------------------------------------

    def _is_lock_expr(self, expr):
        field = _self_attr(expr)
        return field == self.disc.lock or field in self.disc.lock_aliases

    def visit_With(self, node):
        holds = any(self._is_lock_expr(item.context_expr)
                    for item in node.items)
        for item in node.items:
            self.visit(item.context_expr)
        if holds:
            self.lock_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if holds:
            self.lock_depth -= 1

    # -- field-category rules --------------------------------------------

    def visit_Attribute(self, node):
        field = _self_attr(node)
        if field is None or field in self.class_names:
            self.generic_visit(node)
            return
        d = self.disc
        is_store = isinstance(node.ctx, (ast.Store, ast.Del))
        if field == d.lock or field in d.lock_aliases or field in d.safe:
            pass
        elif field in d.locked:
            if self.lock_depth == 0 and not self.in_init:
                self._flag("unguarded-state", node,
                           f"access to lock-guarded field "
                           f"'self.{field}' outside 'with "
                           f"self.{d.lock}'")
        elif field in d.driver:
            if not self.is_driver:
                self._flag("thread-affinity", node,
                           f"driver-thread-only field 'self.{field}' "
                           f"accessed from non-driver method")
        elif field in d.driver_write:
            if is_store and not self.is_driver:
                self._flag("thread-affinity", node,
                           f"driver-owned field 'self.{field}' written "
                           f"from non-driver method (racy reads are "
                           f"tolerated, writes are not)")
        elif field in d.init:
            if is_store and not self.in_init:
                self._flag("init-immutability", node,
                           f"immutable-after-init field 'self.{field}' "
                           f"written outside __init__")
        elif field in d.control:
            if not self.is_control:
                self._flag("thread-affinity", node,
                           f"lifecycle field 'self.{field}' accessed "
                           f"outside control methods")
        else:
            self._flag("unannotated-field", node,
                       f"'self.{field}' has no category in the "
                       f"locking-discipline table — annotate it in "
                       f"repro/analysis/thread_lint.py:LINT_TABLE")
        self.generic_visit(node)

    # -- call rules ------------------------------------------------------

    def visit_Call(self, node):
        callee = None
        if isinstance(node.func, ast.Attribute):
            callee = node.func.attr
        elif isinstance(node.func, ast.Name):
            callee = node.func.id

        # blocking work / user callbacks under the lock
        if self.lock_depth > 0 and callee is not None:
            exempt = False
            if isinstance(node.func, ast.Attribute):
                # Condition.wait/notify on the lock's own condition is
                # the one sanctioned "slow" call under the lock (it
                # RELEASES the lock while waiting).
                owner = _self_attr(node.func.value)
                if (owner in self.disc.lock_aliases
                        and callee in ("wait", "notify", "notify_all")):
                    exempt = True
            if not exempt and (callee in BLOCKING_CALLS
                               or callee in BLOCKING_CONSTRUCTORS):
                self._flag("blocking-under-lock", node,
                           f"'{callee}' invoked while holding "
                           f"self.{self.disc.lock} — blocking/callback "
                           f"work must run after the lock is released")

        # self._method() where _method requires the lock already held
        if (isinstance(node.func, ast.Attribute)
                and _self_attr(node.func) in self.disc.lock_held_methods
                and self.lock_depth == 0):
            self._flag("unguarded-state", node,
                       f"'self.{node.func.attr}' requires the caller to "
                       f"hold self.{self.disc.lock}")

        # cross-object: <expr>.driver_method(...) on another annotated
        # class, from a method not itself driver-side
        if (isinstance(node.func, ast.Attribute)
                and _self_attr(node.func) is None
                and not self.is_driver):
            for other in self.table.values():
                if (callee in other.driver_methods
                        and callee not in self.disc.driver_methods
                        and callee not in self.disc.control_methods):
                    self._flag("thread-affinity", node,
                               f"'{callee}' is a driver-thread method of "
                               f"an annotated class, called from a "
                               f"non-driver method")
                    break
        self.generic_visit(node)

    def visit_Assign(self, node):
        # cross-object driver-field stores: lane.g = ..., lane._carries = ...
        for target in node.targets:
            if (isinstance(target, ast.Attribute)
                    and _self_attr(target) is None
                    and not self.is_driver):
                for other in self.table.values():
                    if target.attr in (other.driver | other.driver_write):
                        self._flag(
                            "thread-affinity", target,
                            f"store to '{target.attr}', a driver-owned "
                            f"field of an annotated class, from a "
                            f"non-driver method")
                        break
        self.generic_visit(node)


def lint_source(src: str, table: dict, filename: str = "<string>"):
    """Lint one file's source against {class_name: ClassDiscipline}.
    Returns a list of :class:`Finding`."""
    findings = []
    tree = ast.parse(src)
    merged = {}
    for classes in LINT_TABLE.values():
        merged.update(classes)
    merged.update(table)
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef) or node.name not in table:
            continue
        disc = table[node.name]
        class_names = _class_level_names(node)
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _MethodLinter(node.name, stmt, disc, merged, filename,
                              class_names, findings).visit(stmt)
    return findings


def lint_file(rel_path: str, root=REPO_ROOT):
    path = pathlib.Path(root) / rel_path
    return lint_source(path.read_text(), LINT_TABLE[rel_path], rel_path)


def run_lint(root=REPO_ROOT):
    """Lint every file in LINT_TABLE; returns all findings."""
    findings = []
    for rel in sorted(LINT_TABLE):
        findings.extend(lint_file(rel, root=root))
    return findings
