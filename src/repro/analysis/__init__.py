"""Static analysis gates for the LASANA hot paths (docs/analysis.md).

Two passes, both CI legs (``tools/check_programs.py`` /
``tools/check_threads.py``):

``jaxpr_audit``
    traces every hot-path entrypoint registered with
    ``kernels.ops.register_entrypoint`` and verifies the program-level
    invariants the benchmarks otherwise only observe at runtime:
    per-tick dispatch budgets (fused <= 3 stacked dispatches, megakernel
    == 1), dot/scan counts frozen in ``tests/data/program_budgets.json``,
    donation discipline (every ``donate_argnums`` leaf actually aliased,
    none silently dropped), no fp64 promotion or host-callback primitives
    in traced bodies, cache-key completeness for every program/engine
    cache (including the ``id(...)``-in-a-cache-key AST ban), and the
    environment-read discipline (``kernels/ops.py`` is the only module
    reading ``REPRO_*`` configuration).

``thread_lint``
    an AST lint of the threaded serve subsystem driven by per-class
    locking-discipline tables: guarded-state access outside ``with
    self._lock``, blocking work (compiles, ``block_until_ready``) or user
    callbacks (``on_chunk``) invoked while holding the lock, and
    driver-thread-only state touched from foreign methods.
"""

from repro.analysis.jaxpr_audit import (Finding, ProgramMetrics,
                                        audit_entry, collect_budgets,
                                        run_audit, synthetic_surrogate)
from repro.analysis.thread_lint import (ClassDiscipline, LINT_TABLE,
                                        lint_file, lint_source, run_lint)

__all__ = [
    "ClassDiscipline",
    "Finding",
    "LINT_TABLE",
    "ProgramMetrics",
    "audit_entry",
    "collect_budgets",
    "lint_file",
    "lint_source",
    "run_audit",
    "run_lint",
    "synthetic_surrogate",
]
