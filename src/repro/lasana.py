"""``repro.lasana`` — the one documented LASANA entry point.

The paper's pitch is surrogates as *deployable artifacts*: train once on
golden (SPICE stand-in) traces, persist, then serve at scale inside a
digital simulation backend. This facade is that pipeline in four calls::

    import repro.lasana as lasana

    sur = lasana.train("lif", lasana.TrainConfig(n_runs=300))   # Surrogate
    sur.save("artifacts/lif.npz")                               # persist
    sur = lasana.load("artifacts/lif.npz")                      # redeploy
    run = lasana.simulate(spec, stimulus, surrogates=sur)       # NetworkRun

Long-horizon workloads stream instead: :func:`simulate_stream` chunks the
T axis with donated chunk-to-chunk carries (bit-identical record, bounded
memory) and :func:`stream` yields per-chunk records for live consumers.

Design contract — surrogates are **pytree arguments, not closures**: a
:class:`Surrogate` is an immutable registered pytree of selected-predictor
arrays plus a static manifest. ``lasana.simulate`` compiles one network
program per (graph, stimulus shape, surrogate structure) and passes the
surrogate *through* it as a traced argument, so retrained or hot-swapped
surrogates — every point of an architecture sweep — reuse the compiled
program with **zero recompiles** (see ``NetworkEngine.compile_count`` and
tests/test_facade.py). Heterogeneous graphs bind one surrogate per circuit
kind with a :class:`SurrogateLibrary`.

Everything here re-exports or wraps the composable pieces in
``repro.core.*`` (network engine, predictors, dataset generation); the old
entry points (``NetworkEngine(bank=...)``, ``persist.save_bank``,
``simulate.run_snn_*``) remain as deprecation shims that route through
this facade. See docs/api.md for the full reference.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Optional

from repro.core.explore import CandidateSpec, DSEReport
from repro.core.network import (NetworkEngine, NetworkRun, NetworkSpec,
                                StreamingRun)
from repro.core.surrogate import (FORMAT_VERSION, Manifest, Surrogate,
                                  SurrogateLibrary)
from repro.resilience.checkpoint import StreamCheckpoint

__all__ = [
    "FORMAT_VERSION",
    "CandidateSpec",
    "DSEReport",
    "Manifest",
    "NetworkRun",
    "StreamCheckpoint",
    "StreamingRun",
    "Surrogate",
    "SurrogateLibrary",
    "TrainConfig",
    "engine",
    "explore",
    "load",
    "resume",
    "save",
    "serve",
    "simulate",
    "simulate_stream",
    "stream",
    "train",
]

DEFAULT_FAMILIES = ("mean", "table", "linear", "gbdt", "mlp")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Configuration for :func:`train` (testbench scale + model families).

    n_runs    randomized testbench runs golden-simulated for the dataset
    n_steps   digital clock periods per run
    alpha     P(timestep is active) in the randomized testbench (§IV-A)
    seed      testbench RNG seed
    families  model families fit per predictor; the best validation-MSE
              family is selected (paper §IV-B). Fewer families = faster
              training (e.g. ``("mean", "linear")`` for smoke tests).
    """

    n_runs: int = 1000
    n_steps: int = 125
    alpha: float = 0.8
    seed: int = 0
    families: tuple = DEFAULT_FAMILIES


def train(circuit: str, cfg: Optional[TrainConfig] = None, *,
          verbose: bool = False) -> Surrogate:
    """Train a :class:`Surrogate` for one circuit kind (paper §IV end-to-end).

    Runs the randomized testbench through the golden transient simulator,
    extracts E1/E2/E3 events, fits every family in ``cfg.families`` per
    predictor, selects by validation MSE, and freezes the winners into an
    immutable pytree artifact. ``Surrogate.fit_info`` carries the
    per-family fit metrics."""
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    cfg = cfg or TrainConfig()
    ds = build_dataset(circuit, TestbenchConfig(
        n_runs=cfg.n_runs, n_steps=cfg.n_steps, alpha=cfg.alpha,
        seed=cfg.seed))
    bank = PredictorBank(circuit, families=tuple(cfg.families))
    bank.fit(ds, verbose=verbose)
    return Surrogate.from_bank(bank)


def save(surrogate, path: str) -> None:
    """Persist a :class:`Surrogate` (one ``.npz`` file) or a
    :class:`SurrogateLibrary` (a directory of ``{kind}.npz``) — alias of
    the artifact's own ``save``. Surrogate paths may omit the ``.npz``
    extension; ``save``/``load`` normalize it identically."""
    surrogate.save(path)


def load(path: str):
    """Load the artifact at ``path`` saved by :func:`save`.

    A file loads as a :class:`Surrogate` (with or without the ``.npz``
    extension spelled out, mirroring :func:`save`); a directory loads as
    a :class:`SurrogateLibrary` (the mixed-graph round trip mirrors the
    single-surrogate one). Raises ``ValueError`` on a format-version
    mismatch (artifacts are versioned; see
    ``repro.core.surrogate.FORMAT_VERSION``)."""
    import os
    if os.path.isdir(path):
        return SurrogateLibrary.load(path)
    return Surrogate.load(path)


# --- compiled-engine cache ------------------------------------------------------
#
# simulate() is stateless for the caller, but compiled network programs are
# cached per live NetworkSpec object, so calling simulate() repeatedly with
# retrained surrogates reuses one executable instead of recompiling per
# call. The cache is attached to the spec itself (not a module-level
# table): engines — and their compiled XLA executables — are released the
# moment the spec is garbage-collected, so sweeps that build many specs
# don't accumulate programs. Within one live spec the cache is a bounded
# LRU over (backend, mode, mesh, record_hidden, fused, fused_kernel)
# variants: a long-lived server process that cycles engine configurations
# evicts the least-recently-used engine (and its executables) instead of
# growing without bound.

_ENGINE_ATTR = "_lasana_engine_cache"
_ENGINE_LOCK = threading.Lock()

# engine-variant entries kept per live spec; read at call time so tests
# can tune it via monkeypatching, and resolved through
# ops.engine_cache_capacity so REPRO_ENGINE_CACHE can retune a deployment
ENGINE_CACHE_CAPACITY = 8


def engine(spec: NetworkSpec, *, backend: str = "lasana",
           mode: str = "standalone", mesh=None,
           record_hidden: bool = True, fused: bool = True,
           fused_kernel: Optional[bool] = None) -> NetworkEngine:
    """The cached :class:`NetworkEngine` serving ``spec`` for :func:`simulate`.

    One engine (and therefore one set of compiled programs) exists per live
    ``(spec, backend, mode, mesh, record_hidden, fused, fused_kernel)``
    combination; surrogates are bound per ``run()``/``simulate()`` call,
    not per engine. ``fused`` selects the stacked ``predict_heads`` tick
    (default) vs the per-``predict`` baseline; ``fused_kernel`` is the
    tri-state megakernel override (``None`` defers to
    ``REPRO_FUSED_KERNEL``, see docs/architecture.md "Inference hot
    path"). Useful directly when you want explicit control or to assert
    on ``engine(spec).compile_count`` in tests.

    The per-spec cache is a bounded LRU (``ENGINE_CACHE_CAPACITY``
    variants): requesting a new combination beyond capacity evicts the
    least-recently-used engine and its compiled executables — long-lived
    processes (the serving layer) cannot accumulate programs without
    bound. Thread-safe: concurrent callers racing on one spec get the
    same engine instance."""
    fused_kernel = None if fused_kernel is None else bool(fused_kernel)
    # the mesh keys BY VALUE (jax.sharding.Mesh hashes devices + axis
    # names), never by id(): after a mesh is garbage-collected, a new mesh
    # allocated at the same address must not silently reuse an engine
    # compiled for the dead mesh. Value-equal meshes share the engine
    # (same devices, same axes — same compiled program); the key keeps the
    # mesh alive only as long as the spec itself.
    key = (backend, mode, mesh, record_hidden, bool(fused), fused_kernel)
    with _ENGINE_LOCK:
        cache = getattr(spec, _ENGINE_ATTR, None)
        if cache is None:
            cache = collections.OrderedDict()
            # NetworkSpec is frozen (dataclass __setattr__ is blocked), but
            # a private cache slot is lifecycle bookkeeping, not spec state
            object.__setattr__(spec, _ENGINE_ATTR, cache)
        eng = cache.get(key)
        if eng is None:
            eng = NetworkEngine(spec, backend=backend, mode=mode, mesh=mesh,
                                record_hidden=record_hidden, fused=fused,
                                fused_kernel=fused_kernel)
            cache[key] = eng
        else:
            cache.move_to_end(key)
        from repro.kernels import ops
        capacity = ops.engine_cache_capacity(ENGINE_CACHE_CAPACITY)
        while len(cache) > max(int(capacity), 1):
            cache.popitem(last=False)
    return eng


def simulate(spec: NetworkSpec, stimulus, *, backend: str = "lasana",
             surrogates=None, mode: str = "standalone", mesh=None,
             record_hidden: bool = True,
             fused_kernel: Optional[bool] = None) -> NetworkRun:
    """Simulate a circuit graph and return its :class:`NetworkRun` record.

    One signature for all three backends (the paper's comparison set):

    spec        the circuit graph (``network.snn_spec`` /
                ``crossbar_mlp_spec`` / ``graph_spec``)
    stimulus    (T, B, fan_in) per-tick drive in the first layer's native
                units; (B, fan_in) is promoted to one combinational wave
    backend     "golden" (ODE reference) | "behavioral" (ideal update) |
                "lasana" (Algorithm 1 over trained surrogates)
    surrogates  backend="lasana": a :class:`Surrogate` (homogeneous graphs)
                or :class:`SurrogateLibrary` / ``{kind: Surrogate}`` dict
                (mixed graphs); legacy ``PredictorBank`` values are frozen
                automatically
    mode        lasana only: "standalone" | "annotation"
    mesh        optional ``jax.sharding.Mesh`` — shard the batch axis
    record_hidden  keep per-layer output traces (memory-heavy at scale)
    fused_kernel  lasana only: tri-state whole-tick-megakernel override —
                ``True``/``False`` force it on/off, ``None`` (default)
                defers to ``REPRO_FUSED_KERNEL`` (records match the
                default path bitwise on discrete outputs, energies to
                rtol 1e-5; see docs/architecture.md "Inference hot path")

    Surrogates pass through the compiled program as traced pytree
    arguments: repeated calls with the same live ``spec`` and retrained
    surrogates of identical structure reuse one compiled executable."""
    return engine(spec, backend=backend, mode=mode, mesh=mesh,
                  record_hidden=record_hidden,
                  fused_kernel=fused_kernel).run(stimulus,
                                                 surrogates=surrogates)


def simulate_stream(spec: NetworkSpec, stimulus, *,
                    chunk_ticks: Optional[int] = None,
                    backend: str = "lasana", surrogates=None,
                    mode: str = "standalone", mesh=None,
                    record_hidden: bool = False,
                    fused_kernel: Optional[bool] = None) -> NetworkRun:
    """Streaming-chunked :func:`simulate`: same record, bounded memory.

    The stimulus T axis is cut into ``chunk_ticks``-tick chunks; each
    chunk runs through one donated-carry compiled program (chunk-to-chunk
    state and surrogate leaves alias in place) while the previous chunk's
    records stream to the host asynchronously — long-horizon workloads run
    at steady-state speed without ever materializing a ``(T, ...)`` trace
    on device. The returned :class:`NetworkRun` is **bit-identical** to
    ``simulate(spec, stimulus, ...)`` for every chunk size, including the
    end-of-run idle flush (charged once, at the true stream end) and the
    compile-vs-steady wall split. At most two chunk programs compile per
    (batch, chunk shape, surrogate structure) regardless of stream length.

    ``stimulus`` may also be an *iterator* of (t_i, B, fan_in) blocks (a
    host generator producing drive on the fly), and ``surrogates`` an
    iterator of libraries to hot-swap predictor weights per chunk with
    zero recompiles. ``record_hidden`` defaults to False here — keeping
    per-layer traces of an unbounded stream defeats the point, so opt in
    explicitly for parity tests. ``fused_kernel`` as in :func:`simulate`."""
    return engine(spec, backend=backend, mode=mode, mesh=mesh,
                  record_hidden=record_hidden,
                  fused_kernel=fused_kernel).run_stream(
                      stimulus, chunk_ticks=chunk_ticks,
                      surrogates=surrogates)


def stream(spec: NetworkSpec, stimulus, *,
           chunk_ticks: Optional[int] = None, backend: str = "lasana",
           surrogates=None, mode: str = "standalone", mesh=None,
           record_hidden: bool = False,
           fused_kernel: Optional[bool] = None,
           checkpoint_every: Optional[int] = None):
    """Generator variant of :func:`simulate_stream` for live consumers.

    Yields one per-chunk :class:`NetworkRun` as its records land on the
    host (chunk k is fetched while chunk k+1 computes); only the final
    chunk carries ``flush_energy``. Feed the chunks to
    :class:`StreamingRun` (or :meth:`NetworkRun.merge`) for the exact
    whole-run record, or consume them incrementally — live dashboards,
    online energy monitors, early stopping. ``fused_kernel`` as in
    :func:`simulate`.

    ``checkpoint_every=N`` attaches a resumable
    :class:`~repro.resilience.StreamCheckpoint` to every Nth chunk's
    record (``run.checkpoint``; persist with ``.save(path)``). A killed
    stream continues from its last checkpoint via :func:`resume`, and
    the merged record is bit-identical to the uninterrupted run — see
    docs/resilience.md. Requires ``chunk_ticks``."""
    return engine(spec, backend=backend, mode=mode, mesh=mesh,
                  record_hidden=record_hidden,
                  fused_kernel=fused_kernel).stream(
                      stimulus, chunk_ticks=chunk_ticks,
                      surrogates=surrogates,
                      checkpoint_every=checkpoint_every)


def resume(checkpoint, spec: NetworkSpec, stimulus, *, surrogates=None,
           mesh=None, fused_kernel: Optional[bool] = None,
           checkpoint_every: Optional[int] = None) -> NetworkRun:
    """Continue a checkpointed stream to completion and merge the record.

    ``checkpoint`` is a :class:`~repro.resilience.StreamCheckpoint` (or a
    path to one saved with ``.save``); ``spec`` and ``stimulus`` are the
    ORIGINAL network spec and full stimulus — the checkpoint pins the
    backend/mode/chunking and validates the spec's content hash, and the
    already-consumed stimulus prefix is skipped. Returns the whole-run
    :class:`NetworkRun`: the checkpoint's accumulated prefix merged with
    the freshly streamed tail, **bit-identical** to the uninterrupted
    run (discrete fields bitwise; energy within float tolerance), with
    zero extra compiles on a warm engine — the tail re-chunks exactly,
    so the donated-carry chunk program is reused as-is.

    ``checkpoint_every`` re-arms checkpointing on the resumed tail
    (multi-failure runs keep making progress)."""
    from repro.resilience import StreamCheckpoint
    if isinstance(checkpoint, str):
        checkpoint = StreamCheckpoint.load(checkpoint)
    eng = engine(spec, backend=checkpoint.backend, mode=checkpoint.mode,
                 mesh=mesh, record_hidden=checkpoint.record_hidden,
                 fused_kernel=fused_kernel)
    acc = StreamingRun()
    acc.update(checkpoint.acc_run)
    for chunk in eng.stream(stimulus, surrogates=surrogates,
                            resume_from=checkpoint,
                            checkpoint_every=checkpoint_every):
        acc.update(chunk)
    return acc.result()


def explore(candidates: CandidateSpec, surrogates, *,
            engine=None) -> DSEReport:
    """Vectorized design-space exploration over crossbar surrogates.

    Prices every candidate in ``candidates`` (a batched
    :class:`CandidateSpec`: layer widths, tile size, V_dd, MoE shape,
    circuit mix) through ONE compiled program: tile counts / MoE
    utilization / FLOP fractions are exact vectorized array math, and
    per-tile energy/latency comes from a single fused
    ``Surrogate.predict_heads`` pass over all candidates at once.
    ``surrogates`` is a crossbar :class:`Surrogate` (or a
    :class:`SurrogateLibrary` / ``{kind: Surrogate}`` dict carrying a
    ``"crossbar"`` entry; legacy ``PredictorBank`` values are frozen).

    Surrogates flow through as traced pytree arguments, so re-sweeping
    with retrained weights of equal structure reuses the compiled program
    with zero recompiles — ``lasana.explore`` shares one process-wide
    :class:`repro.core.explore.DSEEngine` (pass ``engine=`` for an
    isolated one) whose ``compile_count`` the returned
    :class:`DSEReport` carries. ``DSEReport.pareto()`` extracts the
    energy/latency/analog-fraction frontier. See docs/api.md ("Design-
    space exploration")."""
    from repro.core.explore import evaluate_candidates
    return evaluate_candidates(candidates, surrogates, engine=engine)


def serve(config=None, **overrides):
    """Start a persistent multi-tenant simulation server (LASANA-as-a-
    service; see docs/serving.md).

    Returns a started :class:`repro.serve.SimServer`: a long-lived
    process-local service that owns a surrogate artifact store
    (register/hot-swap by ``name@version``), quantizes heterogeneous
    requests onto a bounded set of compiled shape buckets, and packs
    concurrent requests along the batch axis of one compiled program
    (continuous batching — requests join/leave at chunk boundaries, with
    per-slot masks keeping every tenant's energy/latency/event records
    exactly what a solo :func:`simulate` of that request would produce).

    ``config`` is a :class:`repro.serve.ServeConfig`; keyword overrides
    are applied on top (e.g. ``lasana.serve(chunk_ticks=16,
    max_in_flight=8)``). Use as a context manager or call ``close()``::

        with lasana.serve(chunk_ticks=8) as srv:       # no-run
            srv.register_surrogate("lif", sur)
            h = srv.submit(spec, stimulus, surrogates="lif")
            run = h.result()                           # NetworkRun
            print(srv.stats()["requests_completed"])
    """
    from repro.serve import ServeConfig, SimServer
    if config is None:
        config = ServeConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    srv = SimServer(config)
    srv.start()
    return srv
