"""Surrogate artifact store: named, versioned, hot-swappable.

The serving counterpart of ``lasana.save``/``lasana.load``: a process-
local registry mapping ``name -> {version -> surrogate}`` so requests
reference predictor artifacts by a stable string (``"lif"`` or pinned
``"lif@2"``) instead of shipping arrays. Registering a retrained artifact
under an existing name mints the next version and becomes the default for
new requests — in-flight requests keep the version they resolved at
submit, so a hot-swap never changes a running simulation's results. Same-
structure versions share compiled programs (surrogates are traced
arguments of every network program), which is what makes version rollout
free of recompiles.
"""

from __future__ import annotations

import threading

from repro.core.surrogate import as_surrogate


def parse_ref(ref: str) -> tuple:
    """``"name"`` -> (name, None); ``"name@3"`` -> (name, 3)."""
    if "@" not in ref:
        return ref, None
    name, _, ver = ref.rpartition("@")
    if not name:
        raise ValueError(f"bad surrogate ref {ref!r}: expected "
                         "'name' or 'name@version'")
    try:
        return name, int(ver)
    except ValueError:
        raise ValueError(f"bad surrogate ref {ref!r}: version "
                         f"{ver!r} is not an integer") from None


class ArtifactStore:
    """Thread-safe ``name@version`` registry of surrogate artifacts.

    Values are whatever the engine accepts as ``surrogates=``: a
    :class:`Surrogate`, a :class:`SurrogateLibrary`, or a ``{circuit:
    Surrogate}`` mapping (mixed graphs); single artifacts are normalized
    through ``as_surrogate`` at registration so legacy ``PredictorBank``
    values freeze exactly once."""

    def __init__(self):
        self._lock = threading.Lock()
        self._artifacts: dict = {}      # name -> {version: object}

    def register(self, name: str, surrogate, *, version=None) -> int:
        """Register ``surrogate`` under ``name``; returns its version.

        Versions auto-increment from 1 per name; an explicit ``version``
        may fill gaps but never overwrite (hot-swap means *new* version,
        old results must stay reproducible)."""
        if not name or "@" in name:
            raise ValueError(f"artifact name must be non-empty and "
                             f"'@'-free: {name!r}")
        if not isinstance(surrogate, dict) and not hasattr(surrogate,
                                                           "kinds"):
            surrogate = as_surrogate(surrogate)
        with self._lock:
            versions = self._artifacts.setdefault(name, {})
            if version is None:
                version = max(versions, default=0) + 1
            version = int(version)
            if version in versions:
                raise ValueError(
                    f"{name}@{version} already registered; surrogate "
                    "versions are immutable — register a new version")
            versions[version] = surrogate
        return version

    def resolve(self, ref: str) -> tuple:
        """``"name[@version]"`` -> ((name, version), surrogate).

        A bare name resolves to the LATEST version at call time — the
        hot-swap default — while the pinned identity is returned so a
        request's records stay attributed to the exact artifact that
        produced them."""
        name, version = parse_ref(ref)
        with self._lock:
            versions = self._artifacts.get(name)
            if not versions:
                raise KeyError(f"no surrogate registered under {name!r}")
            if version is None:
                version = max(versions)
            if version not in versions:
                raise KeyError(f"{name}@{version} not registered "
                               f"(have {sorted(versions)})")
            return (name, version), versions[version]

    def get(self, name: str, version=None):
        ref = name if version is None else f"{name}@{version}"
        return self.resolve(ref)[1]

    def names(self) -> list:
        with self._lock:
            return sorted(self._artifacts)

    def versions(self, name: str) -> list:
        with self._lock:
            return sorted(self._artifacts.get(name, ()))
