"""Surrogate artifact store: named, versioned, hot-swappable.

The serving counterpart of ``lasana.save``/``lasana.load``: a process-
local registry mapping ``name -> {version -> surrogate}`` so requests
reference predictor artifacts by a stable string (``"lif"`` or pinned
``"lif@2"``) instead of shipping arrays. Registering a retrained artifact
under an existing name mints the next version and becomes the default for
new requests — in-flight requests keep the version they resolved at
submit, so a hot-swap never changes a running simulation's results. Same-
structure versions share compiled programs (surrogates are traced
arguments of every network program), which is what makes version rollout
free of recompiles.
"""

from __future__ import annotations

import threading

from repro.core.surrogate import as_surrogate
from repro.resilience import faults


class ArtifactError(RuntimeError):
    """A surrogate artifact failed to load or validate.

    Raised (in place of raw ``zipfile``/``ValueError`` internals) when a
    path-registered artifact turns out truncated or corrupt, naming the
    ``name@version`` identity and the file path. Only the request that
    forced the load sees it — the store entry stays resolvable-but-
    broken, other names/versions are untouched."""


def load_artifact(path: str, *, name=None, version=None):
    """``lasana.load`` with corruption wrapped in :class:`ArtifactError`.

    ``name``/``version`` give the error its artifact identity (lazy
    path-registered entries resolve through here). A missing file keeps
    its raw ``FileNotFoundError`` (it already names every path tried);
    everything else — bad zip, short read, version mismatch, missing
    manifest — becomes one clean ArtifactError with the cause chained.
    Injection site ``artifact.load`` fires here."""
    ref = name if version is None else f"{name}@{version}"
    import repro.lasana as lasana
    try:
        faults.check("artifact.load")
        return lasana.load(path)
    except FileNotFoundError:
        raise
    except Exception as err:
        who = f"artifact {ref!r} " if name else "artifact "
        raise ArtifactError(
            f"{who}at {path!r} is corrupt or unreadable "
            f"({type(err).__name__}: {err}); re-save it with "
            "lasana.save / Surrogate.save") from err


class _LazyArtifact:
    """A path-registered artifact not yet loaded (see
    :meth:`ArtifactStore.register_path`)."""

    __slots__ = ("path",)

    def __init__(self, path: str):
        self.path = path


def parse_ref(ref: str) -> tuple:
    """``"name"`` -> (name, None); ``"name@3"`` -> (name, 3)."""
    if "@" not in ref:
        return ref, None
    name, _, ver = ref.rpartition("@")
    if not name:
        raise ValueError(f"bad surrogate ref {ref!r}: expected "
                         "'name' or 'name@version'")
    try:
        return name, int(ver)
    except ValueError:
        raise ValueError(f"bad surrogate ref {ref!r}: version "
                         f"{ver!r} is not an integer") from None


class ArtifactStore:
    """Thread-safe ``name@version`` registry of surrogate artifacts.

    Values are whatever the engine accepts as ``surrogates=``: a
    :class:`Surrogate`, a :class:`SurrogateLibrary`, or a ``{circuit:
    Surrogate}`` mapping (mixed graphs); single artifacts are normalized
    through ``as_surrogate`` at registration so legacy ``PredictorBank``
    values freeze exactly once."""

    def __init__(self):
        self._lock = threading.Lock()
        self._artifacts: dict = {}      # name -> {version: object}

    def register(self, name: str, surrogate, *, version=None) -> int:
        """Register ``surrogate`` under ``name``; returns its version.

        Versions auto-increment from 1 per name; an explicit ``version``
        may fill gaps but never overwrite (hot-swap means *new* version,
        old results must stay reproducible)."""
        if not name or "@" in name:
            raise ValueError(f"artifact name must be non-empty and "
                             f"'@'-free: {name!r}")
        if not isinstance(surrogate, dict) and not hasattr(surrogate,
                                                           "kinds"):
            surrogate = as_surrogate(surrogate)
        with self._lock:
            versions = self._artifacts.setdefault(name, {})
            if version is None:
                version = max(versions, default=0) + 1
            version = int(version)
            if version in versions:
                raise ValueError(
                    f"{name}@{version} already registered; surrogate "
                    "versions are immutable — register a new version")
            versions[version] = surrogate
        return version

    def register_path(self, name: str, path: str, *, version=None) -> int:
        """Register an on-disk ``.npz`` artifact lazily; returns version.

        The file is NOT read here: the first request that resolves this
        version loads it (through :func:`load_artifact`), so a truncated
        or corrupt file fails only that requesting caller — with a clean
        :class:`ArtifactError` naming ``name@version`` and the path —
        and never the registration, the server, or other artifacts. A
        successful load is cached in place; later resolves are free."""
        if not name or "@" in name:
            raise ValueError(f"artifact name must be non-empty and "
                             f"'@'-free: {name!r}")
        with self._lock:
            versions = self._artifacts.setdefault(name, {})
            if version is None:
                version = max(versions, default=0) + 1
            version = int(version)
            if version in versions:
                raise ValueError(
                    f"{name}@{version} already registered; surrogate "
                    "versions are immutable — register a new version")
            versions[version] = _LazyArtifact(path)
        return version

    def resolve(self, ref: str) -> tuple:
        """``"name[@version]"`` -> ((name, version), surrogate).

        A bare name resolves to the LATEST version at call time — the
        hot-swap default — while the pinned identity is returned so a
        request's records stay attributed to the exact artifact that
        produced them. Path-registered entries load on first resolve
        (outside the store lock; see :meth:`register_path`) and raise
        :class:`ArtifactError` to THIS caller when the file is corrupt."""
        name, version = parse_ref(ref)
        with self._lock:
            versions = self._artifacts.get(name)
            if not versions:
                raise KeyError(f"no surrogate registered under {name!r}")
            if version is None:
                version = max(versions)
            if version not in versions:
                raise KeyError(f"{name}@{version} not registered "
                               f"(have {sorted(versions)})")
            entry = versions[version]
        if isinstance(entry, _LazyArtifact):
            loaded = load_artifact(entry.path, name=name, version=version)
            with self._lock:
                # another resolver may have raced the load; first one wins
                # so every request sees ONE loaded object
                entry = self._artifacts[name][version]
                if isinstance(entry, _LazyArtifact):
                    self._artifacts[name][version] = entry = loaded
        return (name, version), entry

    def get(self, name: str, version=None):
        ref = name if version is None else f"{name}@{version}"
        return self.resolve(ref)[1]

    def names(self) -> list:
        with self._lock:
            return sorted(self._artifacts)

    def versions(self, name: str) -> list:
        with self._lock:
            return sorted(self._artifacts.get(name, ()))
