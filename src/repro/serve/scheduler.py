"""Continuous-batching scheduler: lanes of slot-multiplexed requests.

A :class:`Lane` is one live instance of a compiled slot-program family
(one :class:`~repro.serve.buckets.Bucket` × one resolved surrogate
artifact × one engine mode): a persistent ``width``-slot batch whose
global tick counter ``g`` advances one ``chunk_ticks`` quantum per
:meth:`Lane.step`. Concurrent requests own disjoint slot sets inside the
batch; they

  * JOIN at a chunk boundary — the lane's ``join`` program re-initializes
    their slots with ``t_last = g`` in each layer's native clock, which by
    time-translation invariance makes the slot's tau sequence (and hence
    every surrogate prediction) identical to a request started at tick 0;
  * RUN under a per-slot live mask — each tick only slots whose request
    still has stimulus are simulated, so co-batched requests of different
    lengths never contaminate each other and padding is frozen, not
    computed;
  * LEAVE mid-stream — on the chunk where a request's stimulus ends, the
    lane's ``flush`` program charges ITS trailing idle energy (per-slot
    end times; all other slots charge exactly zero) and the slots return
    to the free list for the next joiner.

Per-slot record streams (energy/latency/events ``(T, L, width)``) are
sliced back into per-request chunk :class:`NetworkRun` records and pushed
to each request's :class:`RequestHandle`; their merge is the request's
whole-run record, matching a solo ``lasana.simulate`` bit-for-bit on
discrete records (rtol 1e-5 on f32 energy sums, whose slot-wise reduction
reassociates float addition; latency maxes additionally carry a one-ULP
absolute epsilon from vectorization-width variance in the surrogate's
dot products, visible on near-zero elements — nothing else differs).

Different surrogate *versions* cannot share a lane — the surrogate is one
traced argument of the batched program — but lanes of equal structure
share the compiled programs, so version rollout costs zero compiles.
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import NetworkRun
from repro.resilience import faults


class RequestHandle:
    """Caller-facing future for one submitted simulation request.

    Chunk records stream in as the scheduler retires them (``on_chunk``
    fires from the driver thread); :meth:`result` blocks for — and
    merges — the complete per-request :class:`NetworkRun`."""

    def __init__(self, req_id: int, tenant: str, on_chunk=None):
        self.id = req_id
        self.tenant = tenant
        self._on_chunk = on_chunk
        self._chunks: list = []
        self._done = threading.Event()
        self._error = None
        self._result = None
        self.wait_chunks = 0          # scheduler rounds spent queued
        self.surrogate_ref = None     # (name, version) when store-resolved
        self.degraded = False         # served on the behavioral fallback
        self.attempts = 0             # admissions consumed (1 + retries)

    def _push(self, chunk: NetworkRun):
        self._chunks.append(chunk)
        if self._on_chunk is not None:
            try:
                faults.check("callback.explode")
                self._on_chunk(chunk)
            except Exception as err:   # a user callback raising must fail
                self._on_chunk = None  # ITS request, not the driver thread
                self._fail(err)

    def _reset_for_retry(self):
        """Drop partial chunk records so a re-admission replays the whole
        request — the merged result must match a clean solo run bitwise,
        and chunks from the faulted attempt can never mix into it."""
        self._chunks = []

    def _finish(self):
        self._result = NetworkRun.merge(self._chunks)
        self._done.set()

    def _fail(self, err: Exception):
        self._error = err
        self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def chunks(self) -> list:
        """Per-chunk records received so far (complete once ``done``)."""
        return list(self._chunks)

    def result(self, timeout=None) -> NetworkRun:
        """Block until the request completes; the merged NetworkRun."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.id} still in flight "
                               f"after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class _Active:
    """One seated request: its queue entry, slots, and tick window.

    Keeps the full ``_Queued`` so the server can requeue a quarantined
    or fault-hit request for another attempt (retry-with-backoff) without
    re-deriving its spec/surrogate resolution."""

    def __init__(self, q, slots: list, g0: int):
        self.q = q                       # server _Queued (for requeue)
        self.handle = q.handle
        self.x = q.stimulus              # (T, b_req, fan_in) host array
        self.slots = slots
        self.g0 = g0                     # global join tick
        self.t_total = self.x.shape[0]

    @property
    def g_end(self) -> int:
        return self.g0 + self.t_total


class Lane:
    """One live continuous batch driving a compiled slot-program family."""

    def __init__(self, engine, spec, bucket, surrogates, *,
                 metrics=None):
        self.engine = engine
        self.spec = spec
        self.bucket = bucket
        self.width = bucket.width
        self.chunk_ticks = bucket.chunk_ticks
        self.metrics = metrics
        # strong reference: the server's lane key embeds id(surrogates)
        # for directly-passed artifacts, which is only stable while the
        # object is alive — holding it here pins the id for the lane's
        # lifetime (retirement drops key and reference together)
        self.surrogates = surrogates
        # behavioral-backend lanes are the graceful-degradation fallback:
        # every request they complete is flagged ``handle.degraded``
        self.degraded = engine.backend == "behavioral"
        # set by the server watchdog (timer thread) when this lane's step
        # overran the hang limit: the step must not push records or count
        # completions — its requests were already failed
        self._poison = threading.Event()
        self.idle_rounds = 0             # rounds with no active requests
        self.programs = engine.slot_programs(self.width, self.chunk_ticks,
                                             surrogates)
        if metrics is not None and self.programs.compile_seconds:
            metrics.add(compile_seconds=self.programs.compile_seconds)
        banks = engine._runtime_banks(surrogates)
        self._banks = engine._donatable_banks(banks)
        self._carries = [engine._init_carry(i, self.width)
                         for i in range(spec.n_layers)]
        self._prev = [jnp.zeros((self.width, l.n_out), jnp.float32)
                      for l in spec.layers]
        self._end_ks = np.zeros(self.width, np.float32)
        self._clocks = [c.clock_ns for c in engine.circs]
        self._last_lif = spec.circuits[-1] == "lif"
        self.g = 0                       # global tick at next chunk start
        self.free = list(range(self.width))
        self.active: list = []

    @property
    def free_width(self) -> int:
        return len(self.free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self.free) / self.width

    def admit(self, q) -> bool:
        """Seat a queued request at the NEXT chunk boundary; False if full."""
        b_req = q.stimulus.shape[1]
        if b_req > len(self.free):
            return False
        slots = [self.free.pop(0) for _ in range(b_req)]
        self.active.append(_Active(q, slots, self.g))
        q.handle.attempts += 1
        q.handle.degraded = self.degraded
        return True

    def step(self) -> dict:
        """Advance every seated request one chunk; returns step stats.

        One scheduling round: join-reset newly seated slots, advance the
        whole batch ``chunk_ticks`` ticks under the live mask, slice each
        tenant's rows out of the shared per-slot records, flush + free
        the slots of requests that ended inside this chunk."""
        if not self.active:
            return {}
        faults.check("lane.step")        # injected driver-visible failure
        faults.stall("chunk.stall")      # injected slow chunk (watchdog)
        if self._poison.is_set():        # the watchdog killed this lane
            return {}                    # while we were stuck above
        t0 = time.time()
        tc, width = self.chunk_ticks, self.width
        g = self.g
        joiners = [a for a in self.active if a.g0 == g]
        if joiners:
            mask = np.zeros(width, bool)
            for a in joiners:
                mask[a.slots] = True
                self._end_ks[a.slots] = np.float32(a.g_end)
            self._carries, self._prev = self.programs.join(
                self._carries, self._prev, jnp.asarray(mask),
                jnp.float32(g))

        fan_in = self.spec.layers[0].fan_in
        x = np.zeros((tc, width, fan_in), np.float32)
        live_ticks = 0
        for a in self.active:
            rows = min(tc, a.g_end - g)
            lo = g - a.g0
            x[:rows, a.slots, :] = a.x[lo:lo + rows]
            live_ticks += rows * len(a.slots)

        outs = self.programs.step(
            jnp.asarray(x), jnp.float32(g), jnp.asarray(self._end_ks),
            self._carries, self._prev, self._banks)
        primary, out_seq, hidden, e_tlb, l_tlb, ev_tlb = jax.device_get(
            outs[:6])
        self._carries, self._prev, self._banks = outs[6], outs[7], outs[8]
        if self._poison.is_set():
            # the watchdog failed this lane's requests mid-dispatch:
            # records of a hung step are dead — push and count nothing
            return {}

        if faults.should_fire("surrogate.nan"):
            # host-side NaN burst into the fetched head outputs of ONE
            # deterministic victim; device carries stay clean, so what is
            # under test is the sentinel + quarantine + requeue path (a
            # replay from scratch is exact), not NaN laundering
            victim = self.active[int(faults.draw("surrogate.nan")
                                     * len(self.active))
                                 % len(self.active)]
            e_tlb = np.array(e_tlb)      # device_get arrays may be
            l_tlb = np.array(l_tlb)      # read-only views
            e_tlb[:, :, victim.slots] = np.nan
            l_tlb[:, :, victim.slots] = np.inf
        quarantined = self._quarantine(primary, out_seq, e_tlb, l_tlb)

        leavers = [a for a in self.active if a.g_end <= g + tc]
        flushes = None
        if leavers:
            t_ends = np.zeros((self.spec.n_layers, width), np.float32)
            for a in leavers:
                for i, clock in enumerate(self._clocks):
                    t_ends[i, a.slots] = np.float32(a.g_end * clock)
            flushes = np.asarray(jax.device_get(self.programs.flush(
                self._carries, jnp.asarray(t_ends), self._banks)))

        events = 0
        for a in self.active:
            rows = min(tc, a.g_end - g)
            flush = np.zeros((self.spec.n_layers,), np.float32)
            if flushes is not None and a.g_end <= g + tc:
                flush = flushes[:, a.slots].sum(axis=1)
            rec = self._slice(a, rows, primary, out_seq, hidden,
                              e_tlb, l_tlb, ev_tlb, flush)
            events += int(rec.events.sum())
            a.handle._push(rec)

        for a in leavers:
            self.active.remove(a)
            self.free.extend(a.slots)
            self.free.sort()
            a.handle._finish()
        self.g = g + tc
        stats = {"live_ticks": live_ticks, "events": events,
                 "occupancy": live_ticks / (tc * width),
                 "completed": len(leavers),
                 "quarantined": quarantined,
                 "steady_seconds": time.time() - t0}
        if self.metrics is not None:
            self.metrics.add(chunks_total=1, ticks_live_total=live_ticks,
                             events_total=events,
                             occupancy_sum=stats["occupancy"],
                             steady_seconds=stats["steady_seconds"],
                             requests_completed=len(leavers),
                             requests_degraded=(len(leavers)
                                                if self.degraded else 0))
        return stats

    def _quarantine(self, primary, out_seq, e_tlb, l_tlb) -> list:
        """Evict requests whose OWN slot outputs went non-finite.

        The NaN/Inf sentinel on the fetched head outputs attributes the
        burst per request over its disjoint slot set: only offending
        requests are unseated (slots freed, their end-ticks zeroed so the
        live mask goes dead next chunk) and returned for the server to
        requeue or fail — no record is pushed for them, and co-tenants'
        slices are untouched, so their merged records stay bitwise
        identical to a solo run. The whole-batch finiteness check is the
        fast path: on clean chunks (the overwhelming majority) this is
        one fused reduction, no per-request work."""
        arrs = [e_tlb, l_tlb, np.asarray(out_seq)]
        if self._last_lif:
            arrs.append(np.asarray(primary))
        if all(np.isfinite(v).all() for v in arrs):
            return []
        quarantined: list = []
        for a in list(self.active):
            S = a.slots
            bad = (not np.isfinite(e_tlb[:, :, S]).all()
                   or not np.isfinite(l_tlb[:, :, S]).all()
                   or not np.isfinite(np.asarray(out_seq)[:, S]).all()
                   or (self._last_lif
                       and not np.isfinite(np.asarray(primary)[S]).all()))
            if not bad:
                continue
            self.active.remove(a)
            self.free.extend(S)
            self._end_ks[S] = np.float32(0.0)   # live mask: dead next chunk
            quarantined.append(a)
        self.free.sort()
        if quarantined and self.metrics is not None:
            self.metrics.add(numerical_faults=len(quarantined))
        return quarantined

    def _slice(self, a: _Active, rows: int, primary, out_seq, hidden,
               e_tlb, l_tlb, ev_tlb, flush) -> NetworkRun:
        """Cut one request's per-chunk record out of the shared batch.

        Slot sums/maxes over the request's own slots reproduce the solo
        record's whole-layer reductions: energy/events sum over disjoint
        circuit sets, latency is a max, and dead ticks/slots contribute
        exact zeros (the live mask froze them)."""
        S = a.slots
        spec = self.spec
        if self._last_lif:
            # per-chunk spike counts: ticks past the request's end emit
            # zero spikes under the live mask, so whole-chunk counts are
            # exact; merge sums the integer partials
            outputs = np.asarray(primary)[S]
            out_spikes = np.asarray(out_seq)[:rows][:, S]
        else:
            outputs = np.asarray(out_seq)[rows - 1][S]
            out_spikes = None
        layer_spikes = None
        if self.engine.record_hidden:
            layer_spikes = [np.asarray(h)[:rows][:, S] for h in hidden]
        return NetworkRun(
            backend=self.engine.backend, mode=self.engine.mode,
            outputs=outputs, out_spikes=out_spikes,
            layer_spikes=layer_spikes,
            energy=e_tlb[:rows][:, :, S].sum(axis=2),
            latency=l_tlb[:rows][:, :, S].max(axis=2),
            events=ev_tlb[:rows][:, :, S].sum(axis=2).astype(np.int64),
            flush_energy=flush,
            n_circuits=np.asarray([l.n_circuits(len(S))
                                   for l in spec.layers]),
            clock_ns=self.engine.clock_ns, wall_seconds=0.0,
            circuits=spec.circuits,
            compile_seconds=0.0)
