"""Minimal JSON-lines request/response protocol for the server.

One op object per line in, one response object per line out — the same
loop serves stdin/stdout (``python -m repro.serve``), a TCP socket
(``--port``), and in-process tests (any file-like pair). Ops:

``{"op": "register_surrogate", "name": N, "path": P}``
    load a saved artifact (``lasana.load``) into the store; or train one
    in place with ``"train": {"circuit": "lif", "n_runs": ..,
    "families": [..]}``. Response: ``{"ok": true, "version": v}``.
``{"op": "register_spec", "name": N, "snn": {"weights": [...],
   "params": [...]}}``
    register a feed-forward SNN spec under a name (the in-process API
    accepts arbitrary ``NetworkSpec`` objects; the wire protocol covers
    the homogeneous case).
``{"op": "simulate", "spec": N, "surrogate": "name[@ver]",
   "stimulus": [[[...]]]}``
    submit one request and stream until done. Response carries the
    merged record's headline numbers (outputs, energy, events, ticks)
    plus a ``"degraded"`` flag (True when served by the behavioral
    fallback). Optional ``"deadline_ms"`` / ``"max_retries"`` map to the
    same-named ``submit`` arguments (see docs/resilience.md).
    Spec names resolve from this connection's registrations first, then
    the server-wide registry (names survive reconnects).
    ``"stimulus_spikes": {"t": T, "b": B, "rate": p, "seed": s}``
    generates a Bernoulli spike train server-side instead of shipping
    the array.
``{"op": "simulate_batch", "requests": [...]}``
    submit every entry (same fields as ``simulate``) BEFORE collecting
    any result — this is the op that exercises continuous batching over
    the wire. If a later submit is rejected (bad entry, ``ServerBusy``),
    the already-submitted requests are still collected: the response is
    ``{"ok": false, "error": msg, "results": [...partials...]}``.
``{"op": "stats"}`` / ``{"op": "shutdown"}``
    the ``/stats`` report; drain and stop.

Every response echoes the request ``"id"`` when given; errors come back
as ``{"ok": false, "error": msg}`` without killing the session.
"""

from __future__ import annotations

import json

import numpy as np


def _build_spec(obj: dict):
    from repro.core.network import snn_spec
    if "snn" not in obj:
        raise ValueError("register_spec needs an 'snn' description: "
                         "{'weights': [...], 'params': [...]}")
    snn = obj["snn"]
    weights = [np.asarray(w, np.float32) for w in snn["weights"]]
    params = [np.asarray(p, np.float32) for p in snn["params"]]
    return snn_spec(weights, params,
                    spike_amp=float(snn.get("spike_amp", 1.5)))


def _stimulus(req: dict, spec) -> np.ndarray:
    if "stimulus" in req:
        return np.asarray(req["stimulus"], np.float32)
    gen = req.get("stimulus_spikes")
    if gen is None:
        raise ValueError("simulate needs 'stimulus' (nested lists) or "
                         "'stimulus_spikes' ({t, b, rate, seed})")
    rng = np.random.default_rng(int(gen.get("seed", 0)))
    amp = float(getattr(spec, "spike_amp", 1.5))
    shape = (int(gen["t"]), int(gen["b"]), spec.layers[0].fan_in)
    return (rng.random(shape) < float(gen.get("rate", 0.2))
            ).astype(np.float32) * amp


def _summarize(handle, req_id) -> dict:
    run = handle.result()
    rep = run.report()["network"]
    out = {"ok": True,
           "outputs": np.asarray(run.outputs).tolist(),
           "energy_j": rep["energy_j"],
           "events": rep["events"],
           "ticks": rep["ticks"],
           "degraded": bool(handle.degraded)}
    if req_id is not None:
        out["id"] = req_id
    return out


def _submit(server, req: dict, specs: dict):
    name = req.get("spec")
    spec = specs.get(name)
    if spec is None and isinstance(name, str):
        # fall back to the server-side registry so a reconnecting client
        # can keep using names registered on an earlier connection
        spec = server.spec(name)
    if spec is None:
        raise KeyError(f"no spec registered under {name!r}")
    kw = {}
    if req.get("deadline_ms") is not None:
        kw["deadline_ms"] = float(req["deadline_ms"])
    if req.get("max_retries") is not None:
        kw["max_retries"] = int(req["max_retries"])
    return server.submit(
        spec, _stimulus(req, spec), surrogates=req["surrogate"],
        tenant=str(req.get("tenant", "default")),
        mode=str(req.get("mode", "standalone")), **kw), req.get("id")


def handle_op(server, obj: dict, specs: dict):
    """Execute one protocol op; returns (response dict, keep_going)."""
    op = obj.get("op")
    if op == "register_surrogate":
        import repro.lasana as lasana
        if "path" in obj:
            # lazy: the artifact loads on first resolve, so a corrupt
            # file fails the requesting simulate (ArtifactError naming
            # name@version + path), never this registration
            version = server.register_surrogate_path(obj["name"],
                                                     obj["path"])
            return ({"ok": True, "name": obj["name"],
                     "version": version}, True)
        if "train" in obj:
            t = dict(obj["train"])
            circuit = t.pop("circuit", "lif")
            t.setdefault("families", ("mean", "linear"))
            t["families"] = tuple(t["families"])
            artifact = lasana.train(circuit, lasana.TrainConfig(**t))
        else:
            raise ValueError("register_surrogate needs 'path' or 'train'")
        version = server.register_surrogate(obj["name"], artifact)
        return {"ok": True, "name": obj["name"], "version": version}, True
    if op == "register_spec":
        spec = _build_spec(obj)
        specs[obj["name"]] = spec
        server.register_spec(obj["name"], spec)
        return {"ok": True, "name": obj["name"]}, True
    if op == "simulate":
        handle, req_id = _submit(server, obj, specs)
        return _summarize(handle, req_id), True
    if op == "simulate_batch":
        handles, error = [], None
        for r in obj["requests"]:
            try:
                handles.append(_submit(server, r, specs))
            except Exception as err:   # collect what WAS submitted — the
                error = f"{type(err).__name__}: {err}"   # work is in
                break                                    # flight either way
        results = [_summarize(h, rid) for h, rid in handles]
        if error is not None:
            return {"ok": False, "error": error, "results": results}, True
        return {"ok": True, "results": results}, True
    if op == "stats":
        return {"ok": True, "stats": server.stats()}, True
    if op == "shutdown":
        return {"ok": True, "shutdown": True}, False
    raise ValueError(f"unknown op {op!r}")


def run_stdio(server, infile, outfile) -> int:
    """Serve JSON-lines ops from ``infile`` to ``outfile`` until EOF or
    ``shutdown``; returns the number of ops handled. The server must be
    started (driver thread) — this loop only parses, submits, and
    blocks on results, exactly like a remote client."""
    handled = 0
    specs: dict = {}
    for line in infile:
        line = line.strip()
        if not line:
            continue
        keep, obj = True, None
        try:
            obj = json.loads(line)
            resp, keep = handle_op(server, obj, specs)
        except Exception as err:         # malformed op != dead session
            resp = {"ok": False, "error": f"{type(err).__name__}: {err}"}
            if isinstance(obj, dict) and obj.get("id") is not None:
                resp["id"] = obj.get("id")
        outfile.write(json.dumps(resp) + "\n")
        outfile.flush()
        handled += 1
        if not keep:
            break
    return handled
