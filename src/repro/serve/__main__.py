"""Stdin/socket driver for the simulation server.

``python -m repro.serve [--chunk-ticks 16] [--slot-widths 4,8]
[--max-in-flight 32] [--port 7351]``

Without ``--port``, speaks the JSON-lines protocol on stdin/stdout —
pipe a script of ops in, read responses out (see
``repro/serve/protocol.py`` for the op set)::

    printf '%s\n' \
      '{"op":"register_surrogate","name":"lif","train":{"circuit":"lif","n_runs":60}}' \
      '{"op":"register_spec","name":"net","snn":{"weights":[...],"params":[...]}}' \
      '{"op":"simulate","spec":"net","surrogate":"lif","stimulus_spikes":{"t":24,"b":2}}' \
      '{"op":"shutdown"}' | python -m repro.serve

With ``--port``, accepts TCP connections one at a time and runs the same
loop per connection (``shutdown`` ends the connection; Ctrl-C ends the
server). The in-process API (``lasana.serve()``) is the primary
interface; this driver exists so the service can be scripted from
anything that can write JSON to a pipe or socket.
"""

from __future__ import annotations

import argparse
import sys


def serve(args) -> dict:
    import repro.lasana as lasana
    from repro.serve.protocol import run_stdio

    widths = tuple(int(w) for w in str(args.slot_widths).split(",") if w)
    server = lasana.serve(chunk_ticks=args.chunk_ticks,
                          slot_widths=widths,
                          max_in_flight=args.max_in_flight,
                          max_queue=args.max_queue)
    handled = 0
    try:
        if args.port:
            import socket
            lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            lsock.bind((args.host, args.port))
            lsock.listen(1)
            print(f"[serve] listening on {args.host}:{args.port}",
                  file=sys.stderr)
            try:
                while True:
                    conn, peer = lsock.accept()
                    print(f"[serve] client {peer}", file=sys.stderr)
                    with conn, conn.makefile("r") as fin, \
                            conn.makefile("w") as fout:
                        handled += run_stdio(server, fin, fout)
            except KeyboardInterrupt:
                pass
            finally:
                lsock.close()
        else:
            handled = run_stdio(server, sys.stdin, sys.stdout)
    finally:
        server.close()
    stats = server.stats()
    print(f"[serve] handled {handled} ops, "
          f"{stats['requests_completed']} requests, "
          f"{stats['compile_count']} compiled programs, "
          f"occupancy {stats['batch_occupancy']:.2f}", file=sys.stderr)
    return {"handled": handled, "stats": stats}


def main():
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--chunk-ticks", type=int, default=16)
    ap.add_argument("--slot-widths", default="4",
                    help="comma ladder of batch widths, e.g. 4,8")
    ap.add_argument("--max-in-flight", type=int, default=32)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (default: stdin/stdout)")
    ap.add_argument("--host", default="127.0.0.1")
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
