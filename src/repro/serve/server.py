"""The persistent multi-tenant simulation server (LASANA-as-a-service).

:class:`SimServer` glues the serving subsystem together around one
driver thread that owns all JAX dispatch:

  * an :class:`~repro.serve.store.ArtifactStore` of named, versioned
    surrogates (register/hot-swap; in-flight requests keep the version
    they resolved at submit);
  * a canonical-spec table + the facade's bounded per-spec engine cache:
    content-equal :class:`NetworkSpec`s from different clients collapse
    onto ONE engine and its AOT program cache, so the number of compiled
    slot programs is bounded by the number of shape buckets — not by
    request count, tenant count, or surrogate versions;
  * a :class:`~repro.serve.buckets.BucketPolicy` quantizing request
    shapes, and one :class:`~repro.serve.scheduler.Lane` per (bucket,
    surrogate version, mode) continuously batching its requests;
  * admission control: a bounded submit queue (``ServerBusy``
    backpressure), a global in-flight cap, and round-robin per-tenant
    fairness so one chatty tenant cannot starve another's queue;
  * fault isolation + bounded device memory: a request the engine
    rejects at lane creation (or whose ``on_chunk`` callback raises)
    fails ITS OWN handle while the driver keeps serving everyone else,
    and lanes idle for ``lane_idle_rounds`` rounds are retired — device
    state is pinned by live work, not by every (bucket, surrogate
    version, mode) the server ever saw;
  * :class:`~repro.serve.metrics.ServerMetrics` behind :meth:`stats`.

Threading contract: ``submit``/``register_*``/``stats`` are safe from any
thread; simulation itself happens on the driver thread (``start()``) or
under the caller of ``run_until_idle()`` — never both at once.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Optional

import numpy as np

from repro.core.network import MODES, NetworkSpec
from repro.ft.watchdog import StepWatchdog
from repro.serve.buckets import BucketPolicy, spec_content_key
from repro.serve.metrics import ServerMetrics
from repro.serve.scheduler import Lane, RequestHandle
from repro.serve.store import ArtifactStore


class ServerBusy(RuntimeError):
    """Backpressure: the submit queue is at capacity — retry later."""


class DeadlineExceeded(RuntimeError):
    """The request's ``deadline_ms`` expired before it could be seated.

    Raised from ``handle.result()``. Expiry is checked at admission (and
    re-checked on every retry requeue), so an expired request fails fast
    in the queue — it never occupies a lane slot, and never displaces
    work that can still meet its own deadline."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Server shape/capacity knobs (see docs/serving.md).

    slot_widths     batch-width ladder of the bucket policy
    chunk_ticks     continuous-batching quantum (join/leave granularity)
    max_in_flight   seated (admitted, unfinished) request cap
    max_queue       submit-queue cap beyond which submit raises
                    :class:`ServerBusy`
    record_hidden   keep per-layer spike traces in request records
                    (parity tests); default off — serving unbounded
                    streams of hidden traces defeats bounded memory
    poll_seconds    driver-thread sleep when idle
    lane_idle_rounds  scheduling rounds a lane may sit with no active
                    requests before it is retired, freeing its
                    device-resident carries and surrogate banks (compiled
                    programs stay cached on the engine, so a later
                    request for the same key re-creates the lane with
                    zero recompiles) — without retirement every (bucket,
                    surrogate version, mode) ever served would pin device
                    memory forever

    Resilience knobs (see docs/resilience.md):

    default_deadline_ms  per-request deadline when ``submit`` gives none;
                    None = requests wait in queue indefinitely
    max_retries     default re-admission budget after a recoverable fault
                    (lane-step failure, NaN/Inf quarantine); a retried
                    request replays from scratch so its merged record is
                    exact. 0 = any fault is terminal for the request
    retry_backoff_ms  delay before a faulted request may be re-admitted,
                    doubled per attempt (the queue is never slept on —
                    the request is simply skipped until its time)
    degrade_after   surrogate faults on one spec before NEW admissions of
                    that spec fall back to the behavioral backend
                    (``handle.degraded`` + ``/stats`` flag them); None
                    disables degradation
    hang_timeout_s  watchdog limit on one lane step; a step exceeding it
                    fails the lane's requests and drops the lane while
                    the server keeps serving. None disables the watchdog
    """

    slot_widths: tuple = (4,)
    chunk_ticks: int = 16
    max_in_flight: int = 32
    max_queue: int = 256
    record_hidden: bool = False
    poll_seconds: float = 0.01
    lane_idle_rounds: int = 50
    default_deadline_ms: Optional[float] = None
    max_retries: int = 0
    retry_backoff_ms: float = 10.0
    degrade_after: Optional[int] = 3
    hang_timeout_s: Optional[float] = None


class _Queued:
    """A submitted-but-not-yet-seated request."""

    def __init__(self, handle, spec_key, spec, stimulus, surrogates,
                 sur_token, mode, *, deadline=None, retries_left=0,
                 backoff_s=0.0):
        self.handle = handle
        self.spec_key = spec_key
        self.spec = spec
        self.stimulus = stimulus
        self.surrogates = surrogates
        self.sur_token = sur_token      # lane-identity of the artifact
        self.mode = mode
        self.deadline = deadline        # monotonic seconds, or None
        self.retries_left = retries_left
        self.backoff_s = backoff_s      # next retry delay (doubles)
        self.not_before = 0.0           # monotonic gate after a requeue


class SimServer:
    """Persistent simulation server over the slot-program engine layer."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config or ServeConfig()
        self.policy = BucketPolicy(slot_widths=self.config.slot_widths,
                                   chunk_ticks=self.config.chunk_ticks)
        self.store = ArtifactStore()
        self.metrics = ServerMetrics()
        self._lock = threading.Lock()          # queues + tables
        self._wake = threading.Condition(self._lock)
        self._queues: dict = collections.OrderedDict()  # tenant -> deque
        self._specs: dict = {}                 # spec_key -> canonical spec
        self._spec_names: dict = {}            # name -> canonical spec
        self._lanes: dict = {}                 # lane key -> Lane
        self._in_flight = 0                    # seated, unfinished
        self._next_id = 0
        self._fault_counts: dict = {}          # spec_key -> surrogate faults
        self._degraded: set = set()            # spec_keys on the fallback
        self._hung: set = set()                # lane keys killed by watchdog
        self._stepping_lane = None             # lane key inside lane.step()
        self._step_count = 0                   # watchdog step generation
        self._watchdog = None
        if self.config.hang_timeout_s is not None:
            self._watchdog = StepWatchdog(
                hang_timeout=self.config.hang_timeout_s,
                on_hang=self._on_hang)
        self._thread = None
        self._stop = threading.Event()
        self._closed = False

    # --- registration ---------------------------------------------------------

    def register_surrogate(self, name: str, surrogate, *,
                           version=None) -> int:
        """Store a surrogate under ``name``; returns its new version."""
        return self.store.register(name, surrogate, version=version)

    def register_surrogate_path(self, name: str, path: str, *,
                                version=None) -> int:
        """Register an on-disk artifact lazily; returns its new version.

        The file is read on first resolve, not here — a truncated or
        corrupt artifact fails only the request that forced the load
        (with :class:`~repro.serve.store.ArtifactError`), never the
        registration or the server."""
        return self.store.register_path(name, path, version=version)

    def register_spec(self, name: str, spec: NetworkSpec) -> str:
        """Name a spec for by-reference submission (wire protocol)."""
        with self._lock:
            self._spec_names[name] = self._canonical(spec)
        return spec_content_key(spec)

    def _canonical(self, spec: NetworkSpec):
        """Collapse content-equal specs onto one engine-owning object."""
        key = spec_content_key(spec)
        return self._specs.setdefault(key, spec)

    def spec(self, name: str):
        """The :meth:`register_spec`-registered spec, or None.

        The server-side registry outlives wire connections: a client that
        reconnects can keep submitting against names registered earlier."""
        with self._lock:
            return self._spec_names.get(name)

    # --- submission -----------------------------------------------------------

    def submit(self, spec, stimulus, *, surrogates, tenant: str = "default",
               mode: str = "standalone", on_chunk=None,
               deadline_ms: Optional[float] = None,
               max_retries: Optional[int] = None) -> RequestHandle:
        """Queue one simulation request; returns its handle immediately.

        spec        a :class:`NetworkSpec` or the name of a
                    :meth:`register_spec`-registered one
        stimulus    (T, B, fan_in) drive in the first layer's native
                    units ((B, fan_in) promotes to one tick)
        surrogates  a store ref (``"name"`` = latest, ``"name@ver"`` =
                    pinned) or a direct surrogate object
        tenant      fairness domain: queued requests are admitted
                    round-robin across tenants, FIFO per lane within
                    one (a full lane never blocks queued requests
                    bound for other lanes)
        on_chunk    optional callback fired (from the driver thread) per
                    streamed chunk record
        deadline_ms admission deadline: if the request is still queued
                    when it expires, it fails fast with
                    :class:`DeadlineExceeded` and never takes a slot
                    (default: ``config.default_deadline_ms``)
        max_retries re-admissions allowed after a recoverable fault; a
                    retried request replays from scratch, so its merged
                    record is exact (default: ``config.max_retries``)

        Raises :class:`ServerBusy` when the queue is full (backpressure)
        and ``ValueError`` for malformed requests — both synchronously,
        never parked on the queue."""
        if self._closed:
            raise RuntimeError("server is closed")
        if isinstance(spec, str):
            with self._lock:
                got = self._spec_names.get(spec)
            if got is None:
                raise KeyError(f"no spec registered under {spec!r}")
            spec = got
        x = np.asarray(stimulus, np.float32)
        if x.ndim == 2:
            x = x[None]
        if x.ndim != 3:
            raise ValueError(f"stimulus must be (T, B, n_in) or (B, n_in), "
                             f"got shape {tuple(x.shape)}")
        if x.shape[-1] != spec.layers[0].fan_in:
            raise ValueError(f"input width {x.shape[-1]} != layer-0 "
                             f"fan_in {spec.layers[0].fan_in}")
        if mode not in MODES:                  # engine() would reject it on
            raise ValueError(                  # the driver thread otherwise
                f"mode must be one of {MODES}: {mode}")
        self.policy.width_for(x.shape[1])      # reject oversize batches now
        if isinstance(surrogates, str):
            ref, sur = self.store.resolve(surrogates)
            sur_token = ref                     # (name, version)
        else:
            sur, sur_token = surrogates, ("<direct>", id(surrogates))
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be positive: {deadline_ms}")
        deadline = (None if deadline_ms is None
                    else time.monotonic() + deadline_ms / 1000.0)
        if max_retries is None:
            max_retries = self.config.max_retries

        with self._lock:
            depth = sum(len(q) for q in self._queues.values())
            if depth >= self.config.max_queue:
                self.metrics.add(requests_rejected=1)
                raise ServerBusy(
                    f"submit queue full ({depth}/{self.config.max_queue})")
            self._next_id += 1
            handle = RequestHandle(self._next_id, tenant,
                                   on_chunk=on_chunk)
            handle.surrogate_ref = sur_token
            spec_c = self._canonical(spec)
            self._queues.setdefault(tenant, collections.deque()).append(
                _Queued(handle, spec_content_key(spec_c), spec_c, x, sur,
                        sur_token, mode, deadline=deadline,
                        retries_left=int(max_retries),
                        backoff_s=self.config.retry_backoff_ms / 1000.0))
            self.metrics.add(requests_submitted=1)
            self._wake.notify_all()
        return handle

    # --- scheduling -----------------------------------------------------------

    def _lane_for(self, q: _Queued) -> Lane:
        """The (existing or new) lane serving one queued request.

        Engine resolution and lane construction — which may AOT-compile
        for seconds on first touch — run WITHOUT the server lock, so
        submitters and stats readers never stall behind a compile; only
        the lane-table lookups take the lock. The lane keeps a strong
        reference to the surrogate object (``Lane.surrogates``), so a
        directly-passed surrogate's ``id()`` — part of the lane key —
        cannot be recycled onto a different object while the key is
        live; retirement drops the key and the reference together."""
        import repro.lasana as lasana
        bucket = self.policy.bucket_for(q.spec_key, q.stimulus.shape[1])
        with self._lock:
            # graceful degradation: once a spec has burned through its
            # surrogate-fault budget, NEW admissions go to a behavioral-
            # backend lane (annotation substrate, no surrogate) — the
            # flag is part of the lane key so degraded and healthy lanes
            # never share carries or programs
            degraded = q.spec_key in self._degraded
        key = (bucket.key, q.sur_token, q.mode, degraded)
        with self._lock:
            lane = self._lanes.get(key)
        if lane is None:
            if degraded:
                eng = lasana.engine(
                    q.spec, backend="behavioral", mode=q.mode,
                    record_hidden=self.config.record_hidden)
                lane = Lane(eng, q.spec, bucket, None,
                            metrics=self.metrics)
            else:
                eng = lasana.engine(
                    q.spec, mode=q.mode,
                    record_hidden=self.config.record_hidden)
                lane = Lane(eng, q.spec, bucket, q.surrogates,
                            metrics=self.metrics)
            lane.sur_token = q.sur_token
            with self._lock:
                lane = self._lanes.setdefault(key, lane)
        return lane

    def _admit(self) -> bool:
        """One round-robin admission sweep across tenant queues.

        A request whose lane is full does NOT block the requests queued
        behind it that target OTHER lanes (classic head-of-line blocking
        would cap occupancy across a mixed-bucket workload); once a lane
        rejects, later same-tenant requests for that lane are skipped
        too, so per-lane FIFO order within a tenant is preserved.

        A request whose LANE CREATION fails (e.g. a directly-passed
        surrogate the engine rejects — submit cannot validate those
        cheaply) fails ITS OWN handle and the sweep continues: one bad
        request must never kill the driver thread or other tenants'
        work. The lock is dropped around :meth:`_lane_for` (first-touch
        compiles run unlocked; admission itself is driver-thread-only,
        other threads only append to queues)."""
        admitted = False
        with self._lock:
            tenants = list(self._queues)
        for tenant in tenants:
            blocked: set = set()           # lanes that rejected this sweep
            skipped: list = []
            while True:
                with self._lock:
                    queue = self._queues.get(tenant)
                    if (not queue
                            or self._in_flight >= self.config.max_in_flight):
                        break
                    q = queue.popleft()
                now = time.monotonic()
                if q.deadline is not None and now > q.deadline:
                    # fail fast IN the queue: an expired request never
                    # takes a slot from work that can still make it
                    self.metrics.add(requests_failed=1,
                                     requests_deadline_exceeded=1)
                    q.handle._fail(DeadlineExceeded(
                        f"request {q.handle.id} missed its deadline "
                        f"after {q.handle.wait_chunks} queued rounds"))
                    continue
                if q.not_before > now:
                    skipped.append(q)      # retry backoff: not yet — the
                    continue               # sweep never sleeps on it
                try:
                    lane = self._lane_for(q)
                except Exception as err:   # per-request failure, contained
                    self.metrics.add(requests_failed=1)
                    q.handle._fail(err)
                    continue
                if id(lane) in blocked or not lane.admit(q):
                    blocked.add(id(lane))
                    skipped.append(q)
                    continue
                lane.idle_rounds = 0
                with self._lock:
                    self._in_flight += 1
                admitted = True
            with self._lock:
                if skipped:
                    queue = self._queues.setdefault(tenant,
                                                    collections.deque())
                    queue.extendleft(reversed(skipped))
                elif not self._queues.get(tenant):
                    self._queues.pop(tenant, None)
        with self._lock:
            # rotate start tenant so admission order is fair over rounds
            if self._queues:
                first = next(iter(self._queues))
                self._queues.move_to_end(first)
                for q in [r for dq in self._queues.values() for r in dq]:
                    q.handle.wait_chunks += 1
                    self.metrics.note_wait(q.handle.wait_chunks)
        return admitted

    def _requeue(self, q: _Queued, error: Exception) -> bool:
        """Give a faulted request another attempt, if budget remains.

        Clears the handle's partial chunks (a retry replays the request
        from scratch, so the merged record stays exact), arms the
        exponential backoff gate, and puts the request back at the FRONT
        of its tenant's queue — bypassing ``max_queue``, which governs
        NEW work, not work the server already accepted. With the retry
        budget exhausted the handle fails with ``error``; returns whether
        the request was requeued."""
        if q.retries_left <= 0:
            self.metrics.add(requests_failed=1)
            q.handle._fail(error)
            return False
        q.retries_left -= 1
        q.handle._reset_for_retry()
        q.not_before = time.monotonic() + q.backoff_s
        q.backoff_s *= 2.0
        with self._lock:
            self._queues.setdefault(q.handle.tenant,
                                    collections.deque()).appendleft(q)
        self.metrics.add(requests_retried=1)
        return True

    def _note_fault(self, spec_key: str):
        """Count one surrogate fault against a spec; trip degradation.

        At ``degrade_after`` faults the spec key joins ``_degraded``:
        from then on NEW admissions of that spec build behavioral-backend
        lanes (see :meth:`_lane_for`) — results stay available, flagged
        ``degraded`` on handles and in ``/stats``."""
        after = self.config.degrade_after
        with self._lock:
            n = self._fault_counts.get(spec_key, 0) + 1
            self._fault_counts[spec_key] = n
            if after is not None and n >= after:
                self._degraded.add(spec_key)

    def _on_hang(self):
        """Watchdog callback (timer thread): a lane step blew past
        ``hang_timeout_s``. Fail the hung lane's requests and drop the
        lane NOW so their waiters unblock; the driver thread — still
        stuck inside ``lane.step`` — finds the key in ``_hung`` when
        (if) the step finally returns and discards its results."""
        key = self._stepping_lane       # driver-write field; a racy read
        if key is None:                 # at worst misses one borderline
            return                      # hang, never fingers a wrong lane
        with self._lock:
            lane = self._lanes.pop(key, None)
            if lane is None:
                return
            self._hung.add(key)
            actives = list(lane.active)
            self._in_flight -= len(actives)
            self._wake.notify_all()
        # poison before failing handles: if the stuck step eventually
        # limps home it must push no records and count no completions
        # (the requests below are already failed)
        lane._poison.set()
        self.metrics.add(lane_hangs=1, requests_failed=len(actives))
        for a in actives:
            a.handle._fail(RuntimeError(
                f"request {a.handle.id} failed by the watchdog: lane "
                f"step exceeded hang_timeout_s="
                f"{self.config.hang_timeout_s}"))

    def step(self) -> bool:
        """One scheduling round: admit, advance live lanes, retire idle.

        Returns True when any work happened — the driver loop (or an
        external caller in un-threaded mode) idles when it returns
        False. A lane whose step fails mid-chunk has corrupted carries
        for everyone seated in it: its requests are requeued for a fresh
        attempt (or failed once out of retries) and the lane is dropped,
        but OTHER lanes (and the driver) keep serving. Requests the
        NaN/Inf sentinel quarantined follow the same retry path, and
        count toward their spec's degradation budget. A lane idle for
        ``lane_idle_rounds`` consecutive rounds is retired, releasing
        its device-resident carries and banks; the engine's compiled
        programs survive, so re-creation is compile-free."""
        worked = self._admit()
        with self._lock:
            lanes = list(self._lanes.items())
        retired: list = []
        for key, lane in lanes:
            if not lane.active:
                lane.idle_rounds += 1
                if lane.idle_rounds >= self.config.lane_idle_rounds:
                    retired.append(key)
                continue
            lane.idle_rounds = 0
            hung = False
            try:
                try:
                    if self._watchdog is not None:
                        self._stepping_lane = key
                        self._watchdog.step_begin()
                    stats = lane.step()
                finally:
                    if self._watchdog is not None:
                        self._step_count += 1
                        self._watchdog.step_end(self._step_count)
                        self._stepping_lane = None
                    with self._lock:
                        hung = key in self._hung
                        self._hung.discard(key)
            except Exception as err:       # lane poisoned, server survives
                if hung:                   # watchdog already failed these
                    worked = True          # requests and dropped the lane
                    continue
                actives = list(lane.active)
                with self._lock:
                    self._in_flight -= len(actives)
                    self._lanes.pop(key, None)
                    self._wake.notify_all()
                for a in actives:
                    self._requeue(a.q, err)
                continue
            if hung:
                worked = True              # results of a hung step are
                continue                   # dead: requests already failed
            if stats:
                worked = True
                with self._lock:
                    self._in_flight -= (stats["completed"]
                                        + len(stats["quarantined"]))
                    if stats["completed"]:
                        self._wake.notify_all()
                for a in stats["quarantined"]:
                    self._note_fault(a.q.spec_key)
                    self._requeue(a.q, RuntimeError(
                        f"request {a.handle.id}: non-finite surrogate "
                        "outputs (NaN/Inf burst) quarantined by the "
                        "lane sentinel"))
        if retired:
            with self._lock:
                for key in retired:
                    if self._lanes.pop(key, None) is not None:
                        self.metrics.add(lanes_retired=1)
        return worked

    def run_until_idle(self, *, max_rounds: int = 100000) -> None:
        """Drive scheduling on the CALLING thread until no work remains."""
        if self._thread is not None:
            raise RuntimeError("driver thread is running; use handles "
                               "or stats() instead")
        for _ in range(max_rounds):
            if not self.step():
                with self._lock:
                    if not self._queues and self._in_flight == 0:
                        return
        raise RuntimeError(f"not idle after {max_rounds} rounds")

    # --- lifecycle ------------------------------------------------------------

    def start(self) -> "SimServer":
        """Spawn the driver thread (idempotent); returns self."""
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._drive,
                                            name="lasana-serve",
                                            daemon=True)
            self._thread.start()
        return self

    def _drive(self):
        while not self._stop.is_set():
            try:
                worked = self.step()
            except Exception as err:        # fail loudly per request
                self._fail_all(err)
                raise
            if not worked:
                # also parks when queued work is only backoff-gated
                # retries: submissions and completions notify _wake, so
                # the wait never delays genuinely admissible work
                with self._wake:
                    self._wake.wait(self.config.poll_seconds)

    def _fail_all(self, err: Exception):
        with self._lock:
            for queue in self._queues.values():
                for q in queue:
                    q.handle._fail(err)
            self._queues.clear()
            for lane in self._lanes.values():
                for a in list(lane.active):
                    a.handle._fail(err)

    def close(self, *, drain: bool = True, timeout: float = 60.0):
        """Stop the driver thread; ``drain`` finishes in-flight work."""
        if drain and self._thread is not None:
            import time as _time
            deadline = _time.time() + timeout
            while _time.time() < deadline and self._thread.is_alive():
                with self._lock:
                    if not self._queues and self._in_flight == 0:
                        break
                _time.sleep(0.005)
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        self._closed = True

    def __enter__(self) -> "SimServer":
        return self

    def __exit__(self, *exc):
        self.close(drain=exc[0] is None)

    # --- observability --------------------------------------------------------

    def compile_count(self) -> int:
        """Compiled tick-scan programs across the server's engines."""
        with self._lock:
            engines = {id(l.engine): l.engine for l in self._lanes.values()}
        return sum(e.compile_count for e in engines.values())

    def stats(self) -> dict:
        """The ``/stats`` report: counters, rates, queues, lanes."""
        with self._lock:
            by_bucket: dict = {}
            for queue in self._queues.values():
                for q in queue:
                    b = self.policy.bucket_for(q.spec_key,
                                               q.stimulus.shape[1])
                    name = f"{b.spec_key[:8]}/w{b.width}/c{b.chunk_ticks}"
                    by_bucket[name] = by_bucket.get(name, 0) + 1
            lanes = [{
                "bucket": f"{l.bucket.spec_key[:8]}/w{l.width}"
                          f"/c{l.chunk_ticks}",
                "surrogate": str(getattr(l, "sur_token", key[1])),
                "occupancy": l.occupancy,
                "active_requests": len(l.active),
                "global_tick": l.g,
                "degraded": l.degraded,
            } for key, l in self._lanes.items()]
            degraded_specs = sorted(self._degraded)
        out = self.metrics.snapshot(queue_depth_by_bucket=by_bucket,
                                    lanes=lanes)
        out["degraded_specs"] = degraded_specs
        out["compile_count"] = self.compile_count()
        out["n_lanes"] = len(lanes)
        out["surrogates"] = {n: self.store.versions(n)
                             for n in self.store.names()}
        return out
