"""Server observability: counters behind the ``/stats`` report.

One :class:`ServerMetrics` per server, updated by the scheduler under its
own lock (cheap increments; never holds up JAX dispatch). ``snapshot()``
freezes the counters plus the derived rates — requests/s, events/s, mean
batch occupancy, compile vs steady seconds — into the plain dict that
``SimServer.stats()``, the wire protocol's ``stats`` op, and
``benchmarks/bench_serve.py`` all report.
"""

from __future__ import annotations

import threading
import time


class ServerMetrics:
    """Thread-safe counter block for one server instance."""

    def __init__(self):
        self._lock = threading.Lock()
        self.started_at = time.time()
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_rejected = 0      # backpressure (ServerBusy)
        self.requests_failed = 0        # per-request errors after submit
        self.requests_retried = 0       # re-queued after a recoverable fault
        self.requests_deadline_exceeded = 0   # expired in queue (subset of
                                        # requests_failed: every expiry is
                                        # terminal)
        self.requests_degraded = 0      # completed on the behavioral
                                        # fallback (subset of completed)
        self.numerical_faults = 0       # NaN/Inf bursts quarantined
        self.lane_hangs = 0             # watchdog-detected hung lane steps
        self.lanes_retired = 0          # idle lanes freed (or poisoned)
        self.chunks_total = 0           # lane steps executed
        self.ticks_live_total = 0       # live slot-ticks simulated
        self.events_total = 0           # input events across all tenants
        self.compile_seconds = 0.0      # slot program + engine compiles
        self.steady_seconds = 0.0       # lane-step execute + fetch wall
        self.occupancy_sum = 0.0        # live-tick fraction per lane step
        self.wait_chunks_max = 0        # worst queue wait (chunk rounds)

    def add(self, **deltas):
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def note_wait(self, wait_chunks: int):
        with self._lock:
            self.wait_chunks_max = max(self.wait_chunks_max, wait_chunks)

    def snapshot(self, *, queue_depth_by_bucket=None, lanes=None) -> dict:
        """The ``/stats`` report (see docs/serving.md "Observability")."""
        with self._lock:
            wall = max(time.time() - self.started_at, 1e-9)
            chunks = max(self.chunks_total, 1)
            out = {
                "uptime_seconds": wall,
                "requests_submitted": self.requests_submitted,
                "requests_completed": self.requests_completed,
                "requests_rejected": self.requests_rejected,
                "requests_failed": self.requests_failed,
                "requests_retried": self.requests_retried,
                "requests_deadline_exceeded":
                    self.requests_deadline_exceeded,
                "requests_degraded": self.requests_degraded,
                # derived, never stored: every submitted request ends in
                # exactly one of completed/failed (retries are neither —
                # the request stays in flight), so this cannot go
                # negative while that accounting holds (tested in
                # tests/test_serve.py)
                "requests_in_flight": (self.requests_submitted
                                       - self.requests_completed
                                       - self.requests_failed),
                "requests_per_sec": self.requests_completed / wall,
                "numerical_faults": self.numerical_faults,
                "lane_hangs": self.lane_hangs,
                "lanes_retired": self.lanes_retired,
                "chunks_total": self.chunks_total,
                "ticks_live_total": self.ticks_live_total,
                "events_total": self.events_total,
                "events_per_sec": self.events_total / wall,
                "batch_occupancy": self.occupancy_sum / chunks,
                "compile_seconds": self.compile_seconds,
                "steady_seconds": self.steady_seconds,
                "wait_chunks_max": self.wait_chunks_max,
            }
        out["queue_depth_by_bucket"] = dict(queue_depth_by_bucket or {})
        out["lanes"] = list(lanes or [])
        return out
