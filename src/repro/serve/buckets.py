"""Request shape-bucketing for the simulation server.

The server's compiled-program budget is the heart of its cost model: every
distinct (spec structure, batch width, chunk ticks) triple is one AOT
compile, and everything else — request count, stimulus lengths, surrogate
versions, tenants — must map onto that bounded set. Two pieces implement
the quantization:

:func:`spec_content_key`
    a stable digest of a :class:`NetworkSpec`'s full CONTENT (layer kinds,
    shapes, knobs, weight/param/edge values, spike amplitude). Layer
    weights are baked into the compiled cascade as closure constants, so
    two specs share a program only when their values match — identity
    (``id(spec)``) is the wrong equivalence because clients rebuild
    structurally-equal specs per request. The server keeps ONE canonical
    spec object (and therefore one facade engine + program cache) per
    content key.

:class:`BucketPolicy`
    quantizes a request's batch size onto a small ladder of slot widths
    (the compiled batch axis) and fixes the chunk length all requests
    stream in. A bucket — :class:`Bucket`, ``(spec_key, width,
    chunk_ticks)`` — names one compiled slot-program family; requests in
    the same bucket co-batch along its slot axis regardless of their
    stimulus length, which is handled by per-slot live masks inside the
    program (see ``NetworkEngine.slot_programs``).
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


def spec_content_key(spec) -> str:
    """Stable hex digest of a :class:`NetworkSpec`'s structure AND values.

    Everything the compiled network program bakes in as constants
    participates: per-layer circuit kind, crossbar knobs, weight and
    param values, every edge, and the spike amplitude. Equal keys imply
    the specs compile to interchangeable programs (one canonical engine
    serves both); unequal keys get separate buckets."""
    h = hashlib.sha1()
    for layer in spec.layers:
        h.update(repr((layer.circuit, layer.seg_width, layer.adc_bits,
                       layer.activation,
                       tuple(np.shape(layer.weight)))).encode())
        h.update(np.ascontiguousarray(
            np.asarray(layer.weight, np.float32)).tobytes())
        if layer.params is not None:
            h.update(np.ascontiguousarray(
                np.asarray(layer.params, np.float32)).tobytes())
    for edge in spec.edges:
        h.update(repr((edge.src, edge.dst,
                       tuple(np.shape(edge.weight)))).encode())
        h.update(np.ascontiguousarray(
            np.asarray(edge.weight, np.float32)).tobytes())
    h.update(np.float32(spec.spike_amp).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One compiled-program class: requests in the same bucket co-batch."""

    spec_key: str          # spec_content_key of the canonical spec
    width: int             # slot count = the program's batch axis
    chunk_ticks: int       # ticks per scheduling round

    @property
    def key(self) -> tuple:
        return (self.spec_key, self.width, self.chunk_ticks)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """How heterogeneous requests quantize onto compiled programs.

    slot_widths   ascending ladder of batch widths; a request with batch
                  ``b`` lands in the smallest width >= b (requests wider
                  than the ladder's top are rejected at submit — they
                  would mint an unbounded program per odd batch size)
    chunk_ticks   the continuous-batching quantum: every request streams
                  in ``chunk_ticks``-tick chunks and joins/leaves only at
                  chunk boundaries; stimulus lengths that are not a
                  multiple ride the per-slot live mask (dead padding ticks
                  are frozen, not simulated)
    """

    slot_widths: tuple = (4,)
    chunk_ticks: int = 16

    def __post_init__(self):
        widths = tuple(sorted(int(w) for w in self.slot_widths))
        if not widths or widths[0] < 1:
            raise ValueError(f"slot_widths must be positive: "
                             f"{self.slot_widths}")
        if self.chunk_ticks < 1:
            raise ValueError(f"chunk_ticks must be positive: "
                             f"{self.chunk_ticks}")
        object.__setattr__(self, "slot_widths", widths)

    @property
    def max_width(self) -> int:
        return self.slot_widths[-1]

    def width_for(self, batch: int) -> int:
        """Smallest ladder width that fits a ``batch``-wide request."""
        for w in self.slot_widths:
            if batch <= w:
                return w
        raise ValueError(
            f"request batch {batch} exceeds the widest slot bucket "
            f"{self.max_width}; widen BucketPolicy.slot_widths or split "
            "the request")

    def bucket_for(self, spec_key: str, batch: int) -> Bucket:
        return Bucket(spec_key=spec_key, width=self.width_for(batch),
                      chunk_ticks=self.chunk_ticks)
