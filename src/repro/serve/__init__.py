"""LASANA-as-a-service: persistent multi-tenant simulation serving.

The serving layer over the surrogate network engine (docs/serving.md):
a long-lived :class:`SimServer` owning a versioned surrogate
:class:`ArtifactStore`, a bounded compiled-program cache quantized by
:class:`BucketPolicy` shape buckets, and a continuous-batching scheduler
(:mod:`repro.serve.scheduler`) that packs concurrent requests along the
batch axis of one compiled slot program — requests join/leave at chunk
boundaries, per-slot masks keep every tenant's records exactly what a
solo ``lasana.simulate`` would produce, and partial records stream back
per chunk. ``lasana.serve()`` is the facade entry; ``python -m
repro.serve`` is the stdin/socket driver.
"""

from repro.serve.buckets import Bucket, BucketPolicy, spec_content_key
from repro.serve.metrics import ServerMetrics
from repro.serve.protocol import run_stdio
from repro.serve.scheduler import Lane, RequestHandle
from repro.serve.server import (DeadlineExceeded, ServeConfig, ServerBusy,
                                SimServer)
from repro.serve.store import ArtifactError, ArtifactStore

__all__ = [
    "ArtifactError",
    "ArtifactStore",
    "Bucket",
    "BucketPolicy",
    "DeadlineExceeded",
    "Lane",
    "RequestHandle",
    "ServeConfig",
    "ServerBusy",
    "ServerMetrics",
    "SimServer",
    "run_stdio",
    "spec_content_key",
]
