"""train_step / serve_step factories.

``make_train_step`` builds a jit-able ``(state, batch) -> (state, metrics)``
with microbatched gradient accumulation (lax.scan over microbatches keeps
the HLO O(1) in accumulation steps) and the sharding contract derived from
the logical rule table. ``make_decode_step``/``make_prefill`` build the
serving counterparts.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import params as prm
from repro.models.model import Model
from repro.optim import AdamW


# --- train state -------------------------------------------------------------

def init_train_state(model: Model, optimizer: AdamW, key):
    params = model.init(key)
    return {"step": jnp.zeros((), jnp.int32), "params": params,
            "opt": optimizer.init(params)}


def abstract_train_state(model: Model, optimizer: AdamW):
    ap = model.abstract_params()
    return {"step": jax.ShapeDtypeStruct((), jnp.int32), "params": ap,
            "opt": optimizer.init_abstract(ap)}


def train_state_shardings(model: Model, optimizer: AdamW, mesh: Mesh,
                          rules: shd.ShardingRules):
    pshard = prm.shardings(model.param_specs(), mesh, rules)
    opt = {"m": pshard, "v": pshard}
    if optimizer.cfg.compress_grads:
        opt["err"] = pshard
    return {"step": NamedSharding(mesh, P()), "params": pshard, "opt": opt}


# --- microbatching -------------------------------------------------------------
#
# Gradient-accumulation batches arrive microbatch-major: every leaf is
# (M, B/M, ...) with the *second* dim sharded over dp. (A post-hoc reshape of
# a dp-sharded (B, ...) cannot keep rows local — XLA replicates — so the
# data pipeline deals microbatch slices directly; see data/lm_data.py.)


def make_train_step(model: Model, optimizer: AdamW, *,
                    num_microbatches: int = 1, n_moe_groups: int = 1,
                    donate: bool = True):
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit outside."""

    def loss_fn(params, mb):
        return model.loss(params, mb, n_moe_groups=n_moe_groups)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = batch   # leaves already (M, B/M, ...)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, jnp.zeros((), jnp.float32)), mbs)
            inv = 1.0 / num_microbatches
            grads = jax.tree.map(lambda g: (g * inv).astype(jnp.bfloat16), grads)
            loss = loss_sum * inv
            metrics = {"loss": loss}
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state["opt"], params, state["step"])
        metrics = {**metrics, **opt_metrics}
        new_state = {"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}
        return new_state, {k: v for k, v in metrics.items()
                           if jnp.asarray(v).ndim == 0}

    return train_step


def _tree_shardings(logical_tree, spec_tree, mesh, rules):
    """Shape-aware shardings for a (logical, ShapeDtypeStruct) tree pair."""
    return jax.tree.map(
        lambda lg, sp: rules.sharding(mesh, lg, sp.shape),
        logical_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def jit_train_step(model: Model, optimizer: AdamW, mesh: Mesh,
                   rules: shd.ShardingRules, shape: ShapeConfig, *,
                   n_moe_groups: int = 1):
    """jit with explicit in/out shardings for the production mesh."""
    step = make_train_step(model, optimizer,
                           num_microbatches=shape.num_microbatches,
                           n_moe_groups=n_moe_groups)
    st_sh = train_state_shardings(model, optimizer, mesh, rules)
    batch_sh = _tree_shardings(model.input_logical(shape),
                               model.input_specs(shape), mesh, rules)
    metric_sh = None  # replicated scalars
    return jax.jit(step,
                   in_shardings=(st_sh, batch_sh),
                   out_shardings=(st_sh, metric_sh),
                   donate_argnums=(0,))


# --- serving -----------------------------------------------------------------------

def make_decode_step(model: Model):
    def serve_step(params, cache, tokens):
        return model.decode(params, cache, tokens)
    return serve_step


def cache_shardings(model: Model, mesh: Mesh, rules: shd.ShardingRules,
                    batch: int, max_seq: int):
    logical = model.cache_logical()
    specs = model.cache_specs(batch, max_seq)
    return _tree_shardings(logical, specs, mesh, rules)


def jit_decode_step(model: Model, mesh: Mesh, rules: shd.ShardingRules,
                    shape: ShapeConfig):
    step = make_decode_step(model)
    pshard = prm.shardings(model.param_specs(), mesh, rules)
    b, s = shape.global_batch, shape.seq_len
    csh = cache_shardings(model, mesh, rules, b, s)
    tok_sh = rules.sharding(mesh, ("batch", None), (b, 1))
    logit_sh = rules.sharding(mesh, ("batch", None, "vocab"),
                              (b, 1, model.cfg.vocab))
    return jax.jit(step,
                   in_shardings=(pshard, csh, tok_sh),
                   out_shardings=(logit_sh, csh),
                   donate_argnums=(1,))


def make_prefill(model: Model, *, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq=max_seq)
    return prefill_step


def jit_prefill(model: Model, mesh: Mesh, rules: shd.ShardingRules,
                shape: ShapeConfig):
    step = make_prefill(model, max_seq=shape.seq_len)
    pshard = prm.shardings(model.param_specs(), mesh, rules)
    batch_sh = _tree_shardings(model.input_logical(shape),
                               model.input_specs(shape), mesh, rules)
    b = shape.global_batch
    csh = cache_shardings(model, mesh, rules, b, shape.seq_len)
    cache_out_sh = {"stacks": csh["stacks"], "pos": csh["pos"]}
    logit_sh = rules.sharding(mesh, ("batch", None, "vocab"),
                              (b, 1, model.cfg.vocab))
    return jax.jit(step, in_shardings=(pshard, batch_sh),
                   out_shardings=(logit_sh, cache_out_sh))
