"""Mistral-Large-123B — dense GQA decoder
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""

from repro.configs.base import AttentionKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family=Family.DENSE,
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    attention=AttentionKind.GQA,
    d_head=128,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-reduced",
        family=Family.DENSE,
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=224,
        vocab=128,
        attention=AttentionKind.GQA,
        d_head=16,
        rope_theta=1e6,
    )
