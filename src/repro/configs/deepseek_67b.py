"""DeepSeek-67B — dense llama-arch GQA decoder [arXiv:2401.02954; hf]."""

from repro.configs.base import AttentionKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family=Family.DENSE,
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    attention=AttentionKind.GQA,
    rope_theta=1e4,
    source="arXiv:2401.02954; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-reduced",
        family=Family.DENSE,
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=172,
        vocab=128,
        attention=AttentionKind.GQA,
    )
