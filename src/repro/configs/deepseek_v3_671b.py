"""DeepSeek-V3 671B — MLA + fine-grained MoE (1 shared + 256 routed, top-8),
aux-loss-free sigmoid routing, MTP head [arXiv:2412.19437; hf]."""

from repro.configs.base import AttentionKind, Family, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family=Family.MOE,
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                       # dense layers' hidden dim
    vocab=129280,
    attention=AttentionKind.MLA,
    d_head=128,
    rope_theta=1e4,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        capacity_factor=1.25,
        router="sigmoid",             # aux-loss-free bias routing
        first_dense=3,                # first 3 layers are dense in DS-V3
    ),
    mtp_depth=1,
    source="arXiv:2412.19437; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced",
        family=Family.MOE,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=160,
        attention=AttentionKind.MLA,
        d_head=16,
        mla=MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            n_shared=1,
            d_ff_expert=32,
            router="sigmoid",
            first_dense=1,
        ),
        mtp_depth=1,
    )
