"""Assigned input-shape suites (same four for every LM arch).

``train_*``   -> lowers train_step
``prefill_*`` -> lowers serve prefill
``decode_*``/``long_*`` -> lower serve_step: ONE new token against a KV/state
cache of ``seq_len`` (the cache for SSM/RG-LRU archs is O(1)/window-bounded;
that asymmetry is the point of the long_500k cell).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import Family, ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"
    # grad-accum microbatches for train cells (memory control at batch 256)
    num_microbatches: int = 1


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", num_microbatches=8),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k needs sub-quadratic attention; skips recorded in DESIGN.md."""
    out = []
    for name in SHAPE_ORDER:
        if name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention archs skip the 500k decode cell
        out.append(name)
    return out


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: O(S^2) at 524288 infeasible by design (see DESIGN.md)"
    return None
