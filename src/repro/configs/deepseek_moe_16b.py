"""DeepSeekMoE-16B — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]."""

from repro.configs.base import AttentionKind, Family, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family=Family.MOE,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                       # first dense layer hidden dim
    vocab=102400,
    attention=AttentionKind.GQA,
    rope_theta=1e4,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_ff_expert=1408,
        capacity_factor=1.25,
        router="softmax",
        aux_loss_weight=0.001,
        first_dense=1,                # layer 0 dense in DeepSeekMoE
    ),
    source="arXiv:2401.06066; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-reduced",
        family=Family.MOE,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=192,
        vocab=128,
        attention=AttentionKind.GQA,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            n_shared=2,
            d_ff_expert=48,
            router="softmax",
            first_dense=1,
        ),
    )
