"""Architecture config registry.

Every assigned architecture has a module ``repro/configs/<id>.py`` exporting
``CONFIG``; the registry maps arch ids (dashed names) to those configs plus
the paper's own LASANA circuit "architectures".
"""

from __future__ import annotations

from repro.configs.base import (
    AttentionKind,
    Family,
    ModelConfig,
    MoEConfig,
    MLAConfig,
    SSMConfig,
    HybridConfig,
    EncDecConfig,
)
from repro.configs.shapes import SHAPES, ShapeConfig, applicable_shapes

_ARCH_MODULES = {
    "starcoder2-3b": "starcoder2_3b",
    "granite-3-8b": "granite_3_8b",
    "deepseek-67b": "deepseek_67b",
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-base": "whisper_base",
    "pixtral-12b": "pixtral_12b",
    "mamba2-1.3b": "mamba2_13b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    if arch not in _ARCH_MODULES:
        raise KeyError(arch)
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.reduced()


__all__ = [
    "ARCH_IDS",
    "AttentionKind",
    "EncDecConfig",
    "Family",
    "HybridConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "SSMConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "reduced_config",
]
