"""Granite-3 8B — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from repro.configs.base import AttentionKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family=Family.DENSE,
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    attention=AttentionKind.GQA,
    rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-reduced",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab=131,
        attention=AttentionKind.GQA,
    )
