"""Model configuration dataclasses for the architecture zoo.

One ``ModelConfig`` describes any member of the zoo; family-specific
sub-configs (MoE, MLA, SSM, hybrid, enc-dec) are attached when used.
Configs are immutable; derived quantities (param counts, head dims) are
properties so EXPERIMENTS tables and the roofline share one source of truth.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    AUDIO = "audio"
    VLM = "vlm"
    SSM = "ssm"
    HYBRID = "hybrid"


class AttentionKind(str, enum.Enum):
    GQA = "gqa"          # grouped-query attention (covers MHA/MQA)
    MLA = "mla"          # multi-head latent attention (DeepSeek-V2/V3)
    LOCAL = "local"      # sliding-window causal attention
    NONE = "none"        # attention-free (pure SSM)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                    # routed experts
    top_k: int
    n_shared: int = 0                 # always-on shared experts
    d_ff_expert: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    router: str = "softmax"           # "softmax" | "sigmoid" (aux-loss-free)
    aux_loss_weight: float = 0.001
    router_dtype: str = "float32"
    # layers [0, first_dense) use the dense d_ff MLP instead of MoE
    first_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Griffin-style interleave: `pattern` repeats over the layer stack."""

    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "local_attn")
    lru_width: int = 0                # 0 -> d_model
    conv_width: int = 4
    window: int = 2048


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 6
    encoder_seq: int = 1500           # whisper-base: 30 s of 20 ms frames
    frontend: str = "audio_stub"      # precomputed frame embeddings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    attention: AttentionKind = AttentionKind.GQA
    mlp_gated: bool = True            # SwiGLU-style; False -> 2-matrix GELU MLP
    d_head: int = 0                   # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    window: int = 0                   # sliding window (LOCAL attention)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # multimodal stub: number of frontend embedding positions in prefill
    n_frontend_tokens: int = 0
    mtp_depth: int = 0                # deepseek-v3 multi-token prediction
    # numerics / execution
    dtype: str = "bfloat16"
    remat_policy: str = "full"        # "full" | "dots" | "none"
    scan_layers: bool = True
    # citation tag from the assignment table
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in (Family.SSM, Family.HYBRID)

    @property
    def has_decoder(self) -> bool:
        return True  # every zoo member has an autoregressive decoder

    # ---- parameter counting (used for 6ND roofline "useful flops") --------
    def _attn_params(self) -> int:
        d, h, kvh, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if self.attention == AttentionKind.MLA:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank * h * qk          # q down/up
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)          # kv down + k_rope
            p += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
            p += h * m.v_head_dim * d                               # o proj
            return p
        if self.attention == AttentionKind.NONE:
            return 0
        return d * h * dh + 2 * d * kvh * dh + h * dh * d           # qkv + o

    def _mlp_params(self) -> int:
        mats = 3 if self.mlp_gated else 2
        return mats * self.d_model * self.d_ff

    def _moe_layer_params(self, active_only: bool) -> int:
        m = self.moe
        dff = m.d_ff_expert or self.d_ff
        per_expert = 3 * self.d_model * dff
        n_routed = m.top_k if active_only else m.n_experts
        return (n_routed + m.n_shared) * per_expert + self.d_model * m.n_experts

    def _ssm_layer_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        nheads = d_in // s.headdim
        p = self.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
        p += d_in * s.conv_kernel + d_in * self.d_model             # conv + out
        p += 2 * nheads                                              # A_log, D
        return p

    def _rglru_block_params(self) -> int:
        hy = self.hybrid
        w = hy.lru_width or self.d_model
        p = 2 * self.d_model * w                                     # two in-proj branches
        p += w * hy.conv_width                                       # temporal conv
        p += 2 * w * w // 1                                          # gates (diag-block approx: full)
        p += w                                                       # Lambda
        p += w * self.d_model                                        # out proj
        return p

    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        d = self.d_model
        n = 0
        per_layer_norms = 2 * d
        if self.family == Family.SSM:
            n += self.n_layers * (self._ssm_layer_params() + d)
        elif self.family == Family.HYBRID:
            hy = self.hybrid
            pat = hy.pattern
            for i in range(self.n_layers):
                kind = pat[i % len(pat)]
                if kind == "recurrent":
                    n += self._rglru_block_params()
                else:
                    n += self._attn_params()
                n += self._mlp_params() + per_layer_norms
        else:
            for i in range(self.n_layers):
                n += self._attn_params() + per_layer_norms
                if self.moe is not None and i >= self.moe.first_dense:
                    n += self._moe_layer_params(active_only)
                else:
                    n += self._mlp_params()
        if self.encdec is not None:
            e = self.encdec
            enc_layer = self._attn_params() + self._mlp_params() + per_layer_norms
            cross = self._attn_params() + d
            n += e.n_encoder_layers * enc_layer
            n += self.n_layers * cross                               # decoder cross-attn
        n += self.vocab * d                                          # embed
        if not self.tie_embeddings:
            n += self.vocab * d                                      # lm head
        if self.mtp_depth:
            n += self.mtp_depth * (self._attn_params() + self._moe_layer_params(active_only)
                                   + per_layer_norms + 2 * d * d)
        n += d                                                       # final norm
        return int(n)

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)

    def describe(self) -> str:
        tot = self.param_count() / 1e9
        act = self.active_param_count() / 1e9
        s = f"{self.name}: {self.family.value} {self.n_layers}L d={self.d_model} {tot:.2f}B params"
        if self.moe:
            s += f" ({act:.2f}B active)"
        return s
