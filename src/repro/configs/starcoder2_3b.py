"""StarCoder2-3B — dense GQA+RoPE decoder [arXiv:2402.19173; hf]."""

from repro.configs.base import AttentionKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family=Family.DENSE,
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    attention=AttentionKind.GQA,
    mlp_gated=False,                  # starcoder2 uses c_fc/c_proj GELU MLP
    rope_theta=1e5,
    source="arXiv:2402.19173; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-reduced",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        attention=AttentionKind.GQA,
        rope_theta=1e5,
    )
