"""Mamba2-1.3B — attention-free SSM with state-space duality (SSD)
[arXiv:2405.21060; unverified]."""

from repro.configs.base import AttentionKind, Family, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family=Family.SSM,
    n_layers=48,
    d_model=2048,
    n_heads=0,                        # attention-free
    n_kv_heads=0,
    d_ff=0,                           # no separate FFN; SSD block includes MLP-ish expand
    vocab=50280,
    attention=AttentionKind.NONE,
    tie_embeddings=True,
    ssm=SSMConfig(
        d_state=128,
        expand=2,
        headdim=64,
        n_groups=1,
        conv_kernel=4,
        chunk_size=256,
    ),
    source="arXiv:2405.21060; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-reduced",
        family=Family.SSM,
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=128,
        attention=AttentionKind.NONE,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=16, expand=2, headdim=16, conv_kernel=4, chunk_size=16),
    )
