"""RecurrentGemma-2B — Griffin: RG-LRU recurrent blocks + local attention,
2:1 interleave [arXiv:2402.19427; hf]."""

from repro.configs.base import AttentionKind, Family, HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family=Family.HYBRID,
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                     # MQA in the local-attention layers
    d_ff=7680,
    vocab=256000,
    attention=AttentionKind.LOCAL,
    d_head=256,
    window=2048,
    tie_embeddings=True,
    hybrid=HybridConfig(
        pattern=("recurrent", "recurrent", "local_attn"),
        lru_width=2560,
        conv_width=4,
        window=2048,
    ),
    source="arXiv:2402.19427; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-reduced",
        family=Family.HYBRID,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=192,
        vocab=160,
        attention=AttentionKind.LOCAL,
        d_head=16,
        window=16,
        tie_embeddings=True,
        hybrid=HybridConfig(
            pattern=("recurrent", "recurrent", "local_attn"),
            lru_width=64,
            conv_width=4,
            window=16,
        ),
    )
