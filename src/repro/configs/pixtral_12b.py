"""Pixtral-12B — VLM: pixtral-ViT frontend (stubbed as precomputed patch
embeddings) + Mistral-NeMo-style decoder backbone
[hf:mistralai/Pixtral-12B-2409; unverified]."""

from repro.configs.base import AttentionKind, Family, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family=Family.VLM,
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    attention=AttentionKind.GQA,
    d_head=128,
    rope_theta=1e9,                   # mistral-nemo long-theta rope
    n_frontend_tokens=1024,           # 1024 image-patch embeddings per sample
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-reduced",
        family=Family.VLM,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=144,
        attention=AttentionKind.GQA,
        d_head=16,
        n_frontend_tokens=8,
    )
