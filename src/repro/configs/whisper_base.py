"""Whisper-base — encoder-decoder transformer, conv audio frontend stubbed
with precomputed frame embeddings [arXiv:2212.04356; unverified]."""

from repro.configs.base import AttentionKind, EncDecConfig, Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family=Family.AUDIO,
    n_layers=6,                       # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    attention=AttentionKind.GQA,
    mlp_gated=False,                  # whisper uses standard GELU MLP
    rope_theta=0.0,                   # whisper uses learned/sinusoidal pos
    tie_embeddings=True,
    encdec=EncDecConfig(
        n_encoder_layers=6,
        encoder_seq=1500,             # 30s of 20ms mel frames after conv stem
        frontend="audio_stub",
    ),
    source="arXiv:2212.04356; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-reduced",
        family=Family.AUDIO,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=160,
        attention=AttentionKind.GQA,
        rope_theta=0.0,
        tie_embeddings=True,
        encdec=EncDecConfig(n_encoder_layers=2, encoder_seq=24, frontend="audio_stub"),
    )
