"""Step-time watchdog: straggler and hang detection.

EWMA of step walltimes; a step exceeding ``threshold x ewma`` flags a
straggler (on a real cluster this triggers the controller to profile /
cordon the slow host; here it logs and counts). A hard ``hang_timeout``
arms a timer per step — if a step never completes, the registered callback
fires (the serve driver fails the hung lane's requests; a launcher would
abort + restart from the last checkpoint).

Two hardening guarantees (tested in tests/test_ft.py):

- all timing uses ``time.monotonic()`` — a wall-clock jump (NTP slew,
  manual reset) can neither false-fire ``on_hang`` nor corrupt the EWMA;
- ``on_hang`` can NEVER fire for a step that already completed: firing
  and completion race through one lock, and the timer callback re-checks
  the step generation + open flag under it before calling out
  (``Timer.cancel()`` alone cannot close that window — the timer thread
  may already be past its wait when cancel lands).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, *, ewma_alpha: float = 0.2, threshold: float = 3.0,
                 hang_timeout: float = 600.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.ewma: Optional[float] = None
        self.alpha = ewma_alpha
        self.threshold = threshold
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang
        self.stragglers = 0
        self.hangs = 0
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._timer: Optional[threading.Timer] = None
        self._t0: Optional[float] = None
        self._gen = 0                  # step generation the armed timer is for
        self._open = False             # a step is currently in flight

    def step_begin(self):
        with self._lock:
            self._t0 = time.monotonic()
            self._gen += 1
            self._open = True
            gen = self._gen
            if self.on_hang is not None:
                self._timer = threading.Timer(self.hang_timeout,
                                              self._fire, args=(gen,))
                self._timer.daemon = True
                self._timer.start()

    def _fire(self, gen: int):
        """Timer body: fire ``on_hang`` only if step ``gen`` is STILL
        open — checked under the lock, so a completion that won the race
        (even one that landed after ``Timer.cancel`` was too late)
        silences the hang for good."""
        with self._lock:
            if gen != self._gen or not self._open:
                return
            self.hangs += 1
            cb = self.on_hang
        if cb is not None:
            cb()                       # outside the lock: the callback may
                                       # grab its own locks (serve driver)

    def step_end(self, step: int) -> dict:
        with self._lock:
            dt = time.monotonic() - self._t0
            self._open = False         # from here _fire(gen) is inert
            timer, self._timer = self._timer, None
            slow = self.ewma is not None and dt > self.threshold * self.ewma
            if slow:
                self.stragglers += 1
                self.events.append({"step": step, "seconds": dt,
                                    "ewma": self.ewma})
            self.ewma = dt if self.ewma is None else (
                self.alpha * dt + (1 - self.alpha) * self.ewma)
            ewma = self.ewma
        if timer is not None:
            timer.cancel()
        return {"step_seconds": dt, "straggler": slow, "ewma": ewma}
