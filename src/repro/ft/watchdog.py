"""Step-time watchdog: straggler and hang detection.

EWMA of step walltimes; a step exceeding ``threshold x ewma`` flags a
straggler (on a real cluster this triggers the controller to profile /
cordon the slow host; here it logs and counts). A hard ``hang_timeout``
arms a timer per step — if a step never completes, the registered callback
fires (the launcher uses it to abort + restart from the last checkpoint).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, *, ewma_alpha: float = 0.2, threshold: float = 3.0,
                 hang_timeout: float = 600.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.ewma: Optional[float] = None
        self.alpha = ewma_alpha
        self.threshold = threshold
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang
        self.stragglers = 0
        self.events: list[dict] = []
        self._timer: Optional[threading.Timer] = None
        self._t0: Optional[float] = None

    def step_begin(self):
        self._t0 = time.time()
        if self.on_hang is not None:
            self._timer = threading.Timer(self.hang_timeout, self.on_hang)
            self._timer.daemon = True
            self._timer.start()

    def step_end(self, step: int) -> dict:
        dt = time.time() - self._t0
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        if slow:
            self.stragglers += 1
            self.events.append({"step": step, "seconds": dt,
                                "ewma": self.ewma})
        self.ewma = dt if self.ewma is None else (
            self.alpha * dt + (1 - self.alpha) * self.ewma)
        return {"step_seconds": dt, "straggler": slow, "ewma": self.ewma}
