"""Elastic re-meshing: resume training on a different device count.

Checkpoints are mesh-independent (global arrays + logical specs), so elastic
resume is: rebuild a mesh over the surviving devices (shrunk along the data
axis — the model axis must stay intact because TP shards are not
self-sufficient), re-derive shardings from the same logical rules, and
``device_put`` the restored tree. Tested in tests/test_ft.py by resuming an
8-host-device run on 4 devices with bitwise-identical loss continuation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro import sharding as shd


@dataclasses.dataclass
class ElasticPlan:
    mesh: Mesh
    rules: shd.ShardingRules
    n_devices: int
    data_size: int
    model_size: int


def plan_mesh(devices=None, *, model_size: int = 1) -> ElasticPlan:
    """Largest (data, model) mesh over the available devices.

    ``model_size`` is fixed by the checkpointed TP layout; the data axis
    absorbs whatever survives. Drops remainder devices (they rejoin at the
    next full restart).
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if n < model_size:
        raise RuntimeError(
            f"cannot re-mesh: {n} devices < model_size {model_size}")
    data = n // model_size
    use = devices[: data * model_size]
    mesh = Mesh(np.array(use).reshape(data, model_size), ("data", "model"))
    return ElasticPlan(mesh=mesh, rules=shd.train_rules(mesh), n_devices=n,
                       data_size=data, model_size=model_size)


def resume_state(ckpt_manager, abstract_state, plan: ElasticPlan,
                 shardings_fn):
    """Restore the latest checkpoint onto the (possibly shrunk) mesh.

    shardings_fn(mesh, rules) -> pytree of NamedSharding matching the state.
    Returns (step, state) or None when no checkpoint exists.
    """
    sh = shardings_fn(plan.mesh, plan.rules)
    got = ckpt_manager.restore_latest(abstract_state, shardings=sh)
    if got is None:
        return None
    step, state, _ = got
    return step, state


def simulate_failure(devices, n_lost: int):
    """Test helper: pretend the last ``n_lost`` devices died."""
    return devices[: len(devices) - n_lost]
