"""Synthetic MNIST-like digits + Poisson rate spike encoding.

No dataset files ship in this container, so the case studies (paper §V-E)
run on a *procedural* digit set: each class is a deterministic stroke
prototype rendered at 20x20 or 28x28, jittered per sample. Classes are
linearly separable enough that a small BNN/SNN trains to high accuracy —
the role MNIST plays in the paper (a workload generator for the
golden-vs-surrogate comparison, not a vision benchmark).
"""

from __future__ import annotations

import numpy as np

_SEGS = {
    # seven-segment-ish strokes in a unit square: (x0, y0, x1, y1)
    0: [(.2, .1, .8, .1), (.2, .9, .8, .9), (.2, .1, .2, .9), (.8, .1, .8, .9)],
    1: [(.5, .1, .5, .9)],
    2: [(.2, .1, .8, .1), (.8, .1, .8, .5), (.2, .5, .8, .5), (.2, .5, .2, .9),
        (.2, .9, .8, .9)],
    3: [(.2, .1, .8, .1), (.2, .5, .8, .5), (.2, .9, .8, .9), (.8, .1, .8, .9)],
    4: [(.2, .1, .2, .5), (.2, .5, .8, .5), (.8, .1, .8, .9)],
    5: [(.8, .1, .2, .1), (.2, .1, .2, .5), (.2, .5, .8, .5), (.8, .5, .8, .9),
        (.8, .9, .2, .9)],
    6: [(.8, .1, .2, .1), (.2, .1, .2, .9), (.2, .9, .8, .9), (.8, .9, .8, .5),
        (.8, .5, .2, .5)],
    7: [(.2, .1, .8, .1), (.8, .1, .5, .9)],
    8: [(.2, .1, .8, .1), (.2, .5, .8, .5), (.2, .9, .8, .9), (.2, .1, .2, .9),
        (.8, .1, .8, .9)],
    9: [(.2, .5, .2, .1), (.2, .1, .8, .1), (.8, .1, .8, .9), (.8, .5, .2, .5)],
}


def _render(cls: int, size: int, rng) -> np.ndarray:
    img = np.zeros((size, size), np.float32)
    jx, jy = rng.uniform(-.06, .06, 2)
    scale = rng.uniform(0.85, 1.1)
    for (x0, y0, x1, y1) in _SEGS[cls]:
        n = 2 * size
        ts = np.linspace(0, 1, n)
        xs = ((x0 + (x1 - x0) * ts) * scale + jx) * (size - 1)
        ys = ((y0 + (y1 - y0) * ts) * scale + jy) * (size - 1)
        xi = np.clip(np.round(xs).astype(int), 0, size - 1)
        yi = np.clip(np.round(ys).astype(int), 0, size - 1)
        img[yi, xi] = 1.0
    # stroke width + blur-ish
    img = np.maximum(img, np.roll(img, 1, 0) * 0.9)
    img = np.maximum(img, np.roll(img, 1, 1) * 0.9)
    img += rng.normal(0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def make_digits(n: int, *, size: int = 20, seed: int = 0):
    """-> (images (n, size*size) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n)
    imgs = np.stack([_render(int(c), size, rng) for c in labels])
    return imgs.reshape(n, -1).astype(np.float32), labels.astype(np.int32)


def poisson_encode(images: np.ndarray, t_steps: int, *, max_rate: float = 0.6,
                   seed: int = 0) -> np.ndarray:
    """Rate coding: spike (T, N, D) with P(spike) ∝ pixel intensity."""
    rng = np.random.default_rng(seed)
    p = np.clip(images * max_rate, 0, 1)
    return (rng.random((t_steps, *images.shape)) < p[None]).astype(np.float32)
