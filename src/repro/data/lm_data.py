"""Deterministic synthetic LM corpus with a production-shaped pipeline.

The stream is a seeded Zipf-ish Markov token process: reproducible from
(seed, step) alone, so any host can materialize exactly its shard without
coordination — restart/elastic-resume just re-derives the stream at the
resumed step (no data-state checkpoint needed). Batches are dealt
microbatch-major (M, B/M, S) to match the train-step contract
(see train/step.py).

A background prefetch thread keeps ``prefetch`` batches ready so input
stalls never serialize the step (straggler mitigation at the input stage).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticCorpus:
    """Seeded Markov stream over ``vocab`` tokens."""

    def __init__(self, vocab: int, seed: int = 0, order_decay: float = 0.7):
        self.vocab = vocab
        self.seed = seed
        self.order_decay = order_decay

    def batch(self, step: int, batch: int, seq: int, *,
              host_id: int = 0, n_hosts: int = 1) -> np.ndarray:
        """Tokens (batch, seq) for this host at this step — pure function."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_id)
        base = rng.integers(0, self.vocab, (batch, seq), dtype=np.int64)
        # local correlation: with p=decay, copy previous token + small drift
        keep = rng.random((batch, seq)) < self.order_decay
        drift = rng.integers(-3, 4, (batch, seq))
        out = base.copy()
        for t in range(1, seq):
            out[:, t] = np.where(keep[:, t],
                                 (out[:, t - 1] + drift[:, t]) % self.vocab,
                                 base[:, t])
        return out.astype(np.int32)


def make_train_batch(corpus: SyntheticCorpus, step: int, *, global_batch: int,
                     seq: int, num_microbatches: int = 1, host_id: int = 0,
                     n_hosts: int = 1, extras: Optional[dict] = None) -> dict:
    """Next-token-prediction batch; leaves are (M, B/M, S) when M > 1."""
    per_host = global_batch // n_hosts
    toks = corpus.batch(step, per_host, seq + 1, host_id=host_id,
                        n_hosts=n_hosts)
    tokens, labels = toks[:, :-1], toks[:, 1:].copy()
    batch = {"tokens": tokens, "labels": labels}
    if extras:
        batch.update(extras)
    if num_microbatches > 1:
        m = num_microbatches
        batch = {k: v.reshape(m, v.shape[0] // m, *v.shape[1:])
                 for k, v in batch.items()}
    return batch


class Prefetcher:
    """Background thread that keeps ``depth`` batches ready."""

    def __init__(self, make_batch, *, depth: int = 2, start_step: int = 0):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
