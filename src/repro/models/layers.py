"""Shared layer primitives: norms, rope, embeddings, dense MLPs.

All forwards are pure functions (params, x) -> y; activations compute in the
config dtype with fp32 softmax/norm statistics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec


# --- norms -------------------------------------------------------------------

def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(w, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


# --- rotary embeddings ---------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return inv  # (dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --- embedding -----------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    # Table is vocab-sharded only: a second (fsdp) dim on the gather table
    # trips XLA SPMD's "involuntary full rematerialization" fallback.
    specs = {"embedding": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", None),
                                    init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return specs


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["lm_head"]
    return jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)


# --- dense MLP -----------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    specs = {
        "up": ParamSpec((d, f), ("embed", "mlp")),
        "down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_gated:
        specs["gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs


def mlp(params, x, cfg: ModelConfig):
    up = jnp.einsum("...d,df->...f", x, params["up"])
    if cfg.mlp_gated:
        gate = jnp.einsum("...d,df->...f", x, params["gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["down"])
