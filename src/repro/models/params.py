"""Abstract parameter specifications.

Models declare their parameters as a pytree of ``ParamSpec`` (shape, dtype,
logical sharding axes, initializer). The tree is then *materialized* three
ways:

- ``materialize``      -> real arrays (smoke tests, examples, training)
- ``abstract``         -> ShapeDtypeStruct stand-ins (dry-run: no allocation)
- ``shardings``        -> NamedShardings via the logical->mesh rule table

Keeping init abstract is what lets the 671B config lower+compile on a CPU
container without ever allocating a parameter.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingRules

InitFn = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"              # normal | zeros | ones | embed | lambda_lru
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"spec rank mismatch: shape {self.shape} vs logical {self.logical}"
            )


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: tuple[int, ...]) -> int:
    # stacked-layer leading dims are not fan-in; use second-to-last dim.
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1][-2:][-1:])) or shape[-2]


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    shape, dtype = spec.shape, spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "lambda_lru":
        # Griffin Λ init: a in [0.9, 0.999] -> Λ = softplus^-1-ish param.
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(jnp.expm1(-jnp.log(u) * 8.0) + 1e-8)  # softplus inverse of -c^-1 log a
        return lam.astype(dtype)
    if spec.init == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32, math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus
    if spec.init == "a_log":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    std = spec.scale / math.sqrt(max(_fan_in(shape), 1))
    if spec.init == "embed":
        std = spec.scale
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def materialize(key: jax.Array, spec_tree):
    """Seeded init of the full parameter pytree."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract(spec_tree):
    """ShapeDtypeStruct tree — for .lower() without allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def shardings(spec_tree, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: rules.sharding(mesh, s.logical, s.shape), spec_tree,
        is_leaf=_is_spec,
    )


def logical_specs(spec_tree):
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=_is_spec)


def param_bytes(spec_tree) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    )


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec))


def stacked(spec: ParamSpec, n: int) -> ParamSpec:
    """Prepend a scan-over-layers dim (logical axis 'layers', never sharded)."""
    return ParamSpec(
        shape=(n, *spec.shape),
        logical=("layers", *spec.logical),
        init=spec.init,
        scale=spec.scale,
        dtype=spec.dtype,
    )


def map_stacked(tree, n: int):
    return jax.tree.map(lambda s: stacked(s, n), tree, is_leaf=_is_spec)
