"""Mamba-2 block: state-space duality (SSD) chunked scan [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute *within* chunks of length Q plus a sequential inter-chunk state
recurrence of length S/Q — O(S*Q) work, O(S/Q) scan depth. Decode is the
O(1) recurrent update; the "KV cache" is the (H, P, N) state + conv tail,
which is why long_500k is trivially feasible for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.params import ParamSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.headdim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nheads, conv_ch


def ssm_specs(cfg: ModelConfig) -> dict:
    s, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nheads   # z, x, B, C, dt
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.conv_kernel, conv_ch), (None, "ssm_inner")),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((nheads,), (None,), init="a_log", dtype=jnp.float32),
        "d_skip": ParamSpec((nheads,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((nheads,), (None,), init="dt_bias", dtype=jnp.float32),
        "norm": ParamSpec((d_in,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    s, d_in, nheads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, bb, cc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn,
                                          2 * d_in + 2 * gn], axis=-1)
    return z, x, bb, cc, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : xp.shape[1] - (k - 1 - i), :] * w[i] for i in range(k))
    return out + b


def ssd_chunked(x, dt, a, bb, cc, d_skip, *, chunk: int, init_state=None):
    """SSD scan. x:(B,S,H,P) dt:(B,S,H) a:(H,) bb/cc:(B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).

    One lax.scan over chunks carries the inter-chunk state; the rematted
    body does the quadratic intra-chunk work, so peak memory is one chunk's
    (B,Q,Q,H) score tensor rather than all Nc of them.
    """
    b, s, h, p = x.shape
    g, n = bb.shape[2], bb.shape[3]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q
    rep = h // g

    # (Nc, B, Q, ...) chunked views for scan
    xr = jnp.moveaxis(x.reshape(b, nc, q, h, p), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(b, nc, q, h), 1, 0)
    br = jnp.moveaxis(jnp.repeat(bb.reshape(b, nc, q, g, n), rep, axis=3), 1, 0)
    cr = jnp.moveaxis(jnp.repeat(cc.reshape(b, nc, q, g, n), rep, axis=3), 1, 0)

    mask = jnp.tril(jnp.ones((q, q), bool))
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    @jax.checkpoint
    def body(hprev, xs):
        xc, dtc, bc, cc_ = xs                    # (B,Q,H,P),(B,Q,H),(B,Q,H,N)x2
        da = dtc * a[None, None, :]              # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        seg = cum[:, -1, :]                      # (B,H)
        # intra-chunk
        li = cum[:, :, None, :] - cum[:, None, :, :]
        ldec = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        scores = jnp.einsum("bqhk,bthk->bqth", cc_, bc)
        xdt = xc * dtc[..., None]
        y_diag = jnp.einsum("bqth,bqth,bthp->bqhp", scores.astype(jnp.float32),
                            ldec, xdt.astype(jnp.float32))
        # inter-chunk: read previous state
        decay_in = jnp.exp(cum)                  # (B,Q,H)
        y_off = jnp.einsum("bqhn,bqh,bhpn->bqhp", cc_.astype(jnp.float32),
                           decay_in, hprev)
        # state update: contribution of this chunk to the running state
        decay_to_end = jnp.exp(seg[:, None, :] - cum)
        cst = jnp.einsum("bqhn,bqh,bqhp->bhpn", bc.astype(jnp.float32),
                         decay_to_end, xdt.astype(jnp.float32))
        hnew = hprev * jnp.exp(seg)[:, :, None, None] + cst
        y = y_diag + y_off + xc.astype(jnp.float32) * d_skip[None, None, :, None]
        return hnew, y.astype(x.dtype)

    h_final, ys = jax.lax.scan(body, h0, (xr, dtr, br, cr))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, h_final


def mamba2_forward(params, x, cfg: ModelConfig, *, return_state: bool = False):
    """Full-sequence mamba2 block. x: (B,S,d) -> (B,S,d)."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xs, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xbc_raw = jnp.concatenate([xs, bb, cc], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xs, bb, cc = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(*xs.shape[:2], nheads, s.headdim)
    bh = bb.reshape(*bb.shape[:2], s.n_groups, s.d_state)
    ch = cc.reshape(*cc.shape[:2], s.n_groups, s.d_state)
    y, h_final = ssd_chunked(xh, dt, a, bh, ch, params["d_skip"],
                             chunk=s.chunk_size)
    y = y.reshape(*x.shape[:2], d_in)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    if return_state:
        k = s.conv_kernel
        tail = xbc_raw[:, -(k - 1):, :]
        if tail.shape[1] < k - 1:   # S < K-1: left-pad with zeros
            pad = k - 1 - tail.shape[1]
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"conv": tail, "ssm": h_final}
    return out


# --- decode ---------------------------------------------------------------------

def mamba2_cache_spec(cfg: ModelConfig, batch: int, n_layers: int,
                      dtype=jnp.bfloat16) -> dict:
    s, d_in, nheads, conv_ch = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, s.conv_kernel - 1, conv_ch), dtype),
        "ssm": jax.ShapeDtypeStruct((n_layers, batch, nheads, s.headdim, s.d_state),
                                    jnp.float32),
    }


def mamba2_decode(params, x, layer_cache, cfg: ModelConfig):
    """Single-token recurrent update. x: (B,1,d)."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xs, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)[:, 0]           # (B,C)
    conv_hist = jnp.concatenate([layer_cache["conv"],
                                 xbc[:, None].astype(layer_cache["conv"].dtype)],
                                axis=1)                          # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xs_c, bb_c, cc_c = jnp.split(conv_out.astype(x.dtype),
                                 [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt1 * a[None, :])                               # (B,H)
    xh = xs_c.reshape(-1, nheads, s.headdim)
    rep = nheads // s.n_groups
    bh = jnp.repeat(bb_c.reshape(-1, s.n_groups, s.d_state), rep, axis=1)
    chh = jnp.repeat(cc_c.reshape(-1, s.n_groups, s.d_state), rep, axis=1)
    hstate = layer_cache["ssm"]                                  # (B,H,P,N) fp32
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh.astype(jnp.float32),
                     bh.astype(jnp.float32))
    hstate = hstate * da[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", chh.astype(jnp.float32), hstate)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    new_cache = {"conv": conv_hist[:, 1:].astype(layer_cache["conv"].dtype),
                 "ssm": hstate}
    return out, new_cache
