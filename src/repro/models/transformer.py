"""Layer assembly: pre-norm residual blocks over pluggable mixers.

``layer_specs``/``layer_apply``/``layer_decode`` define one decoder layer for
every family; stacks are built in model.py (scanned where homogeneous,
unrolled for the Griffin interleave and enc-dec cross wiring).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionKind, Family, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_specs, rmsnorm, rmsnorm_spec


# --- layer kinds ----------------------------------------------------------------
# "attn_dense"  : attention + dense MLP
# "attn_moe"    : attention + MoE FFN
# "mamba2"      : norm + mamba2 block (no FFN)
# "recurrent"   : RG-LRU block + dense MLP
# "local_attn"  : sliding-window attention + dense MLP
# "enc"         : bidirectional attention + dense MLP (encoder)
# "dec_cross"   : self attn + cross attn + dense MLP (enc-dec decoder)


def layer_specs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    s: dict[str, Any] = {"ln1": rmsnorm_spec(d)}
    if kind == "mamba2":
        s["ssm"] = ssm_mod.ssm_specs(cfg)
        return s
    if kind == "recurrent":
        s["rglru"] = rglru_mod.rglru_specs(cfg)
    else:
        s["attn"] = attn.attn_specs(cfg)
    if kind == "dec_cross":
        s["lnx"] = rmsnorm_spec(d)
        s["xattn"] = attn.attn_specs(cfg, cross=True)
    s["ln2"] = rmsnorm_spec(d)
    if kind == "attn_moe":
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["ffn"] = mlp_specs(cfg)
    return s


def layer_apply(params, x, positions, cfg: ModelConfig, kind: str, *,
                enc_out=None, n_moe_groups: int = 1, causal: bool = True,
                constrain=None):
    """Full-sequence layer. Returns (y, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind == "mamba2":
        return x + ssm_mod.mamba2_forward(params["ssm"], h, cfg), aux
    if kind == "recurrent":
        mixed = rglru_mod.rglru_forward(params["rglru"], h, cfg)
    elif kind == "local_attn":
        mixed = attn.gqa_full(params["attn"], h, positions, cfg, causal=True,
                              window=cfg.window, constrain=constrain)
    elif cfg.attention == AttentionKind.MLA:
        mixed = attn.mla_full(params["attn"], h, positions, cfg, causal=causal)
    else:
        mixed = attn.gqa_full(params["attn"], h, positions, cfg, causal=causal,
                              constrain=constrain)
    x = x + mixed
    if kind == "dec_cross":
        hx = rmsnorm(params["lnx"], x, cfg.norm_eps)
        x = x + attn.gqa_full(params["xattn"], hx, positions, cfg, kv_x=enc_out)
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        y, aux = moe_mod.moe_ffn(params["moe"], h2, cfg, n_groups=n_moe_groups)
        return x + y, aux
    return x + mlp(params["ffn"], h2, cfg), aux


def layer_decode(params, x, layer_cache, pos, cfg: ModelConfig, kind: str, *,
                 enc_out=None):
    """One-token layer step. Returns (y, new_layer_cache)."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    new_cache = dict(layer_cache)
    if kind == "mamba2":
        y, c = ssm_mod.mamba2_decode(params["ssm"], h,
                                     {"conv": layer_cache["conv"],
                                      "ssm": layer_cache["ssm"]}, cfg)
        new_cache.update(c)
        return x + y, new_cache
    if kind == "recurrent":
        y, c = rglru_mod.rglru_decode(params["rglru"], h,
                                      {"conv": layer_cache["conv"],
                                       "h": layer_cache["h"]}, cfg)
        new_cache.update(c)
        x = x + y
    else:
        window = cfg.window if kind == "local_attn" else 0
        if cfg.attention == AttentionKind.MLA:
            y, c = attn.mla_decode(params["attn"], h,
                                   {"c_kv": layer_cache["c_kv"],
                                    "k_rope": layer_cache["k_rope"]}, pos, cfg)
        else:
            y, c = attn.gqa_decode(params["attn"], h,
                                   {"k": layer_cache["k"],
                                    "v": layer_cache["v"],
                                    "kpos": layer_cache["kpos"]}, pos, cfg,
                                   window=window)
        new_cache.update(c)
        x = x + y
    if kind == "dec_cross":
        hx = rmsnorm(params["lnx"], x, cfg.norm_eps)
        # cross kv precomputed at prefill: (B, T_enc, KVH, Dh)
        kvh, dh = cfg.n_kv_heads, cfg.head_dim
        g = cfg.n_heads // kvh
        q = jnp.einsum("bsd,dhk->bshk", hx, params["xattn"]["wq"])
        qg = q.reshape(*q.shape[:2], kvh, g, dh)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, layer_cache["xk"])
        logits = logits.astype(jnp.float32) / jnp.sqrt(float(dh))
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgst,btkd->bskgd", w, layer_cache["xv"])
        o = o.reshape(*x.shape[:2], cfg.n_heads, dh)
        x = x + jnp.einsum("bshk,hkd->bsd", o, params["xattn"]["wo"])
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        y, _ = moe_mod.moe_ffn(params["moe"], h2, cfg, n_groups=1)
        return x + y, new_cache
    return x + mlp(params["ffn"], h2, cfg), new_cache


def _fill_buffer(buf_len: int, seq: jax.Array, dtype):
    """Pack a (B,S,...) prefill sequence into a (B,buf_len,...) ring buffer.

    Entry for absolute position p lives at slot p % buf_len; returns
    (buffer, kpos) where kpos[i] is the absolute position stored in slot i
    (-1 = empty).
    """
    b, s = seq.shape[0], seq.shape[1]
    rest = seq.shape[2:]
    if s <= buf_len:
        buf = jnp.zeros((b, buf_len, *rest), dtype)
        buf = buf.at[:, :s].set(seq.astype(dtype))
        kpos = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                jnp.full((buf_len - s,), -1, jnp.int32)])
        return buf, kpos
    keep = seq[:, s - buf_len:]
    pos = jnp.arange(s - buf_len, s, dtype=jnp.int32)
    slots = jnp.mod(pos, buf_len)
    buf = jnp.zeros((b, buf_len, *rest), dtype).at[:, slots].set(keep.astype(dtype))
    kpos = jnp.zeros((buf_len,), jnp.int32).at[slots].set(pos)
    return buf, kpos


def layer_prefill(params, x, positions, cfg: ModelConfig, kind: str, *,
                  max_seq: int, enc_out=None, cache_dtype=jnp.bfloat16):
    """Full-sequence layer that also emits its decode cache. -> (y, cache)."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    cache: dict[str, Any] = {}
    if kind == "mamba2":
        y, st = ssm_mod.mamba2_forward(params["ssm"], h, cfg, return_state=True)
        return x + y, {"conv": st["conv"].astype(cache_dtype), "ssm": st["ssm"]}
    if kind == "recurrent":
        y, st = rglru_mod.rglru_forward(params["rglru"], h, cfg, return_state=True)
        cache = {"conv": st["conv"].astype(cache_dtype), "h": st["h"]}
        x = x + y
    else:
        window = cfg.window if kind == "local_attn" else 0
        if cfg.attention == AttentionKind.MLA:
            y, (c_kv, k_rope) = attn.mla_full(params["attn"], h, positions, cfg,
                                              return_kv=True)
            ckv_buf, _ = _fill_buffer(max_seq, c_kv, cache_dtype)
            kr_buf, _ = _fill_buffer(max_seq, k_rope, cache_dtype)
            cache = {"c_kv": ckv_buf, "k_rope": kr_buf}
        else:
            y, (k, v) = attn.gqa_full(params["attn"], h, positions, cfg,
                                      window=window, return_kv=True)
            buf_len = min(max_seq, window) if window else max_seq
            k_buf, kpos = _fill_buffer(buf_len, k, cache_dtype)
            v_buf, _ = _fill_buffer(buf_len, v, cache_dtype)
            cache = {"k": k_buf, "v": v_buf, "kpos": kpos}
        x = x + y
    if kind == "dec_cross":
        hx = rmsnorm(params["lnx"], x, cfg.norm_eps)
        x = x + attn.gqa_full(params["xattn"], hx, positions, cfg, kv_x=enc_out)
        cache["xk"] = jnp.einsum("btd,dhk->bthk", enc_out,
                                 params["xattn"]["wk"]).astype(cache_dtype)
        cache["xv"] = jnp.einsum("btd,dhk->bthk", enc_out,
                                 params["xattn"]["wv"]).astype(cache_dtype)
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        yf, _ = moe_mod.moe_ffn(params["moe"], h2, cfg, n_groups=1)
        return x + yf, cache
    return x + mlp(params["ffn"], h2, cfg), cache


def layer_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype=jnp.bfloat16) -> dict:
    """Per-layer (unstacked) decode-cache ShapeDtypeStructs."""
    if kind == "mamba2":
        spec = ssm_mod.mamba2_cache_spec(cfg, batch, 1, dtype)
        return {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype) for k, v in spec.items()}
    if kind == "recurrent":
        spec = rglru_mod.rglru_cache_spec(cfg, batch, 1, dtype)
        return {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype) for k, v in spec.items()}
    if cfg.attention == AttentionKind.MLA:
        spec = attn.mla_cache_spec(cfg, batch, max_seq, 1, dtype)
        out = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
               for k, v in spec.items()}
    else:
        eff = min(max_seq, cfg.window) if (cfg.window and kind == "local_attn") else max_seq
        kvh, dh = cfg.n_kv_heads, cfg.head_dim
        out = {
            "k": jax.ShapeDtypeStruct((batch, eff, kvh, dh), dtype),
            "v": jax.ShapeDtypeStruct((batch, eff, kvh, dh), dtype),
            "kpos": jax.ShapeDtypeStruct((eff,), jnp.int32),
        }
    if kind == "dec_cross":
        enc_t = cfg.encdec.encoder_seq
        out["xk"] = jax.ShapeDtypeStruct((batch, enc_t, cfg.n_kv_heads, cfg.head_dim), dtype)
        out["xv"] = jax.ShapeDtypeStruct((batch, enc_t, cfg.n_kv_heads, cfg.head_dim), dtype)
    return out


def cache_logical(kind: str, cfg: ModelConfig) -> dict:
    """Logical sharding axes for each cache leaf (batch over dp, heads over tp)."""
    if kind == "mamba2":
        return {"conv": ("batch", None, "ssm_inner"),
                "ssm": ("batch", "heads", None, None)}
    if kind == "recurrent":
        return {"conv": ("batch", None, "mlp"), "h": ("batch", "mlp")}
    if cfg.attention == AttentionKind.MLA:
        out = {"c_kv": ("batch", "kv_seq", None),
               "k_rope": ("batch", "kv_seq", None)}
    else:
        out = {"k": ("batch", "kv_seq", "kv_heads", None),
               "v": ("batch", "kv_seq", "kv_heads", None),
               "kpos": ("kv_seq",)}
    if kind == "dec_cross":
        out["xk"] = ("batch", "kv_seq", "kv_heads", None)
        out["xv"] = ("batch", "kv_seq", "kv_heads", None)
    return out
