"""Unified model API over the architecture zoo.

``Model`` exposes:
  - ``param_specs()``        pytree of ParamSpec (abstract — no allocation)
  - ``init(key)``            materialized params
  - ``loss(params, batch)``  next-token CE (+ MoE aux, + MTP) for train_step
  - ``prefill(params, batch)``  full-sequence forward -> (last logits, cache)
  - ``decode(params, cache, tokens)``  one-token serve step
  - ``cache_specs(batch, max_seq)``    decode-cache ShapeDtypeStructs
  - ``input_specs(shape)``   dry-run ShapeDtypeStruct inputs per shape suite

Homogeneous stacks run under ``lax.scan`` with a rematted body (O(1) HLO in
depth); the Griffin interleave is unrolled (3 distinct layer kinds).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionKind, Family, ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import params as prm
from repro.models import transformer as tfm
from repro.models.layers import (embed, embed_specs, rmsnorm, rmsnorm_spec,
                                 sinusoidal_positions, unembed)
from repro.models.params import ParamSpec


@dataclasses.dataclass(frozen=True)
class StackDef:
    name: str
    kinds: tuple[str, ...]
    scan: bool

    @property
    def homogeneous_kind(self) -> str:
        assert self.scan
        return self.kinds[0]


def _stacks_for(cfg: ModelConfig) -> tuple[StackDef, ...]:
    if cfg.family == Family.SSM:
        return (StackDef("layers", ("mamba2",) * cfg.n_layers, True),)
    if cfg.family == Family.HYBRID:
        pat = cfg.hybrid.pattern
        kinds = tuple(pat[i % len(pat)] for i in range(cfg.n_layers))
        return (StackDef("layers", kinds, False),)
    if cfg.family == Family.AUDIO:
        return (StackDef("decoder", ("dec_cross",) * cfg.n_layers, True),)
    if cfg.moe is not None:
        fd = cfg.moe.first_dense
        stacks = []
        if fd:
            stacks.append(StackDef("dense_layers", ("attn_dense",) * fd, True))
        stacks.append(StackDef("moe_layers", ("attn_moe",) * (cfg.n_layers - fd), True))
        return tuple(stacks)
    return (StackDef("layers", ("attn_dense",) * cfg.n_layers, True),)


def _remat(f, policy: str):
    if policy == "none":
        return f
    if policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None, rules=None):
        self.cfg = cfg
        self.stacks = _stacks_for(cfg)
        self.mesh = mesh
        self.rules = rules

    def _constrain(self, x, logical: tuple):
        """Activation sharding constraint at stack boundaries (no-op off-mesh).

        Explicit constraints keep the batch dim dp-sharded through gathers/
        reshapes where GSPMD propagation gives up (it falls back to full
        replication on the embedding gather otherwise).
        """
        if self.mesh is None or self.rules is None:
            return x
        sh = self.rules.sharding(self.mesh, logical, x.shape)
        return jax.lax.with_sharding_constraint(x, sh)

    # --- parameters --------------------------------------------------------

    def param_specs(self):
        cfg = self.cfg
        specs: dict[str, Any] = {"embed": embed_specs(cfg)}
        for st in self.stacks:
            if st.scan:
                one = tfm.layer_specs(cfg, st.homogeneous_kind)
                specs[st.name] = prm.map_stacked(one, len(st.kinds))
            else:
                specs[st.name] = [tfm.layer_specs(cfg, k) for k in st.kinds]
        specs["final_norm"] = rmsnorm_spec(cfg.d_model)
        if cfg.encdec is not None:
            enc_one = tfm.layer_specs(cfg, "enc")
            specs["encoder"] = prm.map_stacked(enc_one, cfg.encdec.n_encoder_layers)
            specs["enc_norm"] = rmsnorm_spec(cfg.d_model)
        if cfg.mtp_depth:
            kind = "attn_moe" if cfg.moe is not None else "attn_dense"
            specs["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model), ("embed", None)),
                "norm_h": rmsnorm_spec(cfg.d_model),
                "norm_e": rmsnorm_spec(cfg.d_model),
                "layer": tfm.layer_specs(cfg, kind),
                "final_norm": rmsnorm_spec(cfg.d_model),
            }
        return specs

    def init(self, key) -> Any:
        return prm.materialize(key, self.param_specs())

    def abstract_params(self):
        return prm.abstract(self.param_specs())

    # --- embedding / frontends ----------------------------------------------

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens).astype(jnp.bfloat16)
        if cfg.family == Family.VLM and "patches" in batch:
            n = batch["patches"].shape[1]
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, n:]], axis=1)
        return self._constrain(x, ("batch", "seq", None))

    def _encode(self, params, frames):
        cfg = self.cfg
        pe = sinusoidal_positions(frames.shape[1], cfg.d_model)
        x = (frames.astype(jnp.float32) + pe).astype(jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32),
                                     frames.shape[:2])

        def body(carry, layer_params):
            y, _ = tfm.layer_apply(layer_params, carry, positions, cfg, "enc",
                                   causal=False)
            return self._constrain(y, ("batch", "seq", None)), None

        x, _ = jax.lax.scan(_remat(body, cfg.remat_policy), x, params["encoder"])
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # --- full-sequence forward ------------------------------------------------

    def forward(self, params, batch, *, n_moe_groups: int = 1):
        """-> (hidden (B,S,d) post-final-norm, aux_loss)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.rope_theta <= 0 and cfg.family == Family.AUDIO:
            pe = sinusoidal_positions(s, cfg.d_model)
            x = (x.astype(jnp.float32) + pe).astype(x.dtype)
        enc_out = None
        if cfg.encdec is not None:
            enc_out = self._encode(params, batch["frames"])
        aux_total = jnp.zeros((), jnp.float32)
        for st in self.stacks:
            if st.scan:
                kind = st.homogeneous_kind

                def body(carry, layer_params, _kind=kind):
                    xc, aux = carry
                    y, a = tfm.layer_apply(layer_params, xc, positions, cfg,
                                           _kind, enc_out=enc_out,
                                           n_moe_groups=n_moe_groups,
                                           constrain=self._constrain
                                           if self.mesh is not None else None)
                    y = self._constrain(y, ("batch", "seq", None))
                    return (y, aux + a), None

                (x, aux_total), _ = jax.lax.scan(
                    _remat(body, cfg.remat_policy), (x, aux_total),
                    params[st.name])
            else:
                for i, kind in enumerate(st.kinds):
                    def body(xc, _p=params[st.name][i], _k=kind):
                        y, a = tfm.layer_apply(_p, xc, positions, cfg, _k,
                                               enc_out=enc_out,
                                               n_moe_groups=n_moe_groups)
                        return y, a
                    x, a = _remat(body, cfg.remat_policy)(x)
                    x = self._constrain(x, ("batch", "seq", None))
                    aux_total = aux_total + a
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return h, aux_total

    # --- training loss ----------------------------------------------------------

    @staticmethod
    def _ce(logits, labels):
        """fp32 CE with -1 = masked. -> (sum_loss, n_valid)."""
        valid = labels >= 0
        lab = jnp.where(valid, labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * valid
        return jnp.sum(nll), jnp.sum(valid.astype(jnp.float32))

    def loss(self, params, batch, *, n_moe_groups: int = 1):
        cfg = self.cfg
        h, aux = self.forward(params, batch, n_moe_groups=n_moe_groups)
        logits = self._constrain(unembed(params["embed"], h, cfg),
                                 ("batch", "seq", "vocab"))
        total, n = self._ce(logits, batch["labels"])
        loss = total / jnp.maximum(n, 1.0)
        metrics = {"ce": loss, "aux": aux, "tokens": n}
        if cfg.mtp_depth:
            mtp = params["mtp"]
            tokens = batch["tokens"]
            e_next = embed(params["embed"], tokens[:, 1:]).astype(h.dtype)
            x_mtp = jnp.concatenate(
                [rmsnorm(mtp["norm_h"], h[:, :-1], cfg.norm_eps),
                 rmsnorm(mtp["norm_e"], e_next, cfg.norm_eps)], axis=-1)
            x_mtp = jnp.einsum("bsk,kd->bsd", x_mtp, mtp["proj"])
            pos = jnp.broadcast_to(jnp.arange(x_mtp.shape[1], dtype=jnp.int32),
                                   x_mtp.shape[:2])
            kind = "attn_moe" if cfg.moe is not None else "attn_dense"
            y, _ = tfm.layer_apply(mtp["layer"], x_mtp, pos, cfg, kind,
                                   n_moe_groups=n_moe_groups)
            h_mtp = rmsnorm(mtp["final_norm"], y, cfg.norm_eps)
            logits_mtp = unembed(params["embed"], h_mtp, cfg)
            t2, n2 = self._ce(logits_mtp, batch["labels"][:, 1:])
            mtp_loss = t2 / jnp.maximum(n2, 1.0)
            metrics["mtp_ce"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        loss = loss + aux
        metrics["loss"] = loss
        return loss, metrics

    # --- serving ------------------------------------------------------------------

    def cache_specs(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        caches: dict[str, Any] = {}
        for st in self.stacks:
            if st.scan:
                one = tfm.layer_cache_spec(cfg, st.homogeneous_kind, batch,
                                           max_seq, dtype)
                caches[st.name] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((len(st.kinds), *s.shape), s.dtype),
                    one)
            else:
                caches[st.name] = [tfm.layer_cache_spec(cfg, k, batch, max_seq, dtype)
                                   for k in st.kinds]
        return {"stacks": caches, "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_logical(self):
        cfg = self.cfg
        out: dict[str, Any] = {}
        for st in self.stacks:
            if st.scan:
                one = tfm.cache_logical(st.homogeneous_kind, cfg)
                out[st.name] = jax.tree.map(
                    lambda spec: ("layers", *spec), one,
                    is_leaf=lambda v: isinstance(v, tuple))
            else:
                out[st.name] = [tfm.cache_logical(k, cfg) for k in st.kinds]
        return {"stacks": out, "pos": ()}

    def prefill(self, params, batch, *, max_seq: int, cache_dtype=jnp.bfloat16):
        """Full-sequence forward that also builds the decode cache."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.rope_theta <= 0 and cfg.family == Family.AUDIO:
            pe = sinusoidal_positions(s, cfg.d_model)
            x = (x.astype(jnp.float32) + pe).astype(x.dtype)
        enc_out = None
        if cfg.encdec is not None:
            enc_out = self._encode(params, batch["frames"])
        caches: dict[str, Any] = {}
        for st in self.stacks:
            if st.scan:
                kind = st.homogeneous_kind

                def body(xc, layer_params, _kind=kind):
                    y, c = tfm.layer_prefill(layer_params, xc, positions, cfg,
                                             _kind, max_seq=max_seq,
                                             enc_out=enc_out,
                                             cache_dtype=cache_dtype)
                    return self._constrain(y, ("batch", "seq", None)), c

                x, caches[st.name] = jax.lax.scan(
                    _remat(body, cfg.remat_policy), x, params[st.name])
            else:
                lst = []
                for i, kind in enumerate(st.kinds):
                    x, c = tfm.layer_prefill(params[st.name][i], x, positions,
                                             cfg, kind, max_seq=max_seq,
                                             enc_out=enc_out,
                                             cache_dtype=cache_dtype)
                    lst.append(c)
                caches[st.name] = lst
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], h[:, -1:], cfg)
        return logits, {"stacks": caches, "pos": jnp.asarray(s, jnp.int32)}

    def decode(self, params, cache, tokens):
        """One-token step. tokens: (B,1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = embed(params["embed"], tokens).astype(jnp.bfloat16)
        if cfg.rope_theta <= 0 and cfg.family == Family.AUDIO:
            pe = jax.lax.dynamic_slice_in_dim(
                sinusoidal_positions(65536, cfg.d_model), pos, 1, axis=0)
            x = (x.astype(jnp.float32) + pe[None]).astype(x.dtype)
        new_caches: dict[str, Any] = {}
        for st in self.stacks:
            if st.scan:
                kind = st.homogeneous_kind

                def body(xc, xs, _kind=kind):
                    layer_params, layer_cache = xs
                    y, c = tfm.layer_decode(layer_params, xc, layer_cache, pos,
                                            cfg, _kind)
                    return self._constrain(y, ("batch", "seq", None)), c

                x, new_caches[st.name] = jax.lax.scan(
                    body, x, (params[st.name], cache["stacks"][st.name]))
            else:
                lst = []
                for i, kind in enumerate(st.kinds):
                    x, c = tfm.layer_decode(params[st.name][i], x,
                                            cache["stacks"][st.name][i], pos,
                                            cfg, kind)
                    lst.append(c)
                new_caches[st.name] = lst
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg)
        return logits, {"stacks": new_caches, "pos": pos + 1}

    # --- dry-run input specs ---------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        Train cells with grad accumulation are microbatch-major: every leaf
        is (M, B/M, ...) with the second dim dp-sharded.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        m = shape.num_microbatches if shape.kind == "train" else 1
        lead = (m, b // m) if m > 1 else (b,)
        specs = {"tokens": jax.ShapeDtypeStruct((*lead, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((*lead, s), jnp.int32)
        if cfg.encdec is not None:
            specs["frames"] = jax.ShapeDtypeStruct(
                (*lead, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == Family.VLM and cfg.n_frontend_tokens:
            specs["patches"] = jax.ShapeDtypeStruct(
                (*lead, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        return specs

    def input_logical(self, shape: ShapeConfig) -> dict:
        m = shape.num_microbatches if shape.kind == "train" else 1
        lead = (None, "batch") if m > 1 else ("batch",)
        out = {"tokens": (*lead, None)}
        if shape.kind == "train":
            out["labels"] = (*lead, None)
        if shape.kind != "decode":
            if self.cfg.encdec is not None:
                out["frames"] = (*lead, None, None)
            if self.cfg.family == Family.VLM and self.cfg.n_frontend_tokens:
                out["patches"] = (*lead, None, None)
        return out


def make_model(cfg: ModelConfig, mesh=None, rules=None) -> Model:
    return Model(cfg, mesh=mesh, rules=rules)
