"""Attention mixers: GQA (covers MHA/MQA), sliding-window local attention,
MLA (DeepSeek multi-head latent attention), and encoder cross-attention.

Memory discipline: training/prefill attention is *chunked over query blocks*
(lax.scan with a rematted body), so peak logits memory is
(B, block_q, T) rather than (B, S, T) — the pure-XLA flash-attention
pattern. A Pallas flash kernel (kernels/flash_attn.py) is the TPU fast path;
this module is the portable XLA path the dry-run lowers.

Two execution modes share one parameterization:

- ``full``  : training / prefill over a whole sequence (causal or bidir)
- ``decode``: one new token against a cache; GQA caches (k, v); MLA caches
  the *latent* (c_kv, k_rope) and uses the absorbed-matmul formulation, so
  decode FLOPs/bytes scale with kv_lora_rank instead of H*Dh.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionKind, ModelConfig
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_spec
from repro.models.params import ParamSpec

NEG_INF = -1e30
DEFAULT_Q_CHUNK = 512


# --- parameter specs ----------------------------------------------------------

def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.attention == AttentionKind.MLA and not cross:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", None)),
            "q_norm": rmsnorm_spec(m.q_lora_rank),
            "wq_b": ParamSpec((m.q_lora_rank, h, qk), (None, "heads", None)),
            "wkv_a": ParamSpec((d, m.kv_lora_rank), ("embed", None)),
            "kv_norm": rmsnorm_spec(m.kv_lora_rank),
            "wk_rope": ParamSpec((d, m.qk_rope_head_dim), ("embed", None)),
            "wk_b": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                              (None, "heads", None)),
            "wv_b": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                              (None, "heads", None)),
            "wo": ParamSpec((h, m.v_head_dim, d), ("heads", None, "embed")),
        }
    # "qk_dim" falls back to the model axis when the head count does not
    # divide it (e.g. 24 heads on a 16-way TP axis): the contraction over a
    # sharded head_dim yields partial sums + one all-reduce, which beats
    # replicating the whole attention computation across TP.
    return {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "qk_dim")),
        "wk": ParamSpec((d, kvh, dh), ("embed", "kv_heads", "qk_dim")),
        "wv": ParamSpec((d, kvh, dh), ("embed", "kv_heads", "qk_dim")),
        "wo": ParamSpec((h, dh, d), ("heads", "qk_dim", "embed")),
    }


# --- masking -------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int):
    """(..., S_q, S_k) additive fp32 bias from position comparisons."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok = ok & (dk <= dq)
    if window:
        ok = ok & (dq - dk < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _pick_chunk(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (shapes here are powers of two)."""
    c = min(want, s)
    while s % c:
        c -= 1
    return max(c, 1)


# --- chunked softmax-attention core ---------------------------------------------

def _chunked_attn(q, k, v, q_pos, k_pos, scale, *, causal: bool, window: int,
                  q_chunk: int = DEFAULT_Q_CHUNK, constrain=None):
    """q:(B,S,KVH,G,D) k:(B,T,KVH,D) v:(B,T,KVH,Dv) -> (B,S,KVH,G,Dv).

    Scans over query chunks with a rematted body: peak logits memory is
    (B,KVH,G,c,T) for one chunk c, and the backward pass recomputes each
    chunk's logits instead of storing them (flash-attention memory shape).
    """
    b, s, kvh, g, d = q.shape
    c = _pick_chunk(s, q_chunk)
    n = s // c
    qc = q.reshape(b, n, c, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    pc = jnp.broadcast_to(q_pos, (b, s)).reshape(b, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        q_blk, p_blk = xs                                    # (B,c,KVH,G,D), (B,c)
        if constrain is not None:
            # sequence-parallel attention: shard the query chunk over the
            # model axis (each TP shard scores c/tp queries vs the full K/V)
            # — the TP strategy for head counts that don't divide the axis.
            q_blk = constrain(q_blk, ("batch", "attn_q_seq", None, None, None))
        logits = jnp.einsum("bckgd,btkd->bkgct", q_blk, k).astype(jnp.float32)
        logits = logits * scale
        bias = _mask_bias(p_blk, k_pos, causal=causal, window=window)  # (B,c,T)
        logits = logits + bias[:, None, None]
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgct,btkd->bckgd", w, v)
        return carry, out

    _, outs = jax.lax.scan(body, 0, (qc, pc))                # (n,B,c,KVH,G,Dv)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kvh, g, v.shape[-1])


# --- GQA / local ----------------------------------------------------------------

def gqa_full(params, x, positions, cfg: ModelConfig, *, causal=True,
             window: int = 0, kv_x=None, kv_positions=None, return_kv=False,
             constrain=None):
    """Training/prefill attention. kv_x!=None -> cross attention (no rope)."""
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kvh
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, params["wv"])
    if kv_x is None and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if kv_positions is None else kv_positions,
                       cfg.rope_theta)
    qg = q.reshape(*q.shape[:2], kvh, g, dh)
    if kv_x is None:
        kpos = positions if kv_positions is None else kv_positions
        do_causal, do_window = causal, window
    else:
        kpos = jnp.arange(src.shape[1], dtype=jnp.int32)[None, :]
        do_causal, do_window = False, 0
    out = _chunked_attn(qg, k, v, positions, kpos,
                        1.0 / jnp.sqrt(float(dh)), causal=do_causal,
                        window=do_window, constrain=constrain)
    out = out.reshape(*x.shape[:2], h, dh)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(params, x, cache: dict, pos, cfg: ModelConfig, *, window: int = 0):
    """One-token decode against a ring-buffer cache.

    cache: {'k','v': (B,Tbuf,KVH,Dh), 'kpos': (Tbuf,) absolute positions
    (-1 = empty)}. ``pos`` is the absolute position of the new token. For
    windowed (local) attention Tbuf == window, so 500k-context decode costs
    O(window) — the point of the sub-quadratic archs.
    """
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kvh
    tbuf = cache["k"].shape[1]
    write = jnp.mod(pos, tbuf)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])      # S == 1
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.rope_theta > 0:
        p = jnp.broadcast_to(pos[None, None], x.shape[:2])
        q = apply_rope(q, p, cfg.rope_theta)
        k_new = apply_rope(k_new, p, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), write, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), write, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"], pos[None].astype(jnp.int32), write, axis=0)
    valid = (kpos >= 0) & (kpos <= pos)
    if window:
        valid = valid & (kpos > pos - window)
    logits = jnp.einsum("bskgd,btkd->bkgst",
                        q.reshape(*q.shape[:2], kvh, g, dh), k)
    logits = logits.astype(jnp.float32) / jnp.sqrt(float(dh))
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(*x.shape[:2], h, dh)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v, "kpos": kpos}


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int,
                   dtype=jnp.bfloat16) -> dict:
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.window:
        max_seq = min(max_seq, cfg.window)        # ring buffer bound (local attn)
    shape = (n_layers, batch, max_seq, kvh, dh)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "kpos": jax.ShapeDtypeStruct((n_layers, max_seq), jnp.int32),
    }


# --- MLA ------------------------------------------------------------------------

def _mla_qkv(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    c_kv = rmsnorm(params["kv_norm"], jnp.einsum("bsd,dr->bsr", x, params["wkv_a"]),
                   cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["wk_rope"])[..., None, :]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_full(params, x, positions, cfg: ModelConfig, *, causal=True,
             q_chunk: int = DEFAULT_Q_CHUNK, return_kv=False):
    """Expanded MLA for train/prefill, chunked over query blocks."""
    m = cfg.mla
    h = cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, params["wk_b"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, params["wv_b"])
    scale = 1.0 / jnp.sqrt(float(m.qk_nope_head_dim + m.qk_rope_head_dim))
    c = _pick_chunk(s, q_chunk)
    n = s // c
    qn = q_nope.reshape(b, n, c, h, -1).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(b, n, c, h, -1).transpose(1, 0, 2, 3, 4)
    pc = jnp.broadcast_to(positions, (b, s)).reshape(b, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        qn_b, qr_b, p_b = xs
        logits = (
            jnp.einsum("bchk,bthk->bhct", qn_b, k_nope)
            + jnp.einsum("bchk,btk->bhct", qr_b, k_rope)
        ).astype(jnp.float32) * scale
        bias = _mask_bias(p_b, positions, causal=causal, window=0)
        logits = logits + bias[:, None]
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhct,bthk->bchk", w, v)
        return carry, out

    _, outs = jax.lax.scan(body, 0, (qn, qr, pc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, m.v_head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(params, x, cache: dict, pos, cfg: ModelConfig):
    """Absorbed-matmul MLA decode against the latent cache.

    cache: {'c_kv': (B,T,r_kv), 'k_rope': (B,T,r_rope)}; ``pos`` is the
    absolute position of the new token. W_uk is absorbed into the query,
    W_uv into the output — per-step cost scales with r_kv (512) not
    H*Dh (16384) [DeepSeek-V2 §2.1.2].
    """
    m = cfg.mla
    p = jnp.broadcast_to(pos[None, None], x.shape[:2])
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, x, p, cfg)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
    # absorb: q_lat[h] = q_nope[h] @ W_uk[h]^T  -> score against latent directly
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])
    scale = 1.0 / jnp.sqrt(float(m.qk_nope_head_dim + m.qk_rope_head_dim))
    logits = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    t = c_kv.shape[1]
    valid = jnp.arange(t, dtype=jnp.int32) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", w, c_kv)
    out = jnp.einsum("bshr,rhk->bshk", ctx_lat, params["wv_b"])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_spec(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int,
                   dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((n_layers, batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((n_layers, batch, max_seq, m.qk_rope_head_dim), dtype),
    }
