"""Mixture-of-experts FFN with sort-based capacity dispatch.

Scalability note (this is what makes 256-expert/1M-token cells lower):
the classic one-hot dispatch tensor (T, E, C) is O(T*E*C) and cannot exist
at DeepSeek-V3 scale. We instead sort the T*K (token, expert) assignments by
expert id, compute each assignment's rank within its expert via the sorted
run starts, and scatter rows into an (E, C, d) buffer (overflow rows drop,
standard capacity semantics). Combine is the reverse gather weighted by
router probabilities. Cost: O(TK log TK) sort + O(TK d) data movement.

Tokens are pre-grouped into ``n_groups`` independent dispatch groups (one per
data shard at scale) so the sort never crosses the sharded token axis; the
(E, C, d) buffers are sharded over the 'experts'->model mesh axis, which is
exactly expert parallelism (the reshard is XLA's all-to-all).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert or cfg.d_ff
    specs = {
        "router": ParamSpec((d, m.n_experts), ("embed", None), dtype=jnp.float32),
        "w_gate": ParamSpec((m.n_experts, d, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((m.n_experts, d, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((m.n_experts, f, d), ("experts", "mlp", "embed")),
    }
    if m.router == "sigmoid":
        specs["router_bias"] = ParamSpec((m.n_experts,), (None,), init="zeros",
                                         dtype=jnp.float32)
    if m.n_shared:
        fs = f * m.n_shared
        specs["shared_gate"] = ParamSpec((d, fs), ("embed", "mlp"))
        specs["shared_up"] = ParamSpec((d, fs), ("embed", "mlp"))
        specs["shared_down"] = ParamSpec((fs, d), ("mlp", "embed"))
    return specs


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cf = ops.moe_capacity_factor(m.capacity_factor)
    c = math.ceil(tokens_per_group * m.top_k * cf / m.n_experts)
    return max(8, -(-c // 8) * 8)     # round up to a multiple of 8


def _routing(params, x_flat, cfg: ModelConfig):
    """x_flat: (G, T, d) -> (weights (G,T,K) fp32, ids (G,T,K) int32, aux loss)."""
    m = cfg.moe
    logits = jnp.einsum("gtd,de->gte", x_flat.astype(jnp.float32),
                        params["router"])
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, None, :]
        _, ids = jax.lax.top_k(sel, m.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        aux = jnp.zeros((), jnp.float32)              # aux-loss-free routing
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, m.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        # Switch-style load-balance loss: E * sum_e f_e * P_e
        pe = jnp.mean(probs, axis=(0, 1))
        fe = jnp.zeros((m.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
        fe = fe / ids.size
        aux = m.aux_loss_weight * m.n_experts * jnp.sum(fe * pe)
    return w, ids.astype(jnp.int32), aux


def _dispatch_indices(ids_flat, n_experts: int, cap: int):
    """ids_flat: (A,) sorted-free assignment ids -> (dest slot or OOB, perm).

    Returns per-assignment destination slot in the (E*C) buffer with
    overflow mapped to E*C (dropped by scatter mode='drop').
    """
    a = ids_flat.shape[0]
    order = jnp.argsort(ids_flat, stable=True)            # sort by expert
    sorted_ids = ids_flat[order]
    counts = jax.ops.segment_sum(jnp.ones((a,), jnp.int32), ids_flat,
                                 num_segments=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    rank_sorted = jnp.arange(a, dtype=jnp.int32) - starts[sorted_ids]
    rank = jnp.zeros((a,), jnp.int32).at[order].set(rank_sorted)
    ok = rank < cap
    dest = jnp.where(ok, ids_flat * cap + rank, n_experts * cap)
    return dest, ok


def moe_ffn(params, x, cfg: ModelConfig, *, n_groups: int = 1):
    """x: (B, S, d) -> (y, aux_loss). Capacity dispatch + expert GLU FFN."""
    m = cfg.moe
    b, s, d = x.shape
    t_total = b * s
    g = n_groups if t_total % n_groups == 0 else 1
    tg = t_total // g
    x_flat = x.reshape(g, tg, d)
    w, ids, aux = _routing(params, x_flat, cfg)
    cap = capacity(tg, cfg)
    k = m.top_k
    e = m.n_experts

    def one_group(xg, idg, wg):
        # xg: (T,d), idg: (T,K), wg: (T,K)
        ids_flat = idg.reshape(-1)                        # (T*K,)
        dest, ok = _dispatch_indices(ids_flat, e, cap)
        rows = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)
        buf = jnp.zeros((e * cap, d), xg.dtype)
        buf = buf.at[dest].set(xg[rows], mode="drop")     # (E*C, d)
        buf = buf.reshape(e, cap, d)
        gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xg.dtype) * up
        out = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * cap, d)
        # combine: gather back, zero for dropped assignments
        gathered = jnp.where(ok[:, None], out.at[dest].get(mode="fill",
                                                           fill_value=0), 0)
        y = jax.ops.segment_sum(gathered * wg.reshape(-1, 1).astype(xg.dtype),
                                rows, num_segments=tg)
        return y

    y = jax.vmap(one_group)(x_flat, ids, w)
    y = y.reshape(b, s, d)
    if m.n_shared:
        sg = jnp.einsum("bsd,df->bsf", x, params["shared_gate"])
        su = jnp.einsum("bsd,df->bsf", x, params["shared_up"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + jnp.einsum("bsf,fd->bsd", sh, params["shared_down"])
    return y, aux
