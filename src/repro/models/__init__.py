from repro.models.model import Model, make_model

__all__ = ["Model", "make_model"]
