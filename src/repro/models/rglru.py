"""Griffin recurrent block: temporal conv + RG-LRU gated linear recurrence
[arXiv:2402.19427].

The linear recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` (log-depth, parallelizable over the sequence —
the TPU-friendly formulation of the paper's custom linear-scan kernel).
Decode is the O(1) recurrent update; the state is (B, W) + a conv tail —
window-free, which is what makes long_500k feasible for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    k = cfg.hybrid.conv_width
    return {
        "in_x": ParamSpec((d, w), ("embed", "mlp")),
        "in_gate": ParamSpec((d, w), ("embed", "mlp")),
        "conv_w": ParamSpec((k, w), (None, "mlp")),
        "conv_b": ParamSpec((w,), ("mlp",), init="zeros"),
        "w_a": ParamSpec((w, w), ("mlp", None)),
        "b_a": ParamSpec((w,), (None,), init="zeros"),
        "w_i": ParamSpec((w, w), ("mlp", None)),
        "b_i": ParamSpec((w,), (None,), init="zeros"),
        "lam": ParamSpec((w,), (None,), init="lambda_lru", dtype=jnp.float32),
        "out": ParamSpec((w, d), ("mlp", "embed")),
    }


def _gates(params, x):
    """x: (..., W) -> (log_a, gated_input) both fp32."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wk->...k", x, params["w_a"])
                       .astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wk->...k", x, params["w_i"])
                       .astype(jnp.float32) + params["b_i"])
    log_a = -_C * r * jax.nn.softplus(params["lam"])             # (..., W) <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x.astype(jnp.float32))
    return log_a, gated


def _conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : xp.shape[1] - (k - 1 - i), :] * w[i] for i in range(k))
    return out + b


def rglru_forward(params, x, cfg: ModelConfig, *, return_state: bool = False):
    """Full-sequence recurrent block. x: (B,S,d) -> (B,S,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"])
                       .astype(jnp.float32))
    xb_raw = jnp.einsum("bsd,dw->bsw", x, params["in_x"])
    xb = _conv(xb_raw, params["conv_w"], params["conv_b"])
    log_a, bterm = _gates(params, xb)
    a = jnp.exp(log_a)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    y = (gate * h).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["out"])
    if return_state:
        k = cfg.hybrid.conv_width
        tail = xb_raw[:, -(k - 1):, :]
        if tail.shape[1] < k - 1:
            pad = k - 1 - tail.shape[1]
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"conv": tail, "h": h[:, -1]}
    return out


# --- decode ---------------------------------------------------------------------

def rglru_cache_spec(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype=jnp.bfloat16) -> dict:
    w = cfg.hybrid.lru_width or cfg.d_model
    k = cfg.hybrid.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((n_layers, batch, k - 1, w), dtype),
        "h": jax.ShapeDtypeStruct((n_layers, batch, w), jnp.float32),
    }


def rglru_decode(params, x, layer_cache, cfg: ModelConfig):
    """Single-token update. x: (B,1,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"])
                       .astype(jnp.float32))[:, 0]
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"])[:, 0]      # (B,W)
    hist = jnp.concatenate([layer_cache["conv"],
                            xb[:, None].astype(layer_cache["conv"].dtype)], axis=1)
    xc = jnp.einsum("bkw,kw->bw", hist.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    log_a, bterm = _gates(params, xc.astype(x.dtype))
    h = layer_cache["h"] * jnp.exp(log_a) + bterm
    y = (gate * h).astype(x.dtype)[:, None]
    out = jnp.einsum("bsw,wd->bsd", y, params["out"])
    return out, {"conv": hist[:, 1:].astype(layer_cache["conv"].dtype), "h": h}
