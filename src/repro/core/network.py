"""Network-level event-driven LASANA simulation engine (paper §V-E at scale).

Composes multiple circuit banks (LIF layers wired by synaptic weight
matrices, or tiled crossbar-row layers) into a layered dataflow graph and
runs the paper's Algorithm 1 across the whole network:

  * batched per-tick event queues — each tick, the spike vector emitted by
    layer i-1 is the event queue consumed by layer i; per-neuron ``changed``
    masks mark which circuits received an input event, so idle neurons are
    skipped and later caught up with ONE merged E2 event (wrapper.py);
  * per-bank jit-compiled steps for three backends over the same graph:
      golden      — sub-step ODE integration of every circuit every tick
      behavioral  — SV-RNM ideal discrete update (no energy/latency)
      lasana      — Algorithm 1 over a trained PredictorBank, in
                    ``standalone`` mode (surrogate predicts spikes + state +
                    energy/latency) or ``annotation`` mode (behavioral model
                    supplies spikes/state, LASANA adds energy/latency);
  * ``shard_map`` batch parallelism over the device mesh via
    core/distributed.py — circuits are batch-local, so a whole network tick
    shards over the flattened mesh with only diagnostic psums;
  * a network-level report aggregating per-layer energy / latency / event
    counts plus an end-of-run flush that charges the static energy of
    still-idle circuits (so event-driven totals are comparable to golden).

Usage::

    from repro.core.network import NetworkEngine, snn_spec

    spec = snn_spec(weights, params_per_layer)        # LIF layers
    golden = NetworkEngine(spec, backend="golden").run(spike_seq)
    lasana = NetworkEngine(spec, backend="lasana", bank=bank).run(spike_seq)
    print(lasana.report()["network"])                 # energy, events/s, ...

    xspec = crossbar_mlp_spec(ternary_weights)        # tiled crossbar MLP
    run = NetworkEngine(xspec, backend="lasana", bank=xbank).run(x_volts)

``spike_seq`` is (T, B, n_in) spike amplitudes; crossbar inputs are
(B, n_in) volts. Pass ``mesh=Mesh(...)`` to shard the batch axis.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.circuits import CrossbarRow, LIFNeuron, get_circuit
from repro.core.distributed import batch_spec, shard_over_batch
from repro.core.wrapper import LasanaState, init_state, lasana_step

P_REPL = P()                     # replicated diagnostics spec
BACKENDS = ("golden", "behavioral", "lasana")
MODES = ("standalone", "annotation")


# --- network specification ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One bank of circuits fed by a synaptic/row weight matrix."""

    weight: Any                 # (fan_in, n_out)
    params: Any                 # (n_out, n_p) or (n_p,) broadcast knobs

    @property
    def n_out(self) -> int:
        return self.weight.shape[1]


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    layers: tuple
    circuit: str = "lif"
    spike_amp: float = 1.5      # V_dd spike amplitude on the event queues
    seg_width: int = 32         # crossbar: row segment width
    adc_bits: int = 8           # crossbar: ADC resolution between layers
    activation: str = "tanh"    # crossbar: digital activation between layers

    @property
    def n_layers(self) -> int:
        return len(self.layers)


def snn_spec(weights, params_per_layer, *, spike_amp: float = 1.5
             ) -> NetworkSpec:
    """Feed-forward SNN of LIF banks: weights[i] (fan_in_i, n_out_i)."""
    layers = tuple(
        LayerSpec(weight=jnp.asarray(w, jnp.float32),
                  params=jnp.asarray(p, jnp.float32))
        for w, p in zip(weights, params_per_layer))
    return NetworkSpec(layers=layers, circuit="lif", spike_amp=spike_amp)


def crossbar_mlp_spec(weights, *, seg_width: int = 32, adc_bits: int = 8,
                      activation: str = "tanh") -> NetworkSpec:
    """Ternary-weight MLP tiled onto ``seg_width``-input crossbar rows."""
    layers = tuple(LayerSpec(weight=jnp.asarray(w, jnp.float32),
                             params=None) for w in weights)
    return NetworkSpec(layers=layers, circuit="crossbar",
                       seg_width=seg_width, adc_bits=adc_bits,
                       activation=activation)


def drive_to_circuit_inputs(drive):
    """Aggregate synaptic drive -> (w, x, n) LIF circuit inputs."""
    w = jnp.clip(drive, -1.0, 1.0)
    x = jnp.full_like(drive, 1.5)
    n = jnp.full_like(drive, 5.0)
    return jnp.stack([w, x, n], axis=-1)


def _tile_params(p, b: int, n_out: int):
    p = jnp.asarray(p, jnp.float32)
    if p.ndim == 1:                       # one knob set for the whole layer
        return jnp.broadcast_to(p[None], (b * n_out, p.shape[0]))
    return jnp.tile(p, (b, 1))            # per-neuron knobs, batch-tiled


def _row_segments(w, seg_width: int):
    """(n_in, n_out) ternary matrix -> (n_out * n_seg, seg_width + 1)
    crossbar row params (last column is the bias row, unused here)."""
    w = np.asarray(w)
    n_in, n_out = w.shape
    n_seg = -(-n_in // seg_width)
    pad = n_seg * seg_width - n_in
    wp = np.pad(w, ((0, pad), (0, 0)))
    segs = (wp.reshape(n_seg, seg_width, n_out)
            .transpose(2, 0, 1).reshape(-1, seg_width))
    return np.concatenate([segs, np.zeros((len(segs), 1))],
                          axis=1).astype(np.float32)


# --- run record ---------------------------------------------------------------

@dataclasses.dataclass
class NetworkRun:
    """Record of one network simulation (spiking: T ticks; crossbar: T=L)."""

    backend: str
    mode: str
    outputs: np.ndarray           # spiking: (B, n_cls) spike counts;
                                  # crossbar: (B, n_cls) analog logits
    out_spikes: Optional[np.ndarray]   # spiking: (T, B, n_cls) amplitudes
    layer_spikes: Optional[list]  # spiking: per layer (T, B, n_i) amplitudes
    energy: np.ndarray            # (T, L) joules per tick per layer
    latency: np.ndarray           # (T, L) ns — max over the layer's circuits
    events: np.ndarray            # (T, L) input events processed
    flush_energy: np.ndarray      # (L,) end-of-run idle static energy
    n_circuits: np.ndarray        # (L,) circuits per layer (B-included)
    clock_ns: float
    wall_seconds: float

    def report(self) -> dict:
        """Aggregate per-layer energy/latency/events + network totals."""
        t_steps, n_layers = self.energy.shape
        layers = []
        for i in range(n_layers):
            layers.append({
                "layer": i,
                "n_circuits": int(self.n_circuits[i]),
                "energy_j": float(self.energy[:, i].sum()
                                  + self.flush_energy[i]),
                "flush_energy_j": float(self.flush_energy[i]),
                "events": int(self.events[:, i].sum()),
                "max_latency_ns": float(self.latency[:, i].max(initial=0.0)),
                "mean_tick_latency_ns": float(self.latency[:, i].mean()),
            })
        total_events = int(self.events.sum())
        return {
            "backend": self.backend,
            "mode": self.mode,
            "layers": layers,
            "network": {
                "ticks": t_steps,
                "sim_time_ns": t_steps * self.clock_ns,
                "energy_j": float(sum(l["energy_j"] for l in layers)),
                "events": total_events,
                "events_per_sec": total_events / max(self.wall_seconds, 1e-9),
                "wall_seconds": self.wall_seconds,
            },
        }


# --- the engine ----------------------------------------------------------------

class NetworkEngine:
    """Layered dataflow graph of circuit banks under one jitted scheduler.

    backend  "golden" | "behavioral" | "lasana"
    mode     lasana only: "standalone" (surrogate closes the loop) or
             "annotation" (behavioral supplies spikes/state, LASANA adds
             energy/latency)
    bank     PredictorBank — required for backend="lasana"
    mesh     optional jax Mesh: shard the batch axis over every mesh axis
    record_hidden  keep per-layer spike trains (tests/parity); disable for
             large sweeps to save host memory
    """

    def __init__(self, spec: NetworkSpec, backend: str = "lasana", *,
                 bank=None, mode: str = "standalone", mesh=None,
                 record_hidden: bool = True):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}: {backend}")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {mode}")
        if backend == "lasana" and bank is None:
            raise ValueError("backend='lasana' requires a PredictorBank")
        self.spec = spec
        self.backend = backend
        self.mode = mode if backend == "lasana" else "standalone"
        self.bank = bank
        self.mesh = mesh
        self.record_hidden = record_hidden
        self.circ = get_circuit(spec.circuit)
        if isinstance(self.circ, LIFNeuron) \
                and spec.spike_amp != self.circ.vdd:
            # spike amplitude IS the circuit's V_dd: the wrapper's spike
            # threshold (0.5 * 1.5) and behavioral/golden outputs are all
            # V_dd-referenced, so other amplitudes would silently diverge
            # across backends
            raise ValueError(
                f"spike_amp {spec.spike_amp} != circuit V_dd "
                f"{self.circ.vdd}; the LIF event queues carry V_dd spikes")
        self._sim_cache: dict = {}

    # --- public entry point ---------------------------------------------------

    def run(self, inputs) -> NetworkRun:
        """Spiking: inputs (T, B, n_in) spike amplitudes.
        Crossbar: inputs (B, n_in) volts."""
        if isinstance(self.circ, LIFNeuron):
            return self._run_spiking(jnp.asarray(inputs, jnp.float32))
        return self._run_crossbar(jnp.asarray(inputs, jnp.float32))

    # --- spiking path ---------------------------------------------------------

    def _init_carry(self, i: int, b: int):
        layer = self.spec.layers[i]
        n = b * layer.n_out
        params = _tile_params(layer.params, b, layer.n_out)
        if self.backend == "golden":
            return self.circ.init_state(n), params
        if self.backend == "behavioral":
            return jnp.zeros((n,), jnp.float32), params
        # lasana: annotation mode keeps the behavioral voltage in .v
        return init_state(n, params)

    def _layer_step(self, i: int, b: int):
        """Returns tick(carry, s_in, t) -> (carry', spikes, e, l, events)."""
        layer = self.spec.layers[i]
        amp = self.spec.spike_amp
        circ, bank, clock = self.circ, self.bank, self.circ.clock_ns
        w = layer.weight
        conn = (jnp.abs(w) > 0).astype(jnp.float32)
        n_out = layer.n_out
        backend, mode = self.backend, self.mode

        def tick(carry, s_in, t):
            drive = (s_in @ w) / amp                       # (B, n_out)
            # event queue delivery: a circuit has an input event iff any
            # presynaptic spike reaches it through a nonzero weight
            pre = (s_in > 0.5 * amp).astype(jnp.float32)
            incoming = (pre @ conn) > 0.5                  # (B, n_out)
            changed = incoming.reshape(-1)
            xin = drive_to_circuit_inputs(drive).reshape(-1, 3)

            if backend == "golden":
                state, params = carry
                new_state, obs = circ.step(state, xin, params)
                spikes = jnp.where(obs["spiked"], amp, 0.0)
                e, l = obs["energy"], jnp.where(obs["spiked"],
                                                obs["latency"], 0.0)
                carry = (new_state, params)
            elif backend == "behavioral":
                v, params = carry
                xin_m = jnp.where(changed[:, None], xin, 0.0)
                v_new, out = circ.behavioral_step(v, xin_m, params)
                spikes = out
                e = jnp.zeros_like(v)
                l = jnp.zeros_like(v)
                carry = (v_new, params)
            elif mode == "annotation":
                xin_m = jnp.where(changed[:, None], xin, 0.0)
                v_new, out = circ.behavioral_step(carry.v, xin_m,
                                                  carry.params)
                ns, e, l, _ = lasana_step(bank, carry, changed, xin, t,
                                          clock, spiking=True, known_out=out)
                spikes = out
                carry = ns._replace(v=v_new, o=out)
            else:                                           # standalone
                ns, e, l, o = lasana_step(bank, carry, changed, xin, t,
                                          clock, spiking=True)
                spikes = jnp.where(changed, o, 0.0)
                carry = ns

            spikes = spikes.reshape(b, n_out)
            return carry, spikes, e, l, changed

        return tick

    def _flush(self, carry, i: int, t_end):
        """Charge trailing-idle static energy (merged E2 to t_end)."""
        if self.backend != "lasana":
            return jnp.zeros(())
        lst = carry
        tau = t_end - lst.t_last
        n_in = self.circ.n_inputs
        feats = jnp.concatenate(
            [jnp.zeros((lst.v.shape[0], n_in), jnp.float32),
             lst.v[:, None], tau[:, None], lst.params], axis=1)
        e = self.bank.predict("M_ES", feats)
        return jnp.sum(jnp.where(tau > 0, e, 0.0))

    def _build_spiking_sim(self, b: int):
        spec = self.spec
        n_layers = spec.n_layers
        clock = self.circ.clock_ns
        steps = [self._layer_step(i, b) for i in range(n_layers)]
        record_hidden = self.record_hidden
        sharded = self.mesh is not None
        axes = tuple(self.mesh.axis_names) if sharded else ()

        def sim(spike_seq, carries):
            t_steps = spike_seq.shape[0]
            times = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * clock

            def tick(carries, xs):
                spikes_t, t = xs
                s = spikes_t
                new_carries, layer_sp, es, ls, evs = [], [], [], [], []
                for i in range(n_layers):
                    carry, s, e, l, changed = steps[i](carries[i], s, t)
                    new_carries.append(carry)
                    layer_sp.append(s)
                    es.append(jnp.sum(e))
                    ls.append(jnp.max(l))
                    evs.append(jnp.sum(changed.astype(jnp.float32)))
                out = (s, tuple(layer_sp) if record_hidden else (),
                       jnp.stack(es), jnp.stack(ls), jnp.stack(evs))
                return new_carries, out

            carries, (out_sp, hidden, e_tl, l_tl, ev_tl) = jax.lax.scan(
                tick, list(carries), (spike_seq, times))
            counts = jnp.sum(out_sp > 0.5 * spec.spike_amp, axis=0)
            t_end = t_steps * clock
            flush = jnp.stack([self._flush(carries[i], i, t_end)
                               for i in range(n_layers)])
            if sharded:        # diagnostics are the only collectives
                e_tl = jax.lax.psum(e_tl, axes)
                l_tl = jax.lax.pmax(l_tl, axes)
                ev_tl = jax.lax.psum(ev_tl, axes)
                flush = jax.lax.psum(flush, axes)
            return counts, out_sp, hidden, e_tl, l_tl, ev_tl, flush

        if not sharded:
            return jax.jit(sim)

        mesh = self.mesh
        cspec = batch_spec(mesh)                     # flattened (B*n,) arrays
        carry_specs = []
        for i in range(spec.n_layers):
            carry = jax.tree.map(lambda _: cspec, self._init_carry(i, b))
            carry_specs.append(carry)
        seq_spec = batch_spec(mesh, ndim=3, axis=1)
        hidden_spec = tuple(seq_spec for _ in range(spec.n_layers)) \
            if self.record_hidden else ()
        out_specs = (batch_spec(mesh, ndim=2), seq_spec, hidden_spec,
                     P_REPL, P_REPL, P_REPL, P_REPL)
        return shard_over_batch(sim, mesh, in_specs=(seq_spec, carry_specs),
                                out_specs=out_specs)

    def _run_spiking(self, spike_seq) -> NetworkRun:
        t_steps, b, _ = spike_seq.shape
        if self.mesh is not None:
            n_dev = int(np.prod([self.mesh.shape[a]
                                 for a in self.mesh.axis_names]))
            if b % n_dev:
                raise ValueError(f"batch {b} not divisible by mesh size "
                                 f"{n_dev}")
        if b not in self._sim_cache:
            self._sim_cache[b] = self._build_spiking_sim(b)
        sim = self._sim_cache[b]
        carries = [self._init_carry(i, b) for i in range(self.spec.n_layers)]

        t0 = time.time()
        counts, out_sp, hidden, e_tl, l_tl, ev_tl, flush = \
            jax.block_until_ready(sim(spike_seq, carries))
        wall = time.time() - t0
        return NetworkRun(
            backend=self.backend, mode=self.mode,
            outputs=np.asarray(counts),
            out_spikes=np.asarray(out_sp),
            layer_spikes=[np.asarray(h) for h in hidden]
            if self.record_hidden else None,
            energy=np.asarray(e_tl), latency=np.asarray(l_tl),
            events=np.asarray(ev_tl, np.int64).astype(np.float64),
            flush_energy=np.asarray(flush),
            n_circuits=np.asarray([b * l.n_out for l in self.spec.layers]),
            clock_ns=self.circ.clock_ns, wall_seconds=wall)

    # --- crossbar (combinational cascade) path --------------------------------

    def _build_crossbar_sim(self):
        spec, circ, bank = self.spec, self.circ, self.bank
        backend, mode = self.backend, self.mode
        seg_w = spec.seg_width
        gain = -circ.r_f * circ.g_unit
        levels = 2 ** spec.adc_bits - 1
        seg_params = [jnp.asarray(_row_segments(l.weight, seg_w))
                      for l in spec.layers]
        n_segs = [-(-l.weight.shape[0] // seg_w) for l in spec.layers]
        sharded = self.mesh is not None
        axes = tuple(self.mesh.axis_names) if sharded else ()

        def layer_eval(i, x):
            b, n_in = x.shape
            n_out, n_seg = spec.layers[i].n_out, n_segs[i]
            xp = jnp.pad(x, ((0, 0), (0, n_seg * seg_w - n_in)))
            xin = xp.reshape(b, n_seg, seg_w)
            xin = jnp.broadcast_to(xin[:, None], (b, n_out, n_seg, seg_w)
                                   ).reshape(-1, seg_w)
            pall = jnp.broadcast_to(seg_params[i][None],
                                    (b, *seg_params[i].shape)
                                    ).reshape(-1, seg_w + 1)
            n_rows = xin.shape[0]
            if backend == "golden":
                _, obs = circ.step(jnp.zeros((n_rows, 1)), xin, pall)
                v, e, l = obs["output"], obs["energy"], obs["latency"]
            elif backend == "behavioral":
                _, v = circ.behavioral_step(jnp.zeros((n_rows,)), xin, pall)
                e = jnp.zeros((n_rows,))
                l = jnp.zeros((n_rows,))
            else:
                st = init_state(n_rows, pall)
                # rows are combinational: evaluated fresh each layer event,
                # t == t_last + clock so no E2 catch-up fires
                known = None
                if mode == "annotation":
                    _, known = circ.behavioral_step(
                        jnp.zeros((n_rows,)), xin, pall)
                _, e, l, v = lasana_step(bank, st, jnp.ones((n_rows,), bool),
                                         xin, circ.clock_ns, circ.clock_ns,
                                         known_out=known)
                if known is not None:
                    v = known
            # 8-bit ADC over [-v_sat, v_sat], then digital gain compensation
            v = (jnp.round((v + circ.v_sat) / (2 * circ.v_sat) * levels)
                 / levels * 2 * circ.v_sat - circ.v_sat)
            out = v.reshape(b, n_out, n_seg).sum(-1) / gain
            return out, jnp.sum(e), jnp.max(l), n_rows

        def sim(x):
            es, ls, evs = [], [], []
            for i in range(spec.n_layers):
                x, e, l, n_rows = layer_eval(i, x)
                es.append(e)
                ls.append(l)
                evs.append(jnp.asarray(float(n_rows)))
                if i < spec.n_layers - 1:
                    if spec.activation == "tanh":
                        x = jnp.tanh(x)
                    x = x * (-circ.input_lo)          # DAC back to volts
            e_l, l_l, ev_l = jnp.stack(es), jnp.stack(ls), jnp.stack(evs)
            if sharded:
                e_l = jax.lax.psum(e_l, axes)
                l_l = jax.lax.pmax(l_l, axes)
                ev_l = jax.lax.psum(ev_l, axes)
            return x, e_l, l_l, ev_l

        if not sharded:
            return jax.jit(sim)
        bspec = batch_spec(self.mesh, ndim=2)
        return shard_over_batch(sim, self.mesh, in_specs=(bspec,),
                                out_specs=(bspec, P_REPL, P_REPL, P_REPL))

    def _run_crossbar(self, x) -> NetworkRun:
        if "xbar" not in self._sim_cache:
            self._sim_cache["xbar"] = self._build_crossbar_sim()
        sim = self._sim_cache["xbar"]
        t0 = time.time()
        logits, e_l, l_l, ev_l = jax.block_until_ready(sim(x))
        wall = time.time() - t0
        n_layers = self.spec.n_layers
        return NetworkRun(
            backend=self.backend, mode=self.mode,
            outputs=np.asarray(logits), out_spikes=None, layer_spikes=None,
            energy=np.asarray(e_l)[None],         # (1, L): one event wave
            latency=np.asarray(l_l)[None],
            events=np.asarray(ev_l, np.float64)[None],
            flush_energy=np.zeros((n_layers,)),
            n_circuits=np.asarray(ev_l, np.int64) // max(x.shape[0], 1),
            clock_ns=self.circ.clock_ns, wall_seconds=wall)
