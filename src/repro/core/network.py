"""Heterogeneous network-level event-driven LASANA engine (paper §V-E at scale).

Composes circuit banks of *different kinds* — event-driven LIF neuron layers
and combinational PCM crossbar-row layers — into one layered dataflow graph
(the MENAGE-style mixed-signal composition: analog crossbar MACs feeding
spiking neuron banks, with optional recurrent feedback) and runs the paper's
Algorithm 1 across the whole graph:

  * per-layer ``circuit`` kinds: every :class:`LayerSpec` names the circuit
    bank it instantiates (``"lif"`` | ``"crossbar"``) plus the bank's local
    knobs (LIF bias params, crossbar segment width / ADC bits / digital
    activation);
  * typed inter-layer adapters (:func:`adapt_signal`): spike trains become
    crossbar input volts (spike -> DAC drive), crossbar ADC codes become
    rate-encoded LIF current drive, crossbar codes become the next crossbar's
    DAC volts — every (src kind, dst kind) pair has one documented signal
    conversion, so heterogeneous layers compose without per-network glue;
  * batched per-tick event queues — each tick, the signal published by layer
    i-1 is the event queue consumed by layer i; per-circuit ``changed`` masks
    mark which circuits received an input event (spike arrival through a
    nonzero weight for LIF banks, a live sample-and-hold input for crossbar
    rows), so idle circuits are skipped and later caught up with ONE merged
    E2 event (core/wrapper.py);
  * recurrent edges (:class:`EdgeSpec`): extra layer->layer connections
    (layer to an *earlier* layer or to itself) that deliver the source
    layer's previous-tick output with a one-tick delay — lateral inhibition,
    feedback loops, winner-take-all circuits;
  * one unified ``_build_sim`` for every graph and all three backends:
      golden      — sub-step ODE integration of every circuit every tick
      behavioral  — SV-RNM ideal discrete update (no energy/latency)
      lasana      — Algorithm 1 over trained :class:`Surrogate` artifacts
                    (a :class:`SurrogateLibrary` with one per circuit
                    kind), in ``standalone`` mode (surrogate predicts output
                    + state + energy/latency) or ``annotation`` mode
                    (behavioral model supplies outputs, LASANA adds
                    energy/latency). Surrogates enter the compiled program
                    as traced pytree arguments: retraining or hot-swapping
                    a surrogate never recompiles the network program;
  * ``shard_map`` batch parallelism over the device mesh via
    core/distributed.py — circuits are batch-local, so a whole network tick
    shards over the flattened mesh with only diagnostic psums;
  * a network-level report attributing per-layer energy / latency / event
    counts to each layer's circuit kind, plus an end-of-run flush that
    charges the static energy of still-idle circuits.

Public API
----------
:class:`LayerSpec` / :class:`EdgeSpec` / :class:`NetworkSpec`
    the graph description (pure data, hashable layer tuples)
:func:`lif_layer` / :func:`crossbar_layer` / :func:`recurrent_edge`
    per-layer/per-edge constructors
:func:`snn_spec` / :func:`crossbar_mlp_spec` / :func:`graph_spec`
    whole-graph constructors (homogeneous SNN, tiled crossbar MLP, arbitrary
    mixed graph)
:func:`adapt_signal` / :func:`event_threshold`
    the typed inter-layer signal adapters
:class:`NetworkEngine` / :class:`NetworkRun`
    the simulator and its run record / report
:meth:`NetworkEngine.run_stream` / :meth:`NetworkEngine.stream` /
:class:`StreamingRun`
    streaming chunked execution: donated chunk-to-chunk carries, async
    host fetch, records bit-identical to the monolithic run

Usage (the facade ``repro.lasana`` wraps this in one documented entry
point — ``lasana.train`` / ``lasana.simulate``)::

    from repro.core.network import (NetworkEngine, crossbar_layer, graph_spec,
                                    lif_layer, recurrent_edge, snn_spec)

    spec = snn_spec(weights, params_per_layer)        # homogeneous LIF net
    golden = NetworkEngine(spec, backend="golden").run(spike_seq)
    lasana = NetworkEngine(spec, backend="lasana",
                           surrogates=surrogate).run(spike_seq)
    print(lasana.report()["network"])                 # energy, events/s, ...

    mixed = graph_spec(                               # MENAGE-style graph
        [crossbar_layer(ternary_w),                   # analog MAC front-end
         lif_layer(readout_w, lif_params)],           # spiking readout
        edges=[recurrent_edge(1, 1, inhibit_w)])      # lateral inhibition
    run = NetworkEngine(mixed, backend="lasana",
                        surrogates={"crossbar": xsur, "lif": lsur}).run(x_seq)

Spiking inputs are (T, B, n_in) spike amplitudes; a 2-D (B, n_in) input is
promoted to one combinational wave (T=1, the pure-crossbar MLP case).
Pass ``mesh=Mesh(...)`` to shard the batch axis.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.circuits import CrossbarRow, LIFNeuron, get_circuit
from repro.core.distributed import batch_spec, shard_over_batch
from repro.core.surrogate import Surrogate, SurrogateLibrary, as_surrogate
from repro.core.wrapper import LasanaState, init_state, lasana_step

P_REPL = P()                     # replicated diagnostics spec
BACKENDS = ("golden", "behavioral", "lasana")
MODES = ("standalone", "annotation")
CIRCUIT_KINDS = ("lif", "crossbar")

# a crossbar row-segment has an input event iff any of its sample-and-hold
# input lines carries a live (nonzero) voltage this tick
_XBAR_EVENT_EPS = 1e-6


# --- network specification ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One bank of circuits of a single ``circuit`` kind.

    weight      (fan_in, n_out) — synaptic matrix (lif) or the ternary
                matrix tiled onto ``seg_width``-input crossbar rows
    params      lif: (n_p,) broadcast knobs or (n_out, n_p); crossbar: None
    circuit     "lif" | "crossbar"
    seg_width   crossbar: row segment width (must equal the circuit's
                ``n_inputs``)
    adc_bits    crossbar: ADC resolution applied to each row output
    activation  crossbar: digital activation applied to this layer's ADC
                codes before they drive any downstream layer ("tanh"|"none")
    """

    weight: Any
    params: Any = None
    circuit: str = "lif"
    seg_width: int = 32
    adc_bits: int = 8
    activation: str = "tanh"

    @property
    def fan_in(self) -> int:
        return self.weight.shape[0]

    @property
    def n_out(self) -> int:
        return self.weight.shape[1]

    @property
    def n_seg(self) -> int:
        return -(-self.fan_in // self.seg_width)

    def n_circuits(self, batch: int) -> int:
        """Circuit instances this layer simulates for one batch."""
        if self.circuit == "crossbar":
            return batch * self.n_out * self.n_seg
        return batch * self.n_out


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """An extra (typically recurrent) connection between two layers.

    Every edge is delivered with a ONE-TICK DELAY: at tick t the destination
    layer receives the source layer's output published at tick t-1 (zeros at
    t=0).  This makes self-loops and layer->earlier-layer feedback
    well-defined inside the single-tick feed-forward cascade.

    weight   (n_out[src], n_out[dst]) for a lif destination (maps straight
             into the destination's synaptic drive) or
             (n_out[src], fan_in[dst]) for a crossbar destination (maps into
             the destination's DAC input volts).
    """

    src: int
    dst: int
    weight: Any


def recurrent_edge(src: int, dst: int, weight) -> EdgeSpec:
    """One-tick-delayed edge from layer ``src``'s output to layer ``dst``."""
    return EdgeSpec(src=src, dst=dst,
                    weight=jnp.asarray(weight, jnp.float32))


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """A layered circuit graph: a feed-forward chain + optional extra edges.

    The chain network-input -> layers[0] -> layers[1] -> ... is evaluated
    within one tick (a combinational cascade); every :class:`EdgeSpec` in
    ``edges`` adds a one-tick-delayed connection on top.
    """

    layers: tuple
    edges: tuple = ()
    spike_amp: float = 1.5      # V_dd spike amplitude on the event queues

    # repro.lasana attaches its compiled-engine cache to the spec (so the
    # executables die with it); that runtime state — holding unpicklable
    # XLA executables — is not spec data and must not serialize
    def __getstate__(self):
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_lasana")}

    def __setstate__(self, state):
        self.__dict__.update(state)

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def circuits(self) -> tuple:
        return tuple(l.circuit for l in self.layers)

    def edges_into(self, i: int) -> tuple:
        return tuple(e for e in self.edges if e.dst == i)


def lif_layer(weight, params, **kw) -> LayerSpec:
    """LIF neuron bank: weight (fan_in, n_out), params (n_p,) | (n_out, n_p)."""
    return LayerSpec(weight=jnp.asarray(weight, jnp.float32),
                     params=jnp.asarray(params, jnp.float32),
                     circuit="lif", **kw)


def crossbar_layer(weight, *, seg_width: int = 32, adc_bits: int = 8,
                   activation: str = "tanh") -> LayerSpec:
    """Ternary matrix (fan_in, n_out) tiled onto seg_width-input rows."""
    return LayerSpec(weight=jnp.asarray(weight, jnp.float32), params=None,
                     circuit="crossbar", seg_width=seg_width,
                     adc_bits=adc_bits, activation=activation)


def snn_spec(weights, params_per_layer, *, spike_amp: float = 1.5,
             edges=()) -> NetworkSpec:
    """Feed-forward SNN of LIF banks: weights[i] (fan_in_i, n_out_i)."""
    layers = tuple(lif_layer(w, p)
                   for w, p in zip(weights, params_per_layer))
    return NetworkSpec(layers=layers, edges=tuple(edges),
                       spike_amp=spike_amp)


def crossbar_mlp_spec(weights, *, seg_width: int = 32, adc_bits: int = 8,
                      activation: str = "tanh") -> NetworkSpec:
    """Ternary-weight MLP tiled onto ``seg_width``-input crossbar rows."""
    layers = tuple(crossbar_layer(w, seg_width=seg_width, adc_bits=adc_bits,
                                  activation=activation) for w in weights)
    return NetworkSpec(layers=layers)


def graph_spec(layers, *, edges=(), spike_amp: float = 1.5) -> NetworkSpec:
    """Arbitrary mixed-circuit graph from LayerSpecs + EdgeSpecs."""
    return NetworkSpec(layers=tuple(layers), edges=tuple(edges),
                       spike_amp=spike_amp)


# --- typed inter-layer adapters -----------------------------------------------

def _digital_activation(y, activation: str):
    if activation == "tanh":
        return jnp.tanh(y)
    return y


def adapt_signal(src_kind: str, dst_kind: str, y, *, spike_amp: float = 1.5,
                 activation: str = "tanh"):
    """Convert a source layer's published output to dst-native input units.

    Published outputs are: lif — spike amplitudes in {0, spike_amp} volts;
    crossbar — post-ADC, gain-compensated codes in weight-sum units;
    "input" — the network stimulus, already in the first layer's native
    units (spike amplitudes for a lif front layer, DAC volts for crossbar).

    Conversions (``activation`` is the SOURCE crossbar layer's digital
    activation block):

      lif      -> lif       identity (spikes are the drive currency)
      lif      -> crossbar  spike -> DAC volts: s * input_hi / spike_amp
      crossbar -> lif       ADC code -> rate-encoded drive:
                            act(y) * spike_amp  (signed; |u| <= spike_amp)
      crossbar -> crossbar  ADC code -> DAC volts: act(y) * input_hi
    """
    if src_kind == "input":
        return y
    xb = get_circuit("crossbar")
    if src_kind == "lif" and dst_kind == "lif":
        return y
    if src_kind == "lif" and dst_kind == "crossbar":
        return (y * (xb.input_hi / spike_amp)).astype(jnp.float32)
    if src_kind == "crossbar" and dst_kind == "lif":
        return (_digital_activation(y, activation)
                * spike_amp).astype(jnp.float32)
    if src_kind == "crossbar" and dst_kind == "crossbar":
        return (_digital_activation(y, activation)
                * xb.input_hi).astype(jnp.float32)
    raise ValueError(f"no adapter for {src_kind!r} -> {dst_kind!r}")


def event_threshold(src_kind: str, spike_amp: float) -> float:
    """|u| above this counts as an input event at a LIF destination.

    Spiking sources emit V_dd pulses (half-amplitude discriminator);
    analog crossbar sources count any appreciable rate-encoded drive.
    """
    if src_kind in ("input", "lif"):
        return 0.5 * spike_amp
    return 0.05 * spike_amp


def drive_to_circuit_inputs(drive, *, spike_amp: float = 1.5,
                            n_spk: float = 5.0):
    """Aggregate synaptic drive -> (w, x, n) LIF circuit inputs.

    ``spike_amp`` is the presynaptic spike amplitude (the source circuit's
    V_dd) and ``n_spk`` the spikes-per-period ceiling the LIF testbench
    trains against; both used to be hardcoded at the 1.5-V_dd defaults,
    which would silently mis-drive any future non-1.5-V_dd LIF circuit."""
    w = jnp.clip(drive, -1.0, 1.0)
    x = jnp.full_like(drive, spike_amp)
    n = jnp.full_like(drive, n_spk)
    return jnp.stack([w, x, n], axis=-1)


def _count_events(changed) -> jax.Array:
    """Exact integer count of a ``changed`` mask.

    Event counts used to accumulate as fp32, which silently drops whole
    events once a tick/layer exceeds 2^24 of them (dry-run scales reach
    2^27 circuits); int32 keeps every count exact to 2^31."""
    return jnp.sum(changed, dtype=jnp.int32)


def _tile_params(p, b: int, n_out: int):
    p = jnp.asarray(p, jnp.float32)
    if p.ndim == 1:                       # one knob set for the whole layer
        return jnp.broadcast_to(p[None], (b * n_out, p.shape[0]))
    return jnp.tile(p, (b, 1))            # per-neuron knobs, batch-tiled

def _row_segments(w, seg_width: int):
    """(n_in, n_out) ternary matrix -> (n_out * n_seg, seg_width + 1)
    crossbar row params (last column is the bias row, unused here)."""
    w = np.asarray(w)
    n_in, n_out = w.shape
    n_seg = -(-n_in // seg_width)
    pad = n_seg * seg_width - n_in
    wp = np.pad(w, ((0, pad), (0, 0)))
    segs = (wp.reshape(n_seg, seg_width, n_out)
            .transpose(2, 0, 1).reshape(-1, seg_width))
    return np.concatenate([segs, np.zeros((len(segs), 1))],
                          axis=1).astype(np.float32)


def _iter_chunks(stimulus, chunk_ticks, fan_in: int, skip_ticks: int = 0):
    """Yield (t_i, B, fan_in) stimulus chunks for the streaming path.

    ``stimulus`` is either one (T, B, fan_in) array — sliced into
    ``chunk_ticks``-tick chunks without ever putting more than one chunk
    on device when it lives in host memory — or an iterator of
    (t_i, B, fan_in) blocks, re-buffered to ``chunk_ticks`` ticks when a
    chunk size is given (the last chunk may be short). 2-D (B, fan_in)
    blocks promote to one tick. ``skip_ticks`` drops the leading ticks
    before chunking (checkpoint resume: the caller re-supplies the FULL
    original stimulus and the consumed prefix is skipped here, so the
    tail re-chunks exactly as the uninterrupted run would have)."""
    if chunk_ticks is not None and chunk_ticks <= 0:
        raise ValueError(f"chunk_ticks must be positive: {chunk_ticks}")

    def check(blk):
        if blk.ndim == 2:
            blk = blk[None]
        if blk.ndim != 3:
            raise ValueError(f"stimulus chunks must be (T, B, n_in), got "
                             f"shape {tuple(blk.shape)}")
        if blk.shape[-1] != fan_in:
            raise ValueError(f"input width {blk.shape[-1]} != layer-0 "
                             f"fan_in {fan_in}")
        return blk

    skip = int(skip_ticks)
    if hasattr(stimulus, "ndim"):              # one whole array
        x = check(stimulus)[skip:]
        step = int(chunk_ticks) if chunk_ticks else x.shape[0]
        for a in range(0, x.shape[0], step):
            yield x[a:a + step]
        return
    parts, have = [], 0                        # iterator of blocks
    for block in stimulus:
        blk = check(np.asarray(block, np.float32))
        if skip:                               # resume: drop consumed prefix
            if blk.shape[0] <= skip:
                skip -= blk.shape[0]
                continue
            blk = blk[skip:]
            skip = 0
        if chunk_ticks is None:
            yield blk
            continue
        parts.append(blk)
        have += blk.shape[0]
        while have >= chunk_ticks:             # one concat per emitted chunk
            buf = parts[0] if len(parts) == 1 \
                else np.concatenate(parts, axis=0)
            yield buf[:chunk_ticks]
            rest = buf[chunk_ticks:]
            parts = [rest] if rest.shape[0] else []
            have = rest.shape[0]
    if have:
        yield parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)


# --- run record ---------------------------------------------------------------

@dataclasses.dataclass
class NetworkRun:
    """Record of one network simulation over T ticks (combinational: T=1)."""

    backend: str
    mode: str
    outputs: np.ndarray           # lif last layer: (B, n_cls) spike counts;
                                  # crossbar last layer: (B, n_cls) codes
    out_spikes: Optional[np.ndarray]   # lif last layer: (T, B, n_cls) amps
    layer_spikes: Optional[list]  # per layer (T, B, n_i) published outputs
    energy: np.ndarray            # (T, L) joules per tick per layer
    latency: np.ndarray           # (T, L) ns — max over the layer's circuits
    events: np.ndarray            # (T, L) input events processed
    flush_energy: np.ndarray      # (L,) end-of-run idle static energy
    n_circuits: np.ndarray        # (L,) circuits per layer (B-included)
    clock_ns: float
    wall_seconds: float           # steady-state execution only (no compile)
    circuits: tuple = ()          # (L,) per-layer circuit kind
    compile_seconds: float = 0.0  # one-time trace+compile of this program
    checkpoint: Optional[Any] = None   # StreamCheckpoint when this chunk
                                  # closed a checkpoint interval (streaming
                                  # with checkpoint_every=; see
                                  # repro.resilience.checkpoint); merge/
                                  # StreamingRun ignore it

    def report(self) -> dict:
        """Aggregate per-layer energy/latency/events + network totals.

        Each layer entry names its ``circuit`` kind and the ``backend`` that
        produced it, so mixed-graph energy breakdowns stay attributable."""
        t_steps, n_layers = self.energy.shape
        circuits = self.circuits or ("?",) * n_layers
        # ONE host transfer for every reduction below (fields may still be
        # device arrays), then vectorized per-layer aggregation — report()
        # on a fresh run must not issue 5 blocking fetches per layer
        energy, latency, events, flush_energy, n_circuits = (
            np.asarray(a) for a in jax.device_get(
                (self.energy, self.latency, self.events,
                 self.flush_energy, self.n_circuits)))
        e_layer = energy.sum(axis=0) + flush_energy             # (L,)
        ev_layer = events.sum(axis=0)                           # (L,)
        max_lat = latency.max(axis=0, initial=0.0)              # (L,)
        # a zero-tick run (T=0: e.g. a drained stream's empty tail chunk)
        # has no ticks to average over — report 0.0, not NaN + a numpy
        # RuntimeWarning from mean() on the empty slice
        mean_lat = (latency.mean(axis=0) if t_steps
                    else np.zeros(n_layers, np.float64))
        layers = []
        for i in range(n_layers):
            layers.append({
                "layer": i,
                "circuit": circuits[i],
                "backend": self.backend,
                "n_circuits": int(n_circuits[i]),
                "energy_j": float(e_layer[i]),
                "flush_energy_j": float(flush_energy[i]),
                "events": int(ev_layer[i]),
                "max_latency_ns": float(max_lat[i]),
                "mean_tick_latency_ns": float(mean_lat[i]),
            })
        total_events = int(ev_layer.sum()) if n_layers else 0
        by_kind: dict = {}
        for l in layers:
            agg = by_kind.setdefault(l["circuit"],
                                     {"energy_j": 0.0, "events": 0})
            agg["energy_j"] += l["energy_j"]
            agg["events"] += l["events"]
        return {
            "backend": self.backend,
            "mode": self.mode,
            "layers": layers,
            "by_circuit": by_kind,
            "network": {
                "ticks": t_steps,
                "sim_time_ns": t_steps * self.clock_ns,
                "energy_j": float(sum(l["energy_j"] for l in layers)),
                "events": total_events,
                "events_per_sec": total_events / max(self.wall_seconds, 1e-9),
                "wall_seconds": self.wall_seconds,
                "compile_seconds": self.compile_seconds,
            },
        }

    @classmethod
    def merge(cls, chunks) -> "NetworkRun":
        """Merge consecutive per-chunk records into one whole-run record.

        ``chunks`` is the sequence :meth:`NetworkEngine.stream` yields (in
        order). The merged record is bit-identical to the monolithic
        :meth:`NetworkEngine.run` over the concatenated stimulus: spike
        counts sum exactly (integer chunk partials), per-tick diagnostics
        concatenate, and the end-of-run flush — present only on the final
        chunk — is applied exactly once. ``wall_seconds`` /
        ``compile_seconds`` sum, which for records from one stream equals
        the end-to-end steady/compile split."""
        acc = StreamingRun()
        for c in chunks:
            acc.update(c)
        return acc.result()


class StreamingRun:
    """Incremental accumulator of per-chunk :class:`NetworkRun` records.

    The streaming counterpart of a monolithic run record:
    :meth:`NetworkEngine.run_stream` feeds it one chunk at a time and
    :meth:`result` freezes a :class:`NetworkRun` bit-identical to the
    monolithic run (see :meth:`NetworkRun.merge`). Live totals —
    :attr:`ticks`, :attr:`events`, :attr:`energy_j` — update as chunks
    arrive, so a dashboard can read progress mid-stream.
    """

    def __init__(self):
        self._first: Optional[NetworkRun] = None
        self._last: Optional[NetworkRun] = None
        self._counts = None            # lif last layer: running spike counts
        self._out_chunks: list = []
        self._hidden_chunks: list = []
        self._energy: list = []
        self._latency: list = []
        self._events: list = []
        self._flush = None
        self.ticks = 0                 # ticks accumulated so far
        self.events = 0                # input events accumulated so far
        self.energy_j = 0.0            # joules accumulated so far (no flush)
        self.wall_seconds = 0.0
        self.compile_seconds = 0.0

    def update(self, chunk: NetworkRun) -> "StreamingRun":
        """Fold the next consecutive chunk record in; returns ``self``."""
        if self._first is None:
            self._first = chunk
            self._flush = np.zeros_like(chunk.flush_energy)
        elif (chunk.backend != self._first.backend
                or chunk.mode != self._first.mode
                or chunk.circuits != self._first.circuits):
            raise ValueError("cannot merge chunks from different runs: "
                             f"{chunk.backend}/{chunk.mode} vs "
                             f"{self._first.backend}/{self._first.mode}")
        self._last = chunk
        if chunk.circuits and chunk.circuits[-1] == "lif":
            c = np.asarray(chunk.outputs, np.int64)
            self._counts = c if self._counts is None else self._counts + c
            self._out_chunks.append(chunk.out_spikes)
        if chunk.layer_spikes is not None:
            self._hidden_chunks.append(chunk.layer_spikes)
        self._energy.append(chunk.energy)
        self._latency.append(chunk.latency)
        self._events.append(chunk.events)
        self._flush = self._flush + chunk.flush_energy
        self.ticks += chunk.energy.shape[0]
        self.events += int(chunk.events.sum())
        self.energy_j += float(chunk.energy.sum())
        self.wall_seconds += chunk.wall_seconds
        self.compile_seconds += chunk.compile_seconds
        return self

    def result(self) -> NetworkRun:
        """Freeze the accumulated chunks into one :class:`NetworkRun`."""
        if self._first is None or self._last is None:
            raise ValueError("StreamingRun.result() before any update()")
        first, last = self._first, self._last
        last_lif = first.circuits and first.circuits[-1] == "lif"
        if last_lif:
            outputs = self._counts.astype(first.outputs.dtype)
            out_spikes = np.concatenate(self._out_chunks, axis=0)
        else:
            outputs = last.outputs
            out_spikes = None
        hidden = None
        if self._hidden_chunks:
            hidden = [np.concatenate([h[i] for h in self._hidden_chunks],
                                     axis=0)
                      for i in range(len(self._hidden_chunks[0]))]
        return NetworkRun(
            backend=first.backend, mode=first.mode,
            outputs=outputs, out_spikes=out_spikes, layer_spikes=hidden,
            energy=np.concatenate(self._energy, axis=0),
            latency=np.concatenate(self._latency, axis=0),
            events=np.concatenate(self._events, axis=0),
            flush_energy=self._flush,
            n_circuits=first.n_circuits, clock_ns=first.clock_ns,
            wall_seconds=self.wall_seconds, circuits=first.circuits,
            compile_seconds=self.compile_seconds)


@dataclasses.dataclass(frozen=True)
class SlotPrograms:
    """The compiled continuous-batching program family for one
    (batch width, chunk ticks, surrogate structure) bucket — what the
    serving layer's scheduler drives (see :meth:`NetworkEngine.
    slot_programs` for the calling conventions and parity contract)."""

    step: Any                      # chunk program, donated carries
    flush: Any                     # per-slot leave-time idle flush
    join: Any                      # masked slot (re)initialization
    compile_seconds: float         # 0.0 when every program was cached


# --- the engine ----------------------------------------------------------------

class NetworkEngine:
    """Heterogeneous circuit graph under one jitted event-driven scheduler.

    backend  "golden" | "behavioral" | "lasana"
    mode     lasana only: "standalone" (surrogate closes the loop) or
             "annotation" (behavioral supplies outputs/state, LASANA adds
             energy/latency)
    surrogates  backend="lasana": a trained :class:`Surrogate` (homogeneous
             graphs) or a :class:`SurrogateLibrary` / ``{circuit kind:
             Surrogate}`` mapping (mixed graphs). Surrogates enter the
             compiled network program as a *traced pytree argument*, so one
             program serves every retrained surrogate with matching
             manifest/shapes — swap at :meth:`run` time with zero
             recompiles. May be omitted here and supplied per ``run()``.
    bank     deprecated alias of ``surrogates``; legacy ``PredictorBank``
             values (single or mapping) are frozen into Surrogates.
    mesh     optional jax Mesh: shard the batch axis over every mesh axis
    record_hidden  keep per-layer output traces (tests/parity); disable for
             large sweeps to save host memory
    fused    lasana only: take the fused inference hot path
             (``Surrogate.predict_heads`` — one feature build + stacked
             same-family predictor passes per tick) in every compiled
             program: monolithic, streaming, and shard_map. Default True;
             ``fused=False`` compiles the per-``predict``-call
             formulation (the benchmark A/B baseline — results agree
             within a few ULPs, see tests/test_fused.py).
    fused_kernel  lasana only: tri-state override of the
             ``REPRO_FUSED_KERNEL`` switch (resolved through
             ``kernels.ops.fused_kernel_enabled``). ``True`` engages the
             whole-tick megakernel hot path (``kernels.tick_megakernel``:
             cross-kind head packs, one fused idle->act->transition body
             per tick, Pallas launcher per ``REPRO_TICK_PALLAS``);
             ``False`` forces the stacked-dispatch path regardless of the
             env; ``None`` (default) defers to the env var.
    """

    def __init__(self, spec: NetworkSpec, backend: str = "lasana", *,
                 surrogates=None, bank=None, mode: str = "standalone",
                 mesh=None, record_hidden: bool = True, fused: bool = True,
                 fused_kernel: bool | None = None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}: {backend}")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {mode}")
        for layer in spec.layers:
            if layer.circuit not in CIRCUIT_KINDS:
                raise ValueError(f"unknown circuit kind {layer.circuit!r}; "
                                 f"registered kinds: {CIRCUIT_KINDS}")
        self.spec = spec
        self.backend = backend
        self.mode = mode if backend == "lasana" else "standalone"
        self.mesh = mesh
        self.record_hidden = record_hidden
        self.fused = bool(fused)
        self.fused_kernel = (None if fused_kernel is None
                             else bool(fused_kernel))
        self.circs = tuple(get_circuit(l.circuit) for l in spec.layers)
        if bank is not None:
            warnings.warn(
                "NetworkEngine(bank=...) is deprecated; pass surrogates= "
                "(repro.lasana.train / Surrogate.from_bank)",
                DeprecationWarning, stacklevel=2)
            if surrogates is None:
                surrogates = bank
        if surrogates is not None and backend != "lasana":
            # same guard run() applies: never silently ignore a surrogate
            raise ValueError(
                f"backend={backend!r} does not use surrogates; pass "
                "surrogates= only with backend='lasana'")
        self.surrogates = (self._normalize_surrogates(surrogates)
                           if surrogates is not None else None)
        for i, (layer, circ) in enumerate(zip(spec.layers, self.circs)):
            if isinstance(circ, LIFNeuron) and spec.spike_amp != circ.vdd:
                # spike amplitude IS the circuit's V_dd: the wrapper's spike
                # threshold (0.5 * 1.5) and behavioral/golden outputs are all
                # V_dd-referenced, so other amplitudes would silently diverge
                # across backends
                raise ValueError(
                    f"spike_amp {spec.spike_amp} != circuit V_dd "
                    f"{circ.vdd}; the LIF event queues carry V_dd spikes")
            if isinstance(circ, CrossbarRow) \
                    and layer.seg_width != circ.n_inputs:
                raise ValueError(
                    f"layer {i}: seg_width {layer.seg_width} != crossbar "
                    f"row n_inputs {circ.n_inputs}")
        self._validate_edges()
        # the network tick is one global digital clock; per-layer event
        # features/timestamps use each circuit's native clock (see _lif_tick)
        self.clock_ns = max(c.clock_ns for c in self.circs)
        self._sim_cache: dict = {}
        # serializes first-compile of a program key so concurrent streams
        # on one engine (the serving layer, threaded clients) compile each
        # program exactly once and never race the cache dict
        self._compile_lock = threading.Lock()
        self.compile_count = 0        # distinct compiled network programs
        self._trace_count = 0         # times a sim body was (re)traced

    def _normalize_surrogates(self, src) -> SurrogateLibrary:
        """Coerce surrogates/bank input into a validated SurrogateLibrary."""
        kinds = set(self.spec.circuits)
        if isinstance(src, SurrogateLibrary):
            mapping = dict(src.items())
        elif isinstance(src, dict):
            mapping = dict(src)
        else:
            if len(kinds) > 1:
                raise ValueError(
                    "mixed-circuit graphs need a {circuit: Surrogate} "
                    "library (legacy {circuit: PredictorBank} mappings are "
                    f"converted), got a single surrogate for kinds "
                    f"{sorted(kinds)}")
            mapping = {next(iter(kinds)): src}
        missing = kinds - set(mapping)
        if missing:
            raise ValueError(
                "backend='lasana' is missing a Surrogate (or legacy "
                f"PredictorBank) for circuit kind(s) {sorted(missing)}")
        lib = {}
        for kind in sorted(kinds):
            s = as_surrogate(mapping[kind])
            if s.circuit != kind:
                raise ValueError(
                    f"surrogate trained for circuit {s.circuit!r} bound to "
                    f"layer kind {kind!r}")
            lib[kind] = s
        return SurrogateLibrary(lib)

    def _validate_edges(self):
        spec = self.spec
        n = spec.n_layers
        for e in spec.edges:
            if not (0 <= e.src < n and 0 <= e.dst < n):
                raise ValueError(f"edge {e.src}->{e.dst} out of range for "
                                 f"{n} layers")
            dst = spec.layers[e.dst]
            want = (spec.layers[e.src].n_out,
                    dst.n_out if dst.circuit == "lif" else dst.fan_in)
            got = tuple(np.shape(e.weight))
            if got != want:
                raise ValueError(
                    f"edge {e.src}->{e.dst} weight shape {got} != {want} "
                    f"(src n_out, dst {'n_out' if dst.circuit == 'lif' else 'fan_in'})")

    # --- public entry point ---------------------------------------------------

    def run(self, inputs, *, surrogates=None) -> NetworkRun:
        """inputs: (T, B, n_in) per-tick stimulus in the first layer's native
        units (spike amplitudes for lif, DAC volts for crossbar); a 2-D
        (B, n_in) input is promoted to one combinational wave (T=1).

        ``surrogates`` overrides the engine-bound library for THIS run only:
        because surrogates are traced arguments of the compiled program,
        swapping a retrained library with identical manifests/shapes reuses
        the cached executable (zero recompiles)."""
        x = jnp.asarray(inputs, jnp.float32)
        if x.ndim == 2:
            x = x[None]
        if x.shape[-1] != self.spec.layers[0].fan_in:
            raise ValueError(f"input width {x.shape[-1]} != layer-0 fan_in "
                             f"{self.spec.layers[0].fan_in}")
        return self._run(x, surrogates=surrogates)

    def run_stream(self, stimulus, *, chunk_ticks: Optional[int] = None,
                   surrogates=None) -> NetworkRun:
        """Streaming-chunked :meth:`run`: same record, bounded memory.

        The T axis is cut into ``chunk_ticks``-tick chunks; each chunk
        runs through one donated-carry compiled program (chunk-to-chunk
        state and surrogate leaves are aliased in place, never copied)
        while the PREVIOUS chunk's per-tick records stream to the host —
        device compute and host fetch double-buffer. The merged
        :class:`NetworkRun` is bit-identical to ``run()`` on the full
        stimulus: identical per-tick energy/latency/events, identical
        spike counts, and the end-of-run idle flush charged exactly once
        at the true stream end. At most two chunk programs compile (full
        chunk + remainder when ``T % chunk_ticks != 0``) regardless of
        stream length, so unbounded-T simulation runs at steady-state
        speed in bounded device memory.

        stimulus    (T, B, fan_in) array — sliced into chunks — or an
                    iterator of (t_i, B, fan_in) blocks (e.g. a host
                    generator producing stimulus on the fly); blocks are
                    re-buffered to ``chunk_ticks`` when it is given.
        chunk_ticks ticks per chunk (default: one chunk = whole stimulus).
        surrogates  as :meth:`run`; additionally an *iterator* of
                    surrogate libraries hot-swaps predictor weights per
                    chunk (``None`` entries / exhaustion hold the last) —
                    equal-structure swaps reuse the compiled programs with
                    zero recompiles.
        """
        acc = StreamingRun()
        for chunk in self.stream(stimulus, chunk_ticks=chunk_ticks,
                                 surrogates=surrogates):
            acc.update(chunk)
        return acc.result()

    def stream(self, stimulus, *, chunk_ticks: Optional[int] = None,
               surrogates=None, checkpoint_every: Optional[int] = None,
               resume_from=None):
        """Generator variant of :meth:`run_stream` for live consumers.

        Yields one :class:`NetworkRun` per chunk as its records land on
        the host (chunk ``k`` is fetched while chunk ``k+1`` computes);
        only the final chunk carries ``flush_energy``. Feed the records to
        :class:`StreamingRun` / :meth:`NetworkRun.merge` for the exact
        whole-run record, or consume them incrementally (dashboards,
        online monitors). Arguments as :meth:`run_stream`, plus:

        checkpoint_every  attach a resumable
                    :class:`~repro.resilience.checkpoint.StreamCheckpoint`
                    to every Nth chunk's record (``.checkpoint``; the
                    flush-bearing final chunk never carries one).
                    Requires ``chunk_ticks`` — checkpoints sit at chunk
                    boundaries so a resumed tail re-chunks (and reuses
                    the compiled chunk program) exactly. Taking a
                    checkpoint synchronizes on that chunk's carries (one
                    device fetch) — that is its entire cost.
        resume_from  a ``StreamCheckpoint`` (from a previous stream's
                    record): restore carries/offset and continue. The
                    caller re-supplies the FULL original stimulus — the
                    consumed prefix is skipped — and only post-resume
                    chunks are yielded; merge them onto
                    ``resume_from.acc_run`` (``lasana.resume`` does) for
                    the whole-run record, bit-identical to the
                    uninterrupted run.

        Argument errors (bad ``chunk_ticks``, array-stimulus shape
        mismatch, missing surrogates, checkpoint/engine mismatch) raise
        HERE, not at the first ``next()`` — a dropped or late-consumed
        generator must not hide them."""
        spec = self.spec
        if chunk_ticks is not None and chunk_ticks <= 0:
            raise ValueError(f"chunk_ticks must be positive: {chunk_ticks}")
        if checkpoint_every is not None:
            if checkpoint_every <= 0:
                raise ValueError("checkpoint_every must be positive: "
                                 f"{checkpoint_every}")
            if chunk_ticks is None:
                raise ValueError(
                    "checkpoint_every requires chunk_ticks: checkpoints "
                    "sit at chunk boundaries")
        if resume_from is not None:
            resume_from.verify_engine(self, spec)
            if chunk_ticks is None:
                chunk_ticks = resume_from.chunk_ticks
            elif chunk_ticks != resume_from.chunk_ticks:
                raise ValueError(
                    f"chunk_ticks {chunk_ticks} != checkpoint's "
                    f"{resume_from.chunk_ticks}: the resumed tail must "
                    "re-chunk exactly as the original stream")
        if hasattr(stimulus, "ndim"):
            if stimulus.ndim not in (2, 3):
                raise ValueError("stimulus must be (T, B, n_in) or "
                                 f"(B, n_in), got shape "
                                 f"{tuple(stimulus.shape)}")
            if stimulus.shape[-1] != spec.layers[0].fan_in:
                raise ValueError(f"input width {stimulus.shape[-1]} != "
                                 f"layer-0 fan_in "
                                 f"{spec.layers[0].fan_in}")
        sur_iter, static_banks = None, None
        if surrogates is not None and hasattr(surrogates, "__next__"):
            sur_iter = surrogates
        else:
            static_banks = self._runtime_banks(surrogates)
        return self._stream_gen(stimulus, chunk_ticks, static_banks,
                                sur_iter, checkpoint_every, resume_from)

    def _stream_gen(self, stimulus, chunk_ticks, static_banks, sur_iter,
                    checkpoint_every=None, resume_from=None):
        from repro.resilience import faults
        spec = self.spec
        chunks = _iter_chunks(stimulus, chunk_ticks,
                              spec.layers[0].fan_in,
                              skip_ticks=(resume_from.k0
                                          if resume_from is not None else 0))

        cur = next(chunks, None)
        if cur is None:
            raise ValueError("streaming run needs at least one stimulus "
                             "tick" + (" past the checkpoint offset"
                                       if resume_from is not None else ""))
        b = cur.shape[1]
        self._check_mesh_batch(b)
        n_layers = spec.n_layers
        last_lif = spec.circuits[-1] == "lif"
        carries = [self._init_carry(i, b) for i in range(n_layers)]
        prev_ys = [jnp.zeros((b, l.n_out), jnp.float32)
                   for l in spec.layers]
        k0 = 0
        if resume_from is not None:
            carries, prev_ys = self._restore_state(resume_from, carries,
                                                   prev_ys, b)
            k0 = int(resume_from.k0)
        banks_dev = None
        if sur_iter is None:
            banks_dev = self._donatable_banks(static_banks)

        # checkpoint bookkeeping: the accumulator mirrors every yielded
        # record so a checkpoint can carry the exact merged prefix; a
        # snapshot taken at dispatch time attaches to ITS chunk's record
        # when that record is finalized one iteration later
        acc = None
        if checkpoint_every is not None:
            acc = StreamingRun()
            if resume_from is not None:
                acc.update(resume_from.acc_run)
        ckpt_pending = None            # (carry snapshot, prev snapshot, k0)

        mark = time.time()             # segment boundary for wall split
        comp_seg = 0.0                 # compile seconds in current segment
        pending = None                 # prior chunk's device refs + meta

        def finalize(pend, flush, attach_ckpt=True):
            nonlocal mark, comp_seg, ckpt_pending
            primary, out_seq, hidden, e_tl, l_tl, ev_tl, comp_s = pend
            if not last_lif:
                out_seq = None       # unused (primary == last tick's codes):
                                     # skip the per-chunk D2H of the trace
            primary, out_seq, hidden, e_tl, l_tl, ev_tl = jax.device_get(
                (primary, out_seq, hidden, e_tl, l_tl, ev_tl))
            now = time.time()
            wall = max(now - mark - comp_seg, 0.0)
            mark, comp_seg = now, 0.0
            run = NetworkRun(
                backend=self.backend, mode=self.mode,
                outputs=np.asarray(primary),
                out_spikes=np.asarray(out_seq) if last_lif else None,
                layer_spikes=[np.asarray(h) for h in hidden]
                if self.record_hidden else None,
                energy=np.asarray(e_tl), latency=np.asarray(l_tl),
                events=np.asarray(ev_tl, np.int64),
                flush_energy=flush,
                n_circuits=np.asarray([l.n_circuits(b)
                                       for l in spec.layers]),
                clock_ns=self.clock_ns, wall_seconds=wall,
                circuits=spec.circuits, compile_seconds=comp_s)
            if acc is not None:
                acc.update(run)
                if ckpt_pending is not None and attach_ckpt:
                    snap_c, snap_p, snap_k = ckpt_pending
                    ckpt_pending = None
                    run.checkpoint = self._make_checkpoint(
                        snap_c, snap_p, snap_k, int(chunk_ticks), b, acc)
            return run

        inflight = None               # latest dispatched chunk's device refs
        try:
            while cur is not None:
                faults.stall("chunk.stall")
                x_chunk = jnp.asarray(cur, jnp.float32)
                if x_chunk.shape[1] != b:
                    raise ValueError(
                        f"stimulus chunk batch {x_chunk.shape[1]} "
                        f"!= first chunk batch {b}")
                if sur_iter is not None:
                    swap = next(sur_iter, None)
                    if swap is not None:
                        banks_dev = self._donatable_banks(
                            self._runtime_banks(swap))
                    elif banks_dev is None:
                        raise ValueError("surrogate iterator must yield a "
                                         "library for the first chunk")
                tc = x_chunk.shape[0]
                k0_arr = jnp.asarray(k0, jnp.float32)
                key = self._program_key("stream", b, tc, banks_dev)
                compiled, comp_s = self._compiled(
                    key, lambda: self._build_stream_step(b, banks_dev),
                    (x_chunk, k0_arr, carries, prev_ys, banks_dev))
                comp_seg += comp_s
                # dispatch chunk k (async), then fetch chunk k-1's records —
                # device compute and host transfer overlap (double buffering)
                outs = compiled(x_chunk, k0_arr, carries, prev_ys, banks_dev)
                inflight = outs
                carries, prev_ys, banks_dev = outs[6], outs[7], outs[8]
                if pending is not None:
                    yield finalize(pending,
                                   np.zeros((n_layers,), np.float32))
                pending = (*outs[:6], comp_s)
                k0 += tc
                if acc is not None:
                    n_chunk = k0 // int(chunk_ticks) \
                        + bool(k0 % int(chunk_ticks))
                    if n_chunk % checkpoint_every == 0:
                        # synchronizing on this chunk's carries is the
                        # checkpoint's whole cost; the snapshot attaches
                        # to this chunk's record at its finalize
                        ckpt_pending = (*jax.device_get((carries,
                                                         prev_ys)), k0)
                if k0 > 2 ** 24 and k0 - tc <= 2 ** 24:
                    # the simulator's time axis (tick index,
                    # LasanaState.t_last) is f32: past 2^24 ticks consecutive
                    # tick times collide, so tau-dependent records (merged-E2
                    # idle energy, flush) lose precision — the stream keeps
                    # running, but say so once
                    warnings.warn(
                        f"stream passed tick 2^24 ({k0} ticks): f32 tick "
                        "times can no longer distinguish consecutive ticks; "
                        "tau-dependent energy records degrade beyond here",
                        RuntimeWarning, stacklevel=2)
                cur = next(chunks, None)

            if self.backend == "lasana":
                t_ends = jnp.asarray([np.float32(k0 * c.clock_ns)
                                      for c in self.circs])
                fkey = self._program_key("flush", b, None, banks_dev)
                flush_fn, comp_s = self._compiled(
                    fkey, lambda: self._build_flush(b, banks_dev),
                    (carries, t_ends, banks_dev))
                comp_seg += comp_s
                flush = np.asarray(jax.device_get(
                    flush_fn(carries, t_ends, banks_dev)))
            else:
                flush = np.zeros((n_layers,), np.float32)
            # the final chunk never carries a checkpoint: its record holds
            # the end-of-run flush, which a resumed tail would re-charge
            yield finalize(pending, flush, attach_ckpt=False)
        finally:
            # a consumer that breaks / cancels mid-stream closes this
            # generator at a yield with one chunk still in flight on
            # device; drain it before dropping the refs so the donated
            # carries settle and the engine is immediately reusable
            if inflight is not None:
                jax.block_until_ready(inflight)

    def _restore_state(self, ckpt, init_carries, init_prev, b: int):
        """Rebuild device carries/prev_ys from a checkpoint's host leaves.

        ``init_carries``/``init_prev`` are fresh tick-0 structures for
        batch ``b`` — they supply the pytree treedefs (and the shape
        oracle) that the flat npz leaves are poured back into. Shape
        mismatches fail loudly here, at resume, not as silent divergence
        mid-stream."""
        if ckpt.batch != b:
            raise ValueError(f"checkpoint batch {ckpt.batch} != stimulus "
                             f"batch {b}")
        flat, treedef = jax.tree_util.tree_flatten(init_carries)
        if len(ckpt.carry_leaves) != len(flat):
            raise ValueError(
                f"checkpoint has {len(ckpt.carry_leaves)} carry leaves, "
                f"engine expects {len(flat)} — different network or "
                "backend")
        leaves = []
        for ref, leaf in zip(flat, ckpt.carry_leaves):
            if tuple(ref.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"checkpoint carry leaf shape {tuple(np.shape(leaf))} "
                    f"!= engine's {tuple(ref.shape)}")
            leaves.append(jnp.asarray(leaf, ref.dtype))
        carries = jax.tree_util.tree_unflatten(treedef, leaves)
        if len(ckpt.prev_ys) != len(init_prev):
            raise ValueError(
                f"checkpoint has {len(ckpt.prev_ys)} prev_ys entries, "
                f"engine expects {len(init_prev)}")
        prev_ys = []
        for ref, p in zip(init_prev, ckpt.prev_ys):
            if tuple(ref.shape) != tuple(np.shape(p)):
                raise ValueError(
                    f"checkpoint prev_ys shape {tuple(np.shape(p))} != "
                    f"engine's {tuple(ref.shape)}")
            prev_ys.append(jnp.asarray(p, jnp.float32))
        return carries, prev_ys

    def _make_checkpoint(self, snap_carries, snap_prev, k0: int,
                         chunk_ticks: int, b: int, acc):
        """Freeze one dispatch-time snapshot into a StreamCheckpoint."""
        from repro.resilience.checkpoint import StreamCheckpoint, spec_key_of
        leaves = [np.asarray(l)
                  for l in jax.tree_util.tree_flatten(snap_carries)[0]]
        return StreamCheckpoint(
            k0=int(k0), chunk_ticks=int(chunk_ticks), batch=int(b),
            spec_key=spec_key_of(self.spec), backend=self.backend,
            mode=self.mode, record_hidden=self.record_hidden,
            carry_leaves=leaves,
            prev_ys=[np.asarray(p) for p in snap_prev],
            acc_run=acc.result())

    @staticmethod
    def _donatable_banks(banks):
        """Private on-device copy of a surrogate library.

        The streaming chunk program DONATES its surrogate leaves (they
        alias straight through to the next chunk), and donation
        invalidates the caller's buffers — so the stream works on its own
        copy and the user's surrogate stays usable."""
        return jax.tree.map(lambda a: jnp.array(a, copy=True), banks)

    # --- per-layer state ------------------------------------------------------

    def _xbar_row_params(self, i: int, b: int):
        layer = self.spec.layers[i]
        segs = jnp.asarray(_row_segments(layer.weight, layer.seg_width))
        return jnp.broadcast_to(segs[None], (b, *segs.shape)
                                ).reshape(-1, layer.seg_width + 1)

    def _init_carry(self, i: int, b: int):
        layer = self.spec.layers[i]
        circ = self.circs[i]
        if layer.circuit == "crossbar":
            n_rows = layer.n_circuits(b)
            pall = self._xbar_row_params(i, b)
            if self.backend == "golden":
                return circ.init_state(n_rows), pall    # ((n_rows, 1), ...)
            if self.backend == "behavioral":
                return jnp.zeros((n_rows,), jnp.float32), pall
            return init_state(n_rows, pall)
        n = layer.n_circuits(b)
        params = _tile_params(layer.params, b, layer.n_out)
        if self.backend == "golden":
            return circ.init_state(n), params
        if self.backend == "behavioral":
            return jnp.zeros((n,), jnp.float32), params
        # lasana: annotation mode keeps the behavioral voltage in .v
        return init_state(n, params)

    # --- per-layer tick functions ---------------------------------------------

    def _lif_tick(self, i: int, slot_records: bool = False):
        """Returns tick(carry, drive, changed, k, bank, pack, layout) ->
        (carry', spikes (B, n), e, l, events); ``drive`` is the
        pre-combined synaptic drive and ``bank`` the layer kind's (traced)
        Surrogate, None outside the lasana backend. ``pack``/``layout``
        are the kind's megakernel head pack (built once per program call
        by :meth:`_mk_pack`) or None for the stacked-dispatch path.
        ``slot_records`` switches the event count from one scalar to a
        per-batch-slot (B,) int32 vector (the continuous-batching server
        attributes records per tenant; layouts are batch-major)."""
        layer = self.spec.layers[i]
        amp = self.spec.spike_amp
        circ = self.circs[i]
        clock = circ.clock_ns
        n_out = layer.n_out
        backend, mode = self.backend, self.mode
        fused = self.fused
        fused_kernel = self.fused_kernel

        def tick(carry, drive, changed, k, bank, pack=None, layout=None):
            # drive is (B_local, n_out): under shard_map the batch dim is
            # shard-local, so every shape below derives from the input
            t = (k + 1.0) * clock
            xin = drive_to_circuit_inputs(drive, spike_amp=amp
                                          ).reshape(-1, 3)

            if backend == "golden":
                state, params = carry
                new_state, obs = circ.step(state, xin, params)
                spikes = jnp.where(obs["spiked"], amp, 0.0)
                e, l = obs["energy"], jnp.where(obs["spiked"],
                                                obs["latency"], 0.0)
                carry = (new_state, params)
            elif backend == "behavioral":
                v, params = carry
                xin_m = jnp.where(changed[:, None], xin, 0.0)
                v_new, out = circ.behavioral_step(v, xin_m, params)
                spikes = out
                e = jnp.zeros_like(v)
                l = jnp.zeros_like(v)
                carry = (v_new, params)
            elif mode == "annotation":
                xin_m = jnp.where(changed[:, None], xin, 0.0)
                v_new, out = circ.behavioral_step(carry.v, xin_m,
                                                  carry.params)
                ns, e, l, _ = lasana_step(bank, carry, changed, xin, t,
                                          clock, spiking=True, vdd=amp,
                                          known_out=out, fused=fused,
                                          fused_kernel=fused_kernel,
                                          megakernel_pack=pack,
                                          megakernel_layout=layout)
                spikes = out
                carry = ns._replace(v=v_new, o=out)
            else:                                           # standalone
                ns, e, l, o = lasana_step(bank, carry, changed, xin, t,
                                          clock, spiking=True, vdd=amp,
                                          fused=fused,
                                          fused_kernel=fused_kernel,
                                          megakernel_pack=pack,
                                          megakernel_layout=layout)
                spikes = jnp.where(changed, o, 0.0)
                carry = ns

            spikes = spikes.reshape(-1, n_out)
            if slot_records:
                ev = jnp.sum(changed.reshape(spikes.shape[0], -1),
                             axis=1, dtype=jnp.int32)
            else:
                ev = _count_events(changed)
            return carry, spikes, e, l, ev

        return tick

    def _xbar_tick(self, i: int, slot_records: bool = False):
        """Returns tick(carry, x_volts (B, fan_in), k, bank, pack, layout)
        -> (carry', codes (B, n_out), e, l, events); ``bank``/``pack``/
        ``layout``/``slot_records`` as in :meth:`_lif_tick`.

        Rows are combinational with sample-and-hold inputs: a row-segment
        fires an input event iff any of its input lines is live (|x| > eps)
        this tick; event-less rows hold their previous settled output."""
        layer = self.spec.layers[i]
        circ = self.circs[i]
        seg_w, n_seg, n_out = layer.seg_width, layer.n_seg, layer.n_out
        fan_in = layer.fan_in
        clock = circ.clock_ns
        gain = -circ.r_f * circ.g_unit
        levels = 2 ** layer.adc_bits - 1
        backend, mode = self.backend, self.mode
        fused = self.fused
        fused_kernel = self.fused_kernel

        def tick(carry, x, k, bank, pack=None, layout=None):
            # x is (B_local, fan_in) volts: under shard_map the batch dim is
            # shard-local, so every shape below derives from the input; row
            # params ride in the carry so they shard with the rows
            b_l = x.shape[0]
            t = (k + 1.0) * clock
            xp = jnp.pad(x, ((0, 0), (0, n_seg * seg_w - fan_in)))
            xin = xp.reshape(b_l, n_seg, seg_w)
            xin = jnp.broadcast_to(xin[:, None], (b_l, n_out, n_seg, seg_w)
                                   ).reshape(-1, seg_w)
            changed = jnp.any(jnp.abs(xin) > _XBAR_EVENT_EPS, axis=-1)

            if backend == "golden":
                state, pall = carry
                v_prev = state[:, 0]
                _, obs = circ.step(state, xin, pall)
                v = jnp.where(changed, obs["output"], v_prev)
                e = jnp.where(changed, obs["energy"], 0.0)
                l = jnp.where(changed, obs["latency"], 0.0)
                carry = (v[:, None], pall)
            elif backend == "behavioral":
                held, pall = carry
                _, settled = circ.behavioral_step(held, xin, pall)
                v = jnp.where(changed, settled, held)
                e = jnp.zeros_like(v)
                l = jnp.zeros_like(v)
                carry = (v, pall)
            else:
                known = None
                if mode == "annotation":
                    _, known = circ.behavioral_step(carry.v, xin,
                                                    carry.params)
                ns, e, l, _ = lasana_step(bank, carry, changed, xin, t,
                                          clock, known_out=known,
                                          fused=fused,
                                          fused_kernel=fused_kernel,
                                          megakernel_pack=pack,
                                          megakernel_layout=layout)
                if known is not None:
                    # behavioral value is both published output and state
                    ns = ns._replace(v=ns.o)
                carry = ns
                v = ns.o

            # adc_bits ADC over [-v_sat, v_sat], then digital gain comp
            v_adc = (jnp.round((v + circ.v_sat) / (2 * circ.v_sat) * levels)
                     / levels * 2 * circ.v_sat - circ.v_sat)
            y = v_adc.reshape(-1, n_out, n_seg).sum(-1) / gain
            if slot_records:
                ev = jnp.sum(changed.reshape(b_l, -1),
                             axis=1, dtype=jnp.int32)
            else:
                ev = _count_events(changed)
            return carry, y, e, l, ev

        return tick

    def _flush(self, carry, i: int, t_end_ns, bank):
        """Charge trailing-idle static energy (merged E2 to the run end).

        ``t_end_ns`` is the run-end time in the layer's native clock units
        — a Python float in the monolithic program (baked constant), a
        traced f32 scalar in the streaming flush program (one program
        serves every total-T, so chunk-count changes never recompile).

        Only stateful event-driven kinds (lif) are flushed: combinational
        sample-and-hold crossbar rows charge nothing in the golden
        reference while their inputs are dead, so predicting M_ES static
        energy for their idle tail would break golden comparability."""
        if self.backend != "lasana":
            return jnp.zeros(())
        if self.spec.layers[i].circuit == "crossbar":
            return jnp.zeros(())
        circ = self.circs[i]
        lst = carry
        tau = t_end_ns - lst.t_last
        n_in = circ.n_inputs
        feats = jnp.concatenate(
            [jnp.zeros((lst.v.shape[0], n_in), jnp.float32),
             lst.v[:, None], tau[:, None], lst.params], axis=1)
        e = bank.predict("M_ES", feats)
        return jnp.sum(jnp.where(tau > 0, e, 0.0))

    # --- the unified graph builder --------------------------------------------

    def _make_cascade(self, slot_records: bool = False):
        """Build the one-network-tick cascade shared by every program.

        Returns ``cascade(banks, carries, prev_ys, u_in, k) ->
        (new_carries, new_ys, e (L,), l (L,), events (L,) int32)`` — the
        exact per-tick dataflow (adapters, event detection, bank steps).
        The monolithic program and the streaming chunk program both scan
        THIS closure, which is what makes chunked runs bit-identical to
        monolithic ones.

        ``slot_records=True`` is the continuous-batching variant (the
        slot-masked programs behind :meth:`slot_programs`): energy /
        latency / event reductions stay per batch slot — ``(L, B)``
        instead of ``(L,)`` — and the cascade accepts an extra
        ``live (B,)`` bool mask. Non-live slots are frozen: their LIF
        event detection is forced off and their crossbar input volts are
        zeroed (below the sample-and-hold event epsilon), so a dead or
        empty slot processes no events, charges no energy, and holds its
        carry — which is exactly what keeps each multiplexed request
        bit-identical to running alone."""
        spec = self.spec
        n_layers = spec.n_layers
        kinds = spec.circuits
        amp = spec.spike_amp
        ticks = [self._lif_tick(i, slot_records) if kinds[i] == "lif"
                 else self._xbar_tick(i, slot_records)
                 for i in range(n_layers)]

        # pre-resolved connection tables (weights, connectivity masks,
        # adapter arguments) — one entry per incoming connection per layer
        ff_conn = []                   # lif layers: (|w| > 0) masks
        rec = [[] for _ in range(n_layers)]
        for i in range(n_layers):
            w = spec.layers[i].weight
            ff_conn.append((jnp.abs(w) > 0).astype(jnp.float32)
                           if kinds[i] == "lif" else None)
            for e in spec.edges_into(i):
                we = jnp.asarray(e.weight, jnp.float32)
                # connectivity mask feeds lif event detection only; crossbar
                # destinations detect events from live input lines instead
                conn = ((jnp.abs(we) > 0).astype(jnp.float32)
                        if kinds[i] == "lif" else None)
                rec[i].append((e.src, we, conn))

        def src_activation(src_idx: Optional[int]) -> str:
            if src_idx is None:
                return "tanh"
            return spec.layers[src_idx].activation

        def cascade(banks, carries, prev_ys, u_in, k, packs=None,
                    live=None):
            packs = packs or {}
            bsz = u_in.shape[0]
            cur, src_kind, src_idx = u_in, "input", None
            new_carries, new_ys = [], []
            es, ls, evs = [], [], []
            for i in range(n_layers):
                layer = spec.layers[i]
                pk, ly = packs.get(kinds[i], (None, None))
                if kinds[i] == "lif":
                    # combine feed-forward + delayed-edge synaptic drive
                    u = adapt_signal(src_kind, "lif", cur, spike_amp=amp,
                                     activation=src_activation(src_idx))
                    drive = (u @ layer.weight) / amp
                    pre = (jnp.abs(u) > event_threshold(src_kind, amp)
                           ).astype(jnp.float32)
                    incoming = (pre @ ff_conn[i]) > 0.5
                    for src, we, conn in rec[i]:
                        ur = adapt_signal(
                            kinds[src], "lif", prev_ys[src],
                            spike_amp=amp,
                            activation=src_activation(src))
                        drive = drive + (ur @ we) / amp
                        pr = (jnp.abs(ur)
                              > event_threshold(kinds[src], amp)
                              ).astype(jnp.float32)
                        incoming = incoming | ((pr @ conn) > 0.5)
                    if live is not None:
                        incoming = incoming & live[:, None]
                    changed = incoming.reshape(-1)
                    carry, y, e, l, ev = ticks[i](carries[i], drive,
                                                  changed, k,
                                                  banks.get(kinds[i]),
                                                  pk, ly)
                else:
                    circ = self.circs[i]
                    xv = adapt_signal(src_kind, "crossbar", cur,
                                      spike_amp=amp,
                                      activation=src_activation(src_idx))
                    for src, we, _ in rec[i]:
                        xv = xv + adapt_signal(
                            kinds[src], "crossbar", prev_ys[src],
                            spike_amp=amp,
                            activation=src_activation(src)) @ we
                    xv = jnp.clip(xv, circ.input_lo, circ.input_hi)
                    if live is not None:
                        xv = jnp.where(live[:, None], xv, 0.0)
                    carry, y, e, l, ev = ticks[i](carries[i], xv, k,
                                                  banks.get(kinds[i]),
                                                  pk, ly)
                new_carries.append(carry)
                new_ys.append(y)
                if slot_records:   # per-tenant attribution: reduce per slot
                    es.append(jnp.sum(e.reshape(bsz, -1), axis=1))
                    ls.append(jnp.max(l.reshape(bsz, -1), axis=1))
                else:
                    es.append(jnp.sum(e))
                    ls.append(jnp.max(l))
                evs.append(ev)
                cur, src_kind, src_idx = y, kinds[i], i
            return (new_carries, new_ys, jnp.stack(es), jnp.stack(ls),
                    jnp.stack(evs))

        return cascade

    def _mk_pack(self, banks):
        """``{kind: (pack, PackLayout)}`` for the megakernel hot path.

        Empty unless the engine runs the lasana fused path AND the
        fused-kernel switch resolves on (``fused_kernel=`` override, else
        ``REPRO_FUSED_KERNEL``). Prefers ONE cross-kind
        ``pack_library`` pack (every kind shares a resident weight block,
        addressed by static offsets); if any kind is ineligible, packable
        kinds still get their own single-kind packs and the rest fall back
        to stacked dispatch inside ``lasana_step``."""
        if self.backend != "lasana" or not self.fused:
            return {}
        from repro.kernels import ops
        if not ops.fused_kernel_enabled(self.fused_kernel):
            return {}
        from repro.kernels import tick_megakernel as mk
        pack, layouts = mk.pack_library(banks)
        if pack is not None:
            return {kind: (pack, lo) for kind, lo in layouts.items()}
        packs = {}
        for kind in banks.kinds():
            p, lo = mk.pack_heads(banks.get(kind))
            if p is not None:
                packs[kind] = (p, lo)
        return packs

    def _chunk_eligible(self) -> bool:
        """Whether :meth:`_chunk_fast_path` can replace the generic scan:
        a single-LIF-layer standalone lasana graph with no delayed edges
        (the cascade then has no cross-layer or cross-tick dataflow beyond
        the LIF carry itself, which the time-looped kernel owns)."""
        spec = self.spec
        return (self.backend == "lasana" and self.mode == "standalone"
                and self.fused and spec.n_layers == 1
                and spec.circuits == ("lif",) and not spec.edges)

    def _chunk_fast_path(self, pack_layout, carries, input_seq, ks):
        """The whole chunk as ONE time-looped megakernel.

        Event detection and synaptic drive vectorize over the chunk up
        front (they have no tick-to-tick dependence); the LIF carry — the
        only sequential dataflow — then advances inside
        ``megakernel_chunk``, whose jnp body is a ``lax.scan`` of the
        exact per-tick step (bit-identical to the generic scan) and whose
        Pallas body keeps v/o/t_last VMEM-resident across the chunk.
        Returns the same ``((carries, prev_ys), outs)`` as the scan."""
        from repro.kernels.tick_megakernel import megakernel_chunk
        spec = self.spec
        layer = spec.layers[0]
        amp = spec.spike_amp
        clock = self.circs[0].clock_ns
        pack, layout = pack_layout
        t_steps, b = input_seq.shape[0], input_seq.shape[1]

        u = input_seq                       # "input" -> lif is the identity
        drive = (u @ layer.weight) / amp
        conn = (jnp.abs(layer.weight) > 0).astype(jnp.float32)
        pre = (jnp.abs(u) > event_threshold("input", amp)
               ).astype(jnp.float32)
        changed_seq = ((pre @ conn) > 0.5).reshape(t_steps, -1)
        xin_seq = drive_to_circuit_inputs(drive, spike_amp=amp
                                          ).reshape(t_steps, -1, 3)
        t_seq = (ks + 1.0) * clock
        new_state, o_seq, e_seq, l_seq = megakernel_chunk(
            pack, layer.circuit, carries[0], changed_seq, xin_seq, t_seq,
            clock, spiking=True, vdd=amp, layout=layout)
        spikes = jnp.where(changed_seq, o_seq, 0.0
                           ).reshape(t_steps, b, layer.n_out)
        es = jnp.sum(e_seq, axis=1)[:, None]
        ls = jnp.max(l_seq, axis=1)[:, None]
        evs = jnp.sum(changed_seq, axis=1, dtype=jnp.int32)[:, None]
        out = (spikes, (spikes,) if self.record_hidden else (), es, ls, evs)
        return ([new_state], [spikes[-1]]), out

    def _scan_chunk(self, cascade, banks, carries, prev_ys, input_seq, ks):
        """lax.scan the cascade over one contiguous block of ticks.

        Megakernel head packs are built HERE, once per program call and
        OUTSIDE the scan, from the traced surrogate leaves — so the pack
        rides the hot-swap contract (retrained weights reuse the program)
        without rebuilding per tick. Eligible single-layer graphs skip the
        scan entirely for the time-looped :meth:`_chunk_fast_path`."""
        record_hidden = self.record_hidden
        packs = self._mk_pack(banks)
        if "lif" in packs and self._chunk_eligible():
            return self._chunk_fast_path(packs["lif"], carries,
                                         input_seq, ks)

        def tick(state, xs):
            carries, prev_ys = state
            u_in, k = xs
            new_carries, new_ys, es, ls, evs = cascade(
                banks, carries, prev_ys, u_in, k, packs)
            out = (new_ys[-1],
                   tuple(new_ys) if record_hidden else (),
                   es, ls, evs)
            return (new_carries, new_ys), out

        return jax.lax.scan(tick, (list(carries), list(prev_ys)),
                            (input_seq, ks))

    def _shard_specs(self, b: int, banks):
        """(carry, prev, seq, hidden, bank) PartitionSpecs for shard_map."""
        mesh = self.mesh
        cspec = batch_spec(mesh)                     # flattened (B*n,) arrays
        carry_specs = [jax.tree.map(lambda _: cspec, self._init_carry(i, b))
                       for i in range(self.spec.n_layers)]
        bspec2 = batch_spec(mesh, ndim=2)
        prev_specs = [bspec2 for _ in range(self.spec.n_layers)]
        seq_spec = batch_spec(mesh, ndim=3, axis=1)
        hidden_spec = tuple(seq_spec for _ in range(self.spec.n_layers)) \
            if self.record_hidden else ()
        # predictor weights replicate across the mesh (batch is the only
        # sharded axis); they still enter as traced arguments
        bank_specs = jax.tree.map(lambda _: P_REPL, banks)
        return carry_specs, prev_specs, bspec2, seq_spec, hidden_spec, \
            bank_specs

    def _build_sim(self, b: int, banks: SurrogateLibrary):
        """Build the jitted monolithic network program for batch ``b``.

        ``banks`` is used only for its pytree *structure* (shard specs);
        the returned program takes the library as a traced argument."""
        spec = self.spec
        n_layers = spec.n_layers
        kinds = spec.circuits
        amp = spec.spike_amp
        cascade = self._make_cascade()
        last_lif = kinds[-1] == "lif"
        sharded = self.mesh is not None
        axes = tuple(self.mesh.axis_names) if sharded else ()

        def sim(input_seq, carries, prev0, banks):
            self._trace_count += 1
            t_steps = input_seq.shape[0]
            ks = jnp.arange(t_steps, dtype=jnp.float32)
            (carries, _), (out_seq, hidden, e_tl, l_tl, ev_tl) = \
                self._scan_chunk(cascade, banks, carries, prev0,
                                 input_seq, ks)
            if last_lif:
                primary = jnp.sum(out_seq > 0.5 * amp, axis=0)
            else:
                primary = out_seq[-1]
            flush = jnp.stack([
                self._flush(carries[i], i, t_steps * self.circs[i].clock_ns,
                            banks.get(kinds[i]))
                for i in range(n_layers)])
            if sharded:        # diagnostics are the only collectives
                e_tl = jax.lax.psum(e_tl, axes)
                l_tl = jax.lax.pmax(l_tl, axes)
                ev_tl = jax.lax.psum(ev_tl, axes)
                flush = jax.lax.psum(flush, axes)
            return primary, out_seq, hidden, e_tl, l_tl, ev_tl, flush

        if not sharded:
            return jax.jit(sim)

        carry_specs, prev_specs, bspec2, seq_spec, hidden_spec, bank_specs \
            = self._shard_specs(b, banks)
        out_specs = (bspec2, seq_spec, hidden_spec,
                     P_REPL, P_REPL, P_REPL, P_REPL)
        return shard_over_batch(
            sim, self.mesh,
            in_specs=(seq_spec, carry_specs, prev_specs, bank_specs),
            out_specs=out_specs)

    def _build_stream_step(self, b: int, banks: SurrogateLibrary):
        """Build the donated-carry chunk program for the streaming path.

        ``step(input_seq, k0, carries, prev_ys, banks)`` runs one chunk of
        ticks starting at global tick ``k0`` (a traced f32 scalar — chunk
        position never recompiles) and returns

            (primary, out_seq, hidden, e_tl, l_tl, ev_tl,
             new_carries, new_prev_ys, banks)

        with ``carries``/``prev_ys``/``banks`` DONATED: XLA aliases the
        chunk-to-chunk state (and the surrogate leaves) in place, so an
        unbounded-T stream runs in bounded device memory with zero
        per-chunk copies of state or predictor weights. ``primary`` is the
        chunk-local reduction of the monolithic program's primary output
        (per-chunk spike counts for a spiking last layer, last-tick codes
        otherwise) so :class:`StreamingRun` can merge exactly."""
        spec = self.spec
        amp = spec.spike_amp
        cascade = self._make_cascade()
        last_lif = spec.circuits[-1] == "lif"
        sharded = self.mesh is not None
        axes = tuple(self.mesh.axis_names) if sharded else ()

        def step(input_seq, k0, carries, prev_ys, banks):
            self._trace_count += 1
            t_steps = input_seq.shape[0]
            ks = k0 + jnp.arange(t_steps, dtype=jnp.float32)
            (carries, prev_ys), (out_seq, hidden, e_tl, l_tl, ev_tl) = \
                self._scan_chunk(cascade, banks, carries, prev_ys,
                                 input_seq, ks)
            if last_lif:
                primary = jnp.sum(out_seq > 0.5 * amp, axis=0)
            else:
                primary = out_seq[-1]
            if sharded:        # diagnostics are the only collectives
                e_tl = jax.lax.psum(e_tl, axes)
                l_tl = jax.lax.pmax(l_tl, axes)
                ev_tl = jax.lax.psum(ev_tl, axes)
            return (primary, out_seq, hidden, e_tl, l_tl, ev_tl,
                    carries, prev_ys, banks)

        donate = (2, 3, 4)             # carries, prev_ys, surrogate leaves
        if not sharded:
            return jax.jit(step, donate_argnums=donate)

        carry_specs, prev_specs, bspec2, seq_spec, hidden_spec, bank_specs \
            = self._shard_specs(b, banks)
        return shard_over_batch(
            step, self.mesh,
            in_specs=(seq_spec, P_REPL, carry_specs, prev_specs, bank_specs),
            out_specs=(bspec2, seq_spec, hidden_spec, P_REPL, P_REPL, P_REPL,
                       carry_specs, prev_specs, bank_specs),
            donate_argnums=donate)

    def _build_flush(self, b: int, banks: SurrogateLibrary):
        """Build the end-of-stream flush program.

        ``flush_fn(carries, t_ends, banks) -> (L,)`` charges the trailing
        idle static energy from the FINAL carries, with ``t_ends`` the
        per-layer run-end times (f32, layer-native clocks) as traced
        scalars — one compiled flush serves every stream length. Runs the
        same :meth:`_flush` math the monolithic program embeds, applied
        exactly once at the true end of the stream."""
        spec = self.spec
        kinds = spec.circuits
        n_layers = spec.n_layers
        sharded = self.mesh is not None

        def flush_fn(carries, t_ends, banks):
            flush = jnp.stack([self._flush(carries[i], i, t_ends[i],
                                           banks.get(kinds[i]))
                               for i in range(n_layers)])
            if sharded:
                flush = jax.lax.psum(flush, tuple(self.mesh.axis_names))
            return flush

        if not sharded:
            return jax.jit(flush_fn)
        carry_specs, _, _, _, _, bank_specs = self._shard_specs(b, banks)
        return shard_over_batch(flush_fn, self.mesh,
                                in_specs=(carry_specs, P_REPL, bank_specs),
                                out_specs=P_REPL)

    # --- continuous-batching slot programs (the serving layer) ----------------

    def _build_slot_step(self, b: int, banks: SurrogateLibrary):
        """Build the slot-masked chunk program for continuous batching.

        ``step(input_seq, k0, end_ks, carries, prev_ys, banks)`` is the
        streaming chunk program with two serving extensions:

          * ``end_ks (b,)`` f32 — each slot's *global end tick*; at tick
            ``k`` only slots with ``k < end_ks[slot]`` are live.  Dead
            slots (request finished mid-chunk, or seat empty) are frozen
            by the cascade's ``live`` mask: no events, no energy, carry
            held — so one compiled program serves every mix of request
            lengths without per-request padding artifacts.
          * per-slot records — energy/latency ``(T, L, b)`` and event
            counts ``(T, L, b)`` int32 stay per batch slot, so the
            scheduler can slice each tenant's rows out of the shared
            batch and the merged per-request :class:`NetworkRun` is
            bit-identical (rtol 1e-5 on f32 energy sums) to running that
            request alone.

        ``carries``/``prev_ys``/``banks`` are DONATED exactly as in
        :meth:`_build_stream_step`."""
        spec = self.spec
        amp = spec.spike_amp
        cascade = self._make_cascade(slot_records=True)
        last_lif = spec.circuits[-1] == "lif"
        record_hidden = self.record_hidden

        def step(input_seq, k0, end_ks, carries, prev_ys, banks):
            self._trace_count += 1
            t_steps = input_seq.shape[0]
            ks = k0 + jnp.arange(t_steps, dtype=jnp.float32)
            packs = self._mk_pack(banks)

            def tick(state, xs):
                carries, prev_ys = state
                u_in, k = xs
                live = k < end_ks
                new_carries, new_ys, es, ls, evs = cascade(
                    banks, carries, prev_ys, u_in, k, packs, live=live)
                out = (new_ys[-1],
                       tuple(new_ys) if record_hidden else (),
                       es, ls, evs)
                return (new_carries, new_ys), out

            (carries, prev_ys), (out_seq, hidden, e_tl, l_tl, ev_tl) = \
                jax.lax.scan(tick, (list(carries), list(prev_ys)),
                             (input_seq, ks))
            if last_lif:
                primary = jnp.sum(out_seq > 0.5 * amp, axis=0)
            else:
                primary = out_seq
            return (primary, out_seq, hidden, e_tl, l_tl, ev_tl,
                    carries, prev_ys, banks)

        return jax.jit(step, donate_argnums=(3, 4, 5))

    def _build_slot_flush(self, b: int, banks: SurrogateLibrary):
        """Build the per-slot leave-time flush program.

        ``flush_fn(carries, t_ends, banks) -> (L, b)`` is :meth:`_flush`
        with a per-layer per-slot end time ``t_ends (L, b)`` (f32,
        layer-native clocks) and per-slot energy sums — when a request
        leaves its slots mid-stream, the scheduler charges ITS trailing
        idle energy from the live carries without disturbing the other
        tenants (the carries are read, not donated). Slots whose
        ``t_ends`` entry is in the past (tau <= 0, e.g. every slot not
        owned by the leaving request) charge exactly zero."""
        spec = self.spec
        kinds = spec.circuits
        n_layers = spec.n_layers

        def flush_fn(carries, t_ends, banks):
            rows = []
            for i in range(n_layers):
                if self.backend != "lasana" or kinds[i] == "crossbar":
                    rows.append(jnp.zeros((b,), jnp.float32))
                    continue
                circ = self.circs[i]
                lst = carries[i]
                n_per = spec.layers[i].n_circuits(b) // b
                tau = jnp.repeat(t_ends[i], n_per) - lst.t_last
                feats = jnp.concatenate(
                    [jnp.zeros((lst.v.shape[0], circ.n_inputs),
                               jnp.float32),
                     lst.v[:, None], tau[:, None], lst.params], axis=1)
                e = banks.get(kinds[i]).predict("M_ES", feats)
                e = jnp.where(tau > 0, e, 0.0)
                rows.append(jnp.sum(e.reshape(b, -1), axis=1))
            return jnp.stack(rows)

        return jax.jit(flush_fn)

    def _build_slot_join(self, b: int):
        """Build the masked slot (re)initialization program.

        ``join_fn(carries, prev_ys, mask, g0) -> (carries, prev_ys)``
        resets the slots selected by ``mask (b,)`` to a fresh request
        start at global tick ``g0`` (traced f32 — joins never recompile):
        state back to :meth:`_init_carry` values, published outputs
        zeroed, and — lasana backend — ``t_last`` set to ``g0`` in each
        layer's native clock. Because simulation time enters the
        surrogate features only through ``tau = t - t_last``, a request
        whose slot starts life at offset ``g0`` sees exactly the tau
        sequence of a request started at tick 0: that time-translation
        invariance is what makes mid-stream joins bit-identical to solo
        runs. Unmasked slots pass through untouched (``carries`` /
        ``prev_ys`` are donated and alias in place)."""
        spec = self.spec
        n_layers = spec.n_layers

        def join_fn(carries, prev_ys, mask, g0):
            new_carries, new_prev = [], []
            for i in range(n_layers):
                init = self._init_carry(i, b)
                n_per = spec.layers[i].n_circuits(b) // b
                m = jnp.repeat(mask, n_per)

                def sel(new_leaf, old_leaf):
                    mm = m.reshape(m.shape[0],
                                   *([1] * (old_leaf.ndim - 1)))
                    return jnp.where(mm, new_leaf, old_leaf)

                carry = jax.tree.map(sel, init, carries[i])
                if self.backend == "lasana":
                    clock = self.circs[i].clock_ns
                    carry = carry._replace(
                        t_last=jnp.where(m, g0 * clock, carry.t_last))
                new_carries.append(carry)
                new_prev.append(jnp.where(mask[:, None], 0.0, prev_ys[i]))
            return new_carries, new_prev

        return jax.jit(join_fn, donate_argnums=(0, 1))

    def slot_programs(self, b: int, chunk_ticks: int,
                      surrogates=None) -> SlotPrograms:
        """Compile (or fetch) the continuous-batching program family.

        One :class:`SlotPrograms` per (``b``, ``chunk_ticks``, surrogate
        structure) — the serving layer's shape bucket. The scheduler owns
        the calling protocol: :meth:`_build_slot_join` seats joining
        requests, :meth:`_build_slot_step` advances all live slots one
        chunk, :meth:`_build_slot_flush` charges leavers' trailing idle
        energy. Programs are cached in the engine's AOT cache (only the
        ``step`` tick-scan counts toward :attr:`compile_count`) and take
        surrogates as traced arguments, so same-structure hot-swaps and
        multiple co-resident surrogate versions share one executable."""
        if self.backend not in ("lasana", "behavioral"):
            # behavioral is the serve layer's graceful-degradation
            # fallback (quarantined specs re-admit on the paper's
            # annotation substrate); golden stays out — its ODE stepping
            # is orders of magnitude off serving latency budgets
            raise ValueError("slot_programs requires backend='lasana' or "
                             f"'behavioral' (got {self.backend!r})")
        if self.mesh is not None:
            raise ValueError("slot_programs does not support mesh "
                             "sharding yet")
        if chunk_ticks <= 0:
            raise ValueError(f"chunk_ticks must be positive: {chunk_ticks}")
        banks = self._runtime_banks(surrogates)
        spec = self.spec
        carries = [self._init_carry(i, b) for i in range(spec.n_layers)]
        prev0 = [jnp.zeros((b, l.n_out), jnp.float32)
                 for l in spec.layers]
        x0 = jnp.zeros((chunk_ticks, b, spec.layers[0].fan_in),
                       jnp.float32)
        scal = jnp.zeros((), jnp.float32)
        total = 0.0
        step, cs = self._compiled(
            self._program_key("slot", b, chunk_ticks, banks),
            lambda: self._build_slot_step(b, banks),
            (x0, scal, jnp.zeros((b,), jnp.float32), carries, prev0,
             banks))
        total += cs
        flush, cs = self._compiled(
            self._program_key("slotflush", b, None, banks),
            lambda: self._build_slot_flush(b, banks),
            (carries, jnp.zeros((spec.n_layers, b), jnp.float32), banks))
        total += cs
        join, cs = self._compiled(
            self._program_key("slotjoin", b, None, banks),
            lambda: self._build_slot_join(b),
            (carries, prev0, jnp.zeros((b,), bool), scal))
        total += cs
        return SlotPrograms(step=step, flush=flush, join=join,
                            compile_seconds=total)

    def _runtime_banks(self, surrogates) -> SurrogateLibrary:
        if self.backend != "lasana":
            if surrogates is not None:
                raise ValueError(
                    f"backend={self.backend!r} does not use surrogates; "
                    "pass surrogates= only with backend='lasana' (or drop "
                    "the argument to run the reference backend)")
            return SurrogateLibrary()
        banks = (self._normalize_surrogates(surrogates)
                 if surrogates is not None else self.surrogates)
        if banks is None:
            raise ValueError(
                "backend='lasana' requires surrogates: pass surrogates= (a "
                "Surrogate or {circuit: Surrogate} library; legacy "
                "PredictorBank values are converted) to NetworkEngine or "
                "run()")
        return banks

    def _program_key(self, kind: str, b: int, t_steps, banks) -> tuple:
        """Cache key of a compiled program: shapes + surrogate structure.

        ``kind`` separates the monolithic (``"mono"``), streaming-chunk
        (``"stream"``), stream-flush (``"flush"``) and continuous-batching
        (``"slot"`` / ``"slotflush"`` / ``"slotjoin"``) programs; the
        engine's ``fused`` flag, the resolved fused-kernel switch
        (``fused_kernel=`` override else ``REPRO_FUSED_KERNEL``) and the
        resolved megakernel launcher (``REPRO_TICK_PALLAS``) are part of
        the key because each selects a different traced inference body
        (without them in the key, flipping a switch after the first run
        would silently reuse the old program). Two libraries with equal
        treedefs (manifests included) and equal leaf shapes/dtypes share
        one executable — a retrained surrogate is a weight swap, not a
        recompile. The surrogate part of the key is
        ``surrogate.structure_key``, shared with the DSE sweep engine so
        the hot-swap contract cannot drift between the two."""
        from repro.core.surrogate import structure_key
        from repro.kernels import ops
        return (kind, self.fused,
                ops.fused_kernel_enabled(self.fused_kernel),
                ops.tick_pallas_enabled(), b, t_steps,
                structure_key(banks))

    def _compiled(self, key, build, example_args):
        """AOT lower+compile ``build()`` once per cache key.

        Returns ``(compiled, compile_seconds)`` where ``compile_seconds``
        is 0.0 on cache hits; tick-scan programs (``mono``/``stream``/
        ``slot``) count toward :attr:`compile_count`, the tiny flush and
        join helpers do not (they are stream/serve bookkeeping, not
        network programs). Thread-safe: concurrent callers racing on one
        uncompiled key serialize on :attr:`_compile_lock` and share the
        single resulting executable (exactly one compile)."""
        entry = self._sim_cache.get(key)
        if entry is not None:
            return entry[0], 0.0
        with self._compile_lock:
            entry = self._sim_cache.get(key)
            if entry is not None:
                return entry[0], 0.0
            fn = build()
            t0 = time.time()
            compiled = fn.lower(*example_args).compile()
            compile_s = time.time() - t0
            self._sim_cache[key] = (compiled, compile_s)
            if key[0] in ("mono", "stream", "slot"):
                self.compile_count += 1
        return compiled, compile_s

    def _check_mesh_batch(self, b: int):
        if self.mesh is not None:
            n_dev = int(np.prod([self.mesh.shape[a]
                                 for a in self.mesh.axis_names]))
            if b % n_dev:
                raise ValueError(f"batch {b} not divisible by mesh size "
                                 f"{n_dev}")

    def _run(self, x, *, surrogates=None) -> NetworkRun:
        spec = self.spec
        t_steps, b, _ = x.shape
        self._check_mesh_batch(b)
        banks = self._runtime_banks(surrogates)
        carries = [self._init_carry(i, b) for i in range(spec.n_layers)]
        prev0 = [jnp.zeros((b, l.n_out), jnp.float32) for l in spec.layers]

        # AOT-compile once per (shapes, surrogate structure): later runs
        # — including runs with swapped surrogate weights — only execute
        key = self._program_key("mono", b, t_steps, banks)
        compiled, compile_s = self._compiled(
            key, lambda: self._build_sim(b, banks),
            (x, carries, prev0, banks))
        if compile_s == 0.0:
            compile_s = self._sim_cache[key][1]    # historical build time

        t0 = time.time()
        primary, out_seq, hidden, e_tl, l_tl, ev_tl, flush = \
            jax.block_until_ready(compiled(x, carries, prev0, banks))
        wall = time.time() - t0
        last_lif = spec.circuits[-1] == "lif"
        return NetworkRun(
            backend=self.backend, mode=self.mode,
            outputs=np.asarray(primary),
            out_spikes=np.asarray(out_seq) if last_lif else None,
            layer_spikes=[np.asarray(h) for h in hidden]
            if self.record_hidden else None,
            energy=np.asarray(e_tl), latency=np.asarray(l_tl),
            events=np.asarray(ev_tl, np.int64),
            flush_energy=np.asarray(flush),
            n_circuits=np.asarray([l.n_circuits(b) for l in spec.layers]),
            clock_ns=self.clock_ns, wall_seconds=wall,
            circuits=spec.circuits, compile_seconds=compile_s)
