"""Architecture exploration: map LM-zoo architectures onto analog crossbar
macros and annotate energy/latency with LASANA surrogates (DESIGN.md §2.3).

Only *weight-stationary* matmuls map to crossbars (QKVO/FFN/expert/embed
projections); activation-activation products (attention scores, SSD scans,
RG-LRU recurrences) and routers stay digital. Each weight matrix is tiled
into (rows/32 x cols/32) differential-pair macros; one token's forward pass
fires one MVM event per tile, whose energy/latency come from the trained
``M_ED``/``M_L`` crossbar surrogates averaged over the input distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Family, ModelConfig
from repro.core.circuits import CrossbarRow
from repro.core.predictors import PredictorBank, build_features
from repro.models import params as prm
from repro.models.model import Model

TILE = 32

# analog-unmappable params (gather tables / recurrent gates): see DESIGN.md
_DIGITAL_KEYS = ("embedding", "router", "a_log", "dt_bias", "d_skip", "lam",
                 "conv_w", "conv_b", "norm", "ln", "q_norm", "kv_norm",
                 "b_a", "b_i", "kpos")


@dataclasses.dataclass
class TileReport:
    arch: str
    n_matrices: int
    n_tiles: int
    analog_params: int
    total_params: int
    analog_flop_fraction: float
    energy_per_token_j: float
    latency_critical_ns: float
    tile_energy_j: float
    tiles_by_component: dict

    def summary(self) -> str:
        return (f"{self.arch}: {self.n_tiles:,} 32x32 tiles over "
                f"{self.n_matrices} matrices | analog FLOP fraction "
                f"{self.analog_flop_fraction:.2%} | "
                f"{self.energy_per_token_j * 1e9:.3f} nJ/token | "
                f"critical path {self.latency_critical_ns:.2f} ns/layer-stage")


def _is_analog(path: str, spec) -> bool:
    if any(k in path for k in _DIGITAL_KEYS):
        return False
    return len(spec.shape) >= 2


def _matrix_dims(spec) -> tuple[int, int, int]:
    """(count, rows, cols): stacked layer dims multiply the count."""
    shape = spec.shape
    count = 1
    if spec.logical and spec.logical[0] == "layers":
        count = shape[0]
        shape = shape[1:]
    if spec.logical and len(spec.logical) and "experts" in (spec.logical[0],):
        pass
    rows = shape[0]
    cols = int(np.prod(shape[1:]))
    return count, rows, cols


def tile_energy_latency(bank: PredictorBank, *, seed=0, n_samples=2048):
    """Mean per-MVM-event energy (J) / latency (ns) of one 32x32 macro."""
    circ = CrossbarRow()
    key = jax.random.PRNGKey(seed)
    kx, kp, ko = jax.random.split(key, 3)
    x = circ.sample_inputs(kx, (n_samples,))
    p = circ.sample_params(kp, n_samples)
    o_prev = jax.random.uniform(ko, (n_samples,), jnp.float32, -2, 2)
    v = jnp.zeros((n_samples,))
    tau = jnp.full((n_samples,), circ.clock_ns)
    base = jnp.concatenate([x, v[:, None], tau[:, None], p], axis=1)
    o_new = bank.predict("M_O", base)
    feats = jnp.concatenate([base, o_prev[:, None], o_new[:, None]], axis=1)
    e = float(jnp.mean(bank.predict("M_ED", feats)))
    lat = float(jnp.mean(bank.predict("M_L", feats)))
    return e, lat


def explore_arch(cfg: ModelConfig, bank: PredictorBank) -> TileReport:
    model = Model(cfg)
    specs = model.param_specs()
    # jax.tree.leaves_with_path only exists on newer jax; tree_util spells
    # it the same on 0.4.x
    flat = jax.tree_util.tree_leaves_with_path(specs)
    e_tile, l_tile = tile_energy_latency(bank)

    n_tiles = 0
    n_matrices = 0
    analog_params = 0
    total_params = 0
    energy_token = 0.0
    by_comp: dict[str, int] = {}
    for path, spec in flat:
        pstr = jax.tree_util.keystr(path)
        count_elems = int(np.prod(spec.shape))
        total_params += count_elems
        if not _is_analog(pstr, spec):
            continue
        count, rows, cols = _matrix_dims(spec)
        tiles = count * (-(-rows // TILE)) * (-(-cols // TILE))
        n_tiles += tiles
        n_matrices += count
        analog_params += count_elems
        comp = pstr.split("'")[1] if "'" in pstr else pstr
        by_comp[comp] = by_comp.get(comp, 0) + tiles
        # every token fires each tile once per forward pass; MoE scales by
        # the active-expert fraction
        util = 1.0
        if cfg.moe is not None and "moe" in pstr and "shared" not in pstr \
                and "router" not in pstr:
            util = (cfg.moe.top_k) / cfg.moe.n_experts
        energy_token += tiles * e_tile * util

    # digital-FLOP share: attention scores (seq-dependent) + unmapped params.
    # At S=4096: score flops/token = 4*S*H*Dh per layer.
    s_ref = 4096
    if cfg.attention.value != "none":
        score = 4 * s_ref * cfg.n_heads * cfg.head_dim * cfg.n_layers
    else:
        score = 0
    analog_flops = 2 * analog_params
    if cfg.moe is not None:
        act = cfg.active_param_count()
        analog_flops = int(analog_flops * act / max(cfg.param_count(), 1))
    digital_flops = 2 * (total_params - analog_params) + score
    frac = analog_flops / max(analog_flops + digital_flops, 1)

    return TileReport(
        arch=cfg.name,
        n_matrices=n_matrices,
        n_tiles=n_tiles,
        analog_params=analog_params,
        total_params=total_params,
        analog_flop_fraction=frac,
        energy_per_token_j=energy_token,
        latency_critical_ns=l_tile,
        tile_energy_j=e_tile,
        tiles_by_component=by_comp,
    )
