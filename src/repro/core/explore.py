"""Design-space exploration: map architectures onto analog crossbar macros
and annotate energy/latency with LASANA surrogates (DESIGN.md §2.3).

Two evaluation paths share one tile model:

* :func:`explore_arch` — the legacy per-architecture path: walk one
  ``ModelConfig``'s parameter specs, tile every weight-stationary matrix
  into 32x32 differential-pair macros, and price each tile with a trained
  crossbar surrogate (``PredictorBank`` or :class:`Surrogate`).
* :class:`DSEEngine` / :func:`evaluate_candidates` — the vectorized
  design-space engine (the paper's §I "rapid exploration and co-design"
  at scale): a batched :class:`CandidateSpec` (layer widths, tile size,
  V_dd, MoE shape, circuit mix) evaluates as ONE program — tile math is
  pure array ops over the candidate arrays, and per-tile energy/latency
  comes from a single AOT-compiled :meth:`Surrogate.predict_heads` pass
  shared across every candidate. Surrogates stay traced pytree arguments
  (the PR-3 zero-recompile contract), so a 10^3–10^4-point sweep compiles
  once and retrained surrogates re-price the whole space for free.

Only *weight-stationary* matmuls map to crossbars (QKVO/FFN/expert
projections); activation-activation products (attention scores, SSD scans,
RG-LRU recurrences) and routers stay digital. Each weight matrix is tiled
into (rows/T x cols/T) differential-pair macros; one token's forward pass
fires one MVM event per tile, whose energy/latency come from the trained
``M_ED``/``M_L`` crossbar surrogates averaged over the input distribution.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Family, ModelConfig
from repro.core.circuits import CrossbarRow
from repro.core.predictors import PredictorBank, build_features
from repro.core.surrogate import (Surrogate, SurrogateLibrary, as_surrogate,
                                  structure_key)
from repro.models import params as prm
from repro.models.model import Model

TILE = 32
# DAC full-scale drive tracks the supply rail; candidates' V_dd enters the
# surrogate through the input-voltage scale relative to this training rail
VDD_REF = 1.2

# analog-unmappable params (gather tables / recurrent gates): see DESIGN.md
_DIGITAL_KEYS = ("embedding", "router", "a_log", "dt_bias", "d_skip", "lam",
                 "conv_w", "conv_b", "norm", "ln", "q_norm", "kv_norm",
                 "b_a", "b_i", "kpos")

# leading ParamSpec axes that enumerate independent matrices (each slice is
# its own weight-stationary matmul) rather than matrix rows
_STACK_AXES = ("layers", "experts")


@dataclasses.dataclass
class TileReport:
    arch: str
    n_matrices: int
    n_tiles: int
    analog_params: int
    total_params: int
    analog_flop_fraction: float
    energy_per_token_j: float
    latency_critical_ns: float
    tile_energy_j: float
    tiles_by_component: dict

    def summary(self) -> str:
        return (f"{self.arch}: {self.n_tiles:,} 32x32 tiles over "
                f"{self.n_matrices} matrices | analog FLOP fraction "
                f"{self.analog_flop_fraction:.2%} | "
                f"{self.energy_per_token_j * 1e9:.3f} nJ/token | "
                f"critical path {self.latency_critical_ns:.2f} ns/layer-stage")


def _is_analog(path: str, spec) -> bool:
    if any(k in path for k in _DIGITAL_KEYS):
        return False
    return len(spec.shape) >= 2


def _matrix_dims(spec) -> tuple[int, int, int]:
    """(count, rows, cols) of a weight spec's independent matmul matrices.

    Leading ``"layers"`` / ``"experts"`` logical axes enumerate stacked
    *independent* matrices (a scan-over-layers stack, an expert bank) and
    multiply ``count``; the remaining axes are one matrix of ``rows`` x
    ``cols``. An ``(E, d, f)`` expert bank therefore tiles as
    ``E * ceil(d/T) * ceil(f/T)`` — NOT as a single ``(E, d*f)`` matrix,
    which would corrupt tile counts for every MoE architecture.
    """
    shape = list(spec.shape)
    logical = list(spec.logical or ())
    count = 1
    while len(shape) > 2 and logical and logical[0] in _STACK_AXES:
        count *= shape.pop(0)
        logical.pop(0)
    rows = shape[0]
    cols = int(np.prod(shape[1:]))
    return count, rows, cols


def _crossbar_surrogate(surrogates) -> Any:
    """Resolve the crossbar-tile predictor from any accepted form.

    Accepts a :class:`Surrogate`, a legacy fitted ``PredictorBank`` (both
    used directly), or a :class:`SurrogateLibrary` / ``{kind: surrogate}``
    dict — the ``"crossbar"`` entry prices the 32x32 MVM macro."""
    if isinstance(surrogates, (SurrogateLibrary, dict)):
        sur = surrogates.get("crossbar")
        if sur is None:
            raise ValueError(
                "exploration needs a 'crossbar' surrogate; the given "
                "library carries none")
        return sur
    return surrogates


def tile_energy_latency(bank, *, seed=0, n_samples=2048):
    """Mean per-MVM-event energy (J) / latency (ns) of one 32x32 macro."""
    bank = _crossbar_surrogate(bank)
    circ = CrossbarRow()
    key = jax.random.PRNGKey(seed)
    kx, kp, ko = jax.random.split(key, 3)
    x = circ.sample_inputs(kx, (n_samples,))
    p = circ.sample_params(kp, n_samples)
    o_prev = jax.random.uniform(ko, (n_samples,), jnp.float32, -2, 2)
    v = jnp.zeros((n_samples,))
    tau = jnp.full((n_samples,), circ.clock_ns)
    base = jnp.concatenate([x, v[:, None], tau[:, None], p], axis=1)
    o_new = bank.predict("M_O", base)
    feats = jnp.concatenate([base, o_prev[:, None], o_new[:, None]], axis=1)
    e = float(jnp.mean(bank.predict("M_ED", feats)))
    lat = float(jnp.mean(bank.predict("M_L", feats)))
    return e, lat


def explore_arch(cfg: ModelConfig, bank) -> TileReport:
    """Map one zoo architecture onto 32x32 crossbar macros (legacy path).

    ``bank`` is a trained crossbar predictor in any accepted form (see
    :func:`_crossbar_surrogate`). For thousand-point candidate sweeps use
    :func:`evaluate_candidates`, which prices every candidate through one
    compiled program instead of re-dispatching per architecture."""
    bank = _crossbar_surrogate(bank)
    model = Model(cfg)
    specs = model.param_specs()
    # jax.tree.leaves_with_path only exists on newer jax; tree_util spells
    # it the same on 0.4.x
    flat = jax.tree_util.tree_leaves_with_path(specs)
    e_tile, l_tile = tile_energy_latency(bank)

    n_tiles = 0
    n_matrices = 0
    analog_params = 0
    total_params = 0
    energy_token = 0.0
    by_comp: dict[str, int] = {}
    for path, spec in flat:
        pstr = jax.tree_util.keystr(path)
        count_elems = int(np.prod(spec.shape))
        total_params += count_elems
        if not _is_analog(pstr, spec):
            continue
        count, rows, cols = _matrix_dims(spec)
        tiles = count * (-(-rows // TILE)) * (-(-cols // TILE))
        n_tiles += tiles
        n_matrices += count
        analog_params += count_elems
        # leaf weight name (w_gate, wq, ...) so MoE expert banks report
        # their exact per-matrix tile counts instead of a stack aggregate
        comp = pstr.split("'")[-2] if "'" in pstr else pstr
        by_comp[comp] = by_comp.get(comp, 0) + tiles
        # every token fires each tile once per forward pass; MoE scales by
        # the active-expert fraction
        util = 1.0
        if cfg.moe is not None and "moe" in pstr and "shared" not in pstr \
                and "router" not in pstr:
            util = (cfg.moe.top_k) / cfg.moe.n_experts
        energy_token += tiles * e_tile * util

    # digital-FLOP share: attention scores (seq-dependent) + unmapped params.
    # At S=4096: score flops/token = 4*S*H*Dh per layer.
    s_ref = 4096
    if cfg.attention.value != "none":
        score = 4 * s_ref * cfg.n_heads * cfg.head_dim * cfg.n_layers
    else:
        score = 0
    analog_flops = 2 * analog_params
    if cfg.moe is not None:
        act = cfg.active_param_count()
        analog_flops = int(analog_flops * act / max(cfg.param_count(), 1))
    digital_flops = 2 * (total_params - analog_params) + score
    frac = analog_flops / max(analog_flops + digital_flops, 1)

    return TileReport(
        arch=cfg.name,
        n_matrices=n_matrices,
        n_tiles=n_tiles,
        analog_params=analog_params,
        total_params=total_params,
        analog_flop_fraction=frac,
        energy_per_token_j=energy_token,
        latency_critical_ns=l_tile,
        tile_energy_j=e_tile,
        tiles_by_component=by_comp,
    )


# --- batched candidate space ----------------------------------------------------

# (field, default, dtype) — the knobs a DSE candidate carries
_CANDIDATE_FIELDS = (
    ("d_model", 512, np.int64),       # residual width
    ("d_ff", 2048, np.int64),         # FFN (or per-expert) hidden width
    ("n_layers", 8, np.int64),
    ("n_heads", 8, np.int64),
    ("n_kv_heads", 8, np.int64),      # GQA: kv head count
    ("n_experts", 0, np.int64),       # 0 -> dense FFN
    ("top_k", 0, np.int64),           # active experts per token (MoE only)
    ("tile", TILE, np.int64),         # crossbar macro edge (TxT)
    ("v_dd", VDD_REF, np.float32),    # analog supply rail (V)
    ("analog_attn", 1, np.int64),     # 1: QKVO projections map to crossbars
    ("analog_ffn", 1, np.int64),      # 1: FFN/expert matmuls map to crossbars
    ("vocab", 32000, np.int64),       # embedding + LM head (always digital)
)


@dataclasses.dataclass(frozen=True)
class CandidateSpec:
    """A batch of candidate accelerator/architecture configurations.

    Every field is a ``(C,)`` array — candidate ``i`` is row ``i`` across
    all fields. Build one with :meth:`of` (broadcasting scalars),
    :meth:`sample` (randomized sweep) or :meth:`grid` (cartesian product),
    then price the whole batch with :func:`evaluate_candidates` /
    ``lasana.explore``. Knobs:

    ``d_model``/``d_ff``/``n_layers``/``n_heads``/``n_kv_heads``
        transformer layer widths (GQA kv heads; ``head_dim = d_model //
        n_heads``)
    ``n_experts``/``top_k``
        MoE shape; ``n_experts == 0`` is a dense FFN. Expert matrices tile
        per expert and consume energy at the ``top_k / n_experts``
        utilization.
    ``tile``
        crossbar macro edge T (a TxT tile = (T/32)^2 of the trained 32x32
        macro; energy scales with that area, rows settle in parallel)
    ``v_dd``
        analog supply rail; enters the surrogate through the DAC
        full-scale input drive (``v_dd / 1.2`` relative to the training
        rail)
    ``analog_attn``/``analog_ffn``
        circuit mix: which weight-stationary matmul groups map to analog
        crossbars (0 keeps them digital)
    ``vocab``
        embedding/LM-head size — always digital (gather), counts toward
        the digital FLOP share only
    """

    d_model: np.ndarray
    d_ff: np.ndarray
    n_layers: np.ndarray
    n_heads: np.ndarray
    n_kv_heads: np.ndarray
    n_experts: np.ndarray
    top_k: np.ndarray
    tile: np.ndarray
    v_dd: np.ndarray
    analog_attn: np.ndarray
    analog_ffn: np.ndarray
    vocab: np.ndarray

    def __post_init__(self):
        """Broadcast every field to one common ``(C,)`` length and check
        the knobs are self-consistent (positive widths, ``top_k`` within
        ``n_experts``)."""
        arrays = {}
        c = 1
        for name, _, dtype in _CANDIDATE_FIELDS:
            a = np.atleast_1d(np.asarray(getattr(self, name), dtype))
            if a.ndim != 1:
                raise ValueError(f"CandidateSpec.{name} must be scalar or "
                                 f"1-D, got shape {a.shape}")
            arrays[name] = a
            c = max(c, a.shape[0])
        for name, a in arrays.items():
            if a.shape[0] not in (1, c):
                raise ValueError(
                    f"CandidateSpec.{name} has {a.shape[0]} entries but the "
                    f"batch has {c}")
            object.__setattr__(self, name,
                               np.broadcast_to(a, (c,)).copy())
        if np.any(self.d_model < 1) or np.any(self.d_ff < 1) \
                or np.any(self.n_layers < 1) or np.any(self.n_heads < 1) \
                or np.any(self.n_kv_heads < 1) or np.any(self.tile < 1):
            raise ValueError("CandidateSpec widths/tile must be >= 1")
        if np.any(self.v_dd <= 0):
            raise ValueError("CandidateSpec.v_dd must be positive")
        moe = self.n_experts > 0
        if np.any(moe & ((self.top_k < 1) | (self.top_k > self.n_experts))):
            raise ValueError("MoE candidates need 1 <= top_k <= n_experts")

    def __len__(self) -> int:
        return int(self.d_model.shape[0])

    @classmethod
    def of(cls, **knobs) -> "CandidateSpec":
        """Build a batch from scalars/arrays; unspecified knobs take the
        documented defaults, scalars broadcast to the batch length."""
        vals = {name: knobs.pop(name, default)
                for name, default, _ in _CANDIDATE_FIELDS}
        if knobs:
            raise TypeError(f"unknown candidate knob(s): {sorted(knobs)}")
        return cls(**vals)

    @classmethod
    def sample(cls, n: int, *, seed: int = 0, moe_fraction: float = 0.4,
               v_dd_range: tuple = (0.9, 1.5)) -> "CandidateSpec":
        """Randomized ``n``-candidate design space (the sweep generator).

        Widths are drawn from hardware-plausible menus (power-of-two
        ``d_model``, 2-4x FFN expansion, GQA ratios), ``moe_fraction`` of
        candidates get an expert bank, tile sizes span 16-128, and
        ``v_dd`` is uniform over ``v_dd_range``. Deterministic in
        ``seed``."""
        rng = np.random.default_rng(seed)
        d_model = rng.choice([256, 512, 768, 1024, 2048, 4096], n)
        d_ff = d_model * rng.choice([2, 3, 4], n)
        n_layers = rng.choice([4, 8, 12, 16, 24, 32], n)
        n_heads = np.maximum(d_model // 64, 1)
        n_kv_heads = np.maximum(n_heads // rng.choice([1, 1, 2, 4], n), 1)
        moe = rng.random(n) < moe_fraction
        n_experts = np.where(moe, rng.choice([8, 16, 32, 64], n), 0)
        top_k = np.where(moe, np.minimum(rng.choice([1, 2, 4, 8], n),
                                         np.maximum(n_experts, 1)), 0)
        # routed experts are thinner than dense FFNs
        d_ff = np.where(moe, np.maximum(d_model // 2, TILE), d_ff)
        tile = rng.choice([16, 32, 64, 128], n)
        v_dd = rng.uniform(v_dd_range[0], v_dd_range[1], n).astype(np.float32)
        analog_attn = rng.choice([0, 1], n, p=[0.25, 0.75])
        analog_ffn = rng.choice([0, 1], n, p=[0.1, 0.9])
        return cls.of(d_model=d_model, d_ff=d_ff, n_layers=n_layers,
                      n_heads=n_heads, n_kv_heads=n_kv_heads,
                      n_experts=n_experts, top_k=top_k, tile=tile, v_dd=v_dd,
                      analog_attn=analog_attn, analog_ffn=analog_ffn)

    @classmethod
    def grid(cls, **axes) -> "CandidateSpec":
        """Cartesian product over the given per-knob value lists.

        ``CandidateSpec.grid(d_model=[512, 1024], v_dd=[1.0, 1.2])`` is a
        4-candidate batch; unspecified knobs take their defaults."""
        names = [n for n, _, _ in _CANDIDATE_FIELDS if n in axes]
        unknown = set(axes) - set(names)
        if unknown:
            raise TypeError(f"unknown candidate knob(s): {sorted(unknown)}")
        lists = [np.atleast_1d(np.asarray(axes[n])) for n in names]
        mesh = np.meshgrid(*lists, indexing="ij") if lists else []
        return cls.of(**{n: m.reshape(-1) for n, m in zip(names, mesh)})

    def take(self, idx) -> "CandidateSpec":
        """Sub-batch at integer indices ``idx`` (fancy-indexes every knob
        array) — e.g. ``cands.take(report.pareto())``."""
        idx = np.asarray(idx)
        return CandidateSpec(**{name: getattr(self, name)[idx]
                                for name, _, _ in _CANDIDATE_FIELDS})

    def row(self, i: int) -> dict:
        """Candidate ``i`` as a plain ``{knob: python scalar}`` dict."""
        return {name: getattr(self, name)[i].item()
                for name, _, _ in _CANDIDATE_FIELDS}


def _ceil_div(a, b):
    return -(-a // b)


def _tile_table(c: CandidateSpec) -> dict:
    """Pure vectorized tile math over a candidate batch -> (C,) arrays.

    All counts are exact ``int64`` array ops (no surrogate involved):
    per-layer tile/param counts for the attention (QKVO) and FFN/expert
    groups, active-vs-total parameter counts, and the digital score-FLOP
    term at the reference sequence length."""
    d, f, t = c.d_model, c.d_ff, c.tile
    dh = np.maximum(c.d_model // np.maximum(c.n_heads, 1), 1)
    kv = c.n_kv_heads * dh
    td, tf, tkv = _ceil_div(d, t), _ceil_div(f, t), _ceil_div(kv, t)

    # per-layer tile counts per mapped group
    tiles_attn = 2 * td * td + 2 * td * tkv             # wq, wo + wk, wv
    moe = c.n_experts > 0
    tiles_ffn_dense = 3 * td * tf                        # gate/up/down
    tiles_ffn = np.where(moe, c.n_experts * tiles_ffn_dense, tiles_ffn_dense)
    # MoE fires only the routed top-k fraction of expert tiles per token
    util = np.where(moe, c.top_k / np.maximum(c.n_experts, 1), 1.0)

    # per-layer parameter counts (matrix elements, not padded tiles)
    p_attn = 2 * d * d + 2 * d * kv
    p_ffn_all = np.where(moe, c.n_experts, 1) * 3 * d * f
    p_ffn_act = np.where(moe, c.top_k, 1) * 3 * d * f
    p_router = np.where(moe, d * c.n_experts, 0)         # always digital

    a_attn, a_ffn = c.analog_attn.astype(np.int64), \
        c.analog_ffn.astype(np.int64)
    n_tiles = c.n_layers * (a_attn * tiles_attn + a_ffn * tiles_ffn)
    # energy-weighted tiles fired per token
    tiles_token = c.n_layers * (a_attn * tiles_attn
                                + a_ffn * tiles_ffn * util)
    analog_active = c.n_layers * (a_attn * p_attn + a_ffn * p_ffn_act)
    total_active = c.n_layers * (p_attn + p_ffn_act + p_router) \
        + 2 * c.vocab * d
    # digital score flops/token at the reference sequence length
    s_ref = 4096
    score = 4 * s_ref * c.n_heads * dh * c.n_layers
    analog_flops = 2 * analog_active
    digital_flops = 2 * (total_active - analog_active) + score
    frac = analog_flops / np.maximum(analog_flops + digital_flops, 1)
    # sequential analog stages per token: QKV->O, up/gate->down
    stages = c.n_layers * (2 * a_attn + 2 * a_ffn)
    return {
        "n_tiles": n_tiles.astype(np.int64),
        "tiles_token": tiles_token.astype(np.float64),
        "analog_params": analog_active.astype(np.int64),
        "total_params": total_active.astype(np.int64),
        "analog_flop_fraction": frac.astype(np.float64),
        "stages": stages.astype(np.int64),
    }


# --- the vectorized DSE engine --------------------------------------------------

@dataclasses.dataclass
class DSEReport:
    """Batched exploration result: one row per candidate, plus frontier.

    Array fields are ``(C,)`` aligned with ``candidates``; ``pareto()``
    extracts the non-dominated set over (energy/token, critical-path
    latency, analog-FLOP fraction). ``compile_count`` is the number of
    distinct surrogate-pass programs the serving :class:`DSEEngine` has
    compiled — a whole sweep (any C, any retrained surrogate of equal
    structure) holds at <= 2.
    """

    candidates: CandidateSpec
    n_tiles: np.ndarray              # (C,) int64 mapped crossbar tiles
    analog_params: np.ndarray        # (C,) int64 active analog matrix params
    total_params: np.ndarray         # (C,) int64 active params incl. digital
    analog_flop_fraction: np.ndarray # (C,) float64 in [0, 1]
    energy_per_token_j: np.ndarray   # (C,) float64 J per forward token
    latency_critical_ns: np.ndarray  # (C,) float64 analog critical path
    tile_energy_j: np.ndarray        # (C,) float64 per-tile MVM energy
    tile_latency_ns: np.ndarray      # (C,) float64 per-tile settle latency
    compile_count: int = 0
    wall_seconds: float = 0.0

    def __len__(self) -> int:
        return len(self.candidates)

    def pareto(self) -> np.ndarray:
        """Indices of the Pareto frontier: minimize energy/token and
        critical-path latency, maximize analog-FLOP fraction."""
        objs = np.stack([self.energy_per_token_j, self.latency_critical_ns,
                         -self.analog_flop_fraction], axis=1)
        return np.flatnonzero(pareto_mask(objs))

    def summary(self, i: int) -> str:
        """One-line human-readable report row for candidate ``i``."""
        c = self.candidates.row(i)
        moe = (f" E{c['n_experts']}k{c['top_k']}" if c["n_experts"] else "")
        return (f"d{c['d_model']}xf{c['d_ff']}xL{c['n_layers']}{moe} "
                f"T={c['tile']} Vdd={c['v_dd']:.2f}: "
                f"{int(self.n_tiles[i]):,} tiles | "
                f"analog {self.analog_flop_fraction[i]:.1%} | "
                f"{self.energy_per_token_j[i] * 1e9:.3f} nJ/tok | "
                f"{self.latency_critical_ns[i]:.1f} ns")

    def as_dict(self, idx=None) -> dict:
        """JSON-ready ``{column: list}`` table (optionally only rows
        ``idx``) — what ``benchmarks/run.py --json`` records."""
        idx = np.arange(len(self)) if idx is None else np.asarray(idx)
        out = {name: getattr(self.candidates, name)[idx].tolist()
               for name, _, _ in _CANDIDATE_FIELDS}
        for col in ("n_tiles", "analog_flop_fraction", "energy_per_token_j",
                    "latency_critical_ns"):
            out[col] = getattr(self, col)[idx].tolist()
        return out


def pareto_mask(objectives: np.ndarray) -> np.ndarray:
    """Non-dominated mask of ``(C, K)`` objective rows (all minimized).

    Row i is dominated when some row j is <= on every objective and
    strictly < on at least one. O(C^2) broadcasting — fine for the
    10^3-10^4-point spaces this engine targets."""
    o = np.asarray(objectives, np.float64)
    le = np.all(o[:, None, :] <= o[None, :, :], axis=-1)    # j dominates-ish i
    lt = np.any(o[:, None, :] < o[None, :, :], axis=-1)
    dominated = np.any(le & lt, axis=0)
    return ~dominated


class DSEEngine:
    """Compile-once vectorized evaluator for candidate sweeps.

    One AOT-compiled program per (candidate count, sample count, surrogate
    structure) prices every candidate's crossbar tile from a single
    :meth:`Surrogate.predict_heads` pass: the testbench input rows are
    scaled per candidate by the V_dd drive ratio, the transition heads
    (``M_ED``/``M_L``) run over the whole ``(C * n_samples)`` feature
    matrix at once, and the per-candidate means come back as ``(C,)``
    arrays. Surrogates are traced pytree arguments — retrained weights of
    equal structure NEVER recompile (``compile_count`` stays put), exactly
    like the network engine's serving contract.
    """

    def __init__(self, *, n_samples: int = 256, seed: int = 0):
        self.n_samples = int(n_samples)
        self.seed = int(seed)
        self.compile_count = 0           # distinct compiled sweep programs
        self._trace_count = 0
        self._programs: dict = {}
        self._circ = CrossbarRow()
        key = jax.random.PRNGKey(self.seed)
        kx, kp, ko = jax.random.split(key, 3)
        n = self.n_samples
        self._base_x = self._circ.sample_inputs(kx, (n,))
        self._base_p = self._circ.sample_params(kp, n)
        self._base_o = jax.random.uniform(ko, (n,), jnp.float32, -2, 2)

    # -- the traced surrogate pass ------------------------------------------
    def _tile_eval(self, surrogate, v_dd, tile):
        """(C,) per-candidate tile energy/latency from one fused pass."""
        self._trace_count += 1
        n = self.n_samples
        c = v_dd.shape[0]
        drive = (v_dd / VDD_REF)[:, None, None]             # (C,1,1)
        x = (self._base_x[None] * drive).reshape(c * n, -1)  # (C*N, n_in)
        p = jnp.broadcast_to(self._base_p[None],
                             (c, n, self._base_p.shape[1])).reshape(c * n, -1)
        v = jnp.zeros((c * n, 1), jnp.float32)
        tau = jnp.full((c * n, 1), self._circ.clock_ns, jnp.float32)
        base = jnp.concatenate([x, v, tau, p], axis=1)
        o_new = surrogate.predict_heads(
            feats_act=base, heads={"act": ("M_O",)})["act"]["M_O"]
        o_prev = jnp.broadcast_to(self._base_o[None], (c, n)).reshape(-1)
        tr = jnp.concatenate([base, o_prev[:, None], o_new[:, None]], axis=1)
        out = surrogate.predict_heads(
            feats_tr=tr, heads={"tr": ("M_ED", "M_L")})["tr"]
        e32 = jnp.mean(out["M_ED"].reshape(c, n), axis=1)
        l32 = jnp.mean(out["M_L"].reshape(c, n), axis=1)
        # a TxT tile is (T/32)^2 of the trained 32x32 macro area; its rows
        # (and 32-wide row segments) settle in parallel, so energy scales
        # with area while the settle latency stays the macro's
        area = jnp.square(tile.astype(jnp.float32) / TILE)
        return e32 * area, l32

    def _compiled_tile_eval(self, surrogate: Surrogate, c: int):
        """AOT lower+compile the sweep program once per cache key."""
        key = (c, self.n_samples, structure_key(surrogate))
        entry = self._programs.get(key)
        if entry is not None:
            return entry[0], 0.0
        fn = jax.jit(self._tile_eval)
        v_dd = jnp.zeros((c,), jnp.float32)
        tile = jnp.zeros((c,), jnp.int32)
        t0 = time.time()
        compiled = fn.lower(surrogate, v_dd, tile).compile()
        compile_s = time.time() - t0
        self._programs[key] = (compiled, compile_s)
        self.compile_count += 1
        return compiled, compile_s

    # -- public evaluation ---------------------------------------------------
    def evaluate(self, candidates: CandidateSpec, surrogates,
                 *, compiled: bool = True) -> DSEReport:
        """Price every candidate in one vectorized program -> DSEReport.

        ``surrogates`` is a crossbar :class:`Surrogate` (or library /
        legacy bank; resolved like :func:`explore_arch`). ``compiled=
        False`` runs the same math eagerly per call — the per-architecture
        dispatch baseline the benchmark A/Bs against."""
        sur = as_surrogate(_crossbar_surrogate(surrogates))
        if sur.circuit != "crossbar":
            raise ValueError(
                f"DSE tiles are crossbar macros; got a surrogate trained "
                f"for circuit {sur.circuit!r}")
        c = len(candidates)
        v_dd = jnp.asarray(candidates.v_dd, jnp.float32)
        tile = jnp.asarray(candidates.tile, jnp.int32)
        t0 = time.time()
        if compiled:
            prog, _ = self._compiled_tile_eval(sur, c)
            e_tile, l_tile = jax.block_until_ready(prog(sur, v_dd, tile))
        else:
            e_tile, l_tile = jax.block_until_ready(
                self._tile_eval(sur, v_dd, tile))
        wall = time.time() - t0
        e_tile = np.asarray(e_tile, np.float64)
        l_tile = np.asarray(l_tile, np.float64)

        tt = _tile_table(candidates)
        return DSEReport(
            candidates=candidates,
            n_tiles=tt["n_tiles"],
            analog_params=tt["analog_params"],
            total_params=tt["total_params"],
            analog_flop_fraction=tt["analog_flop_fraction"],
            energy_per_token_j=tt["tiles_token"] * e_tile,
            latency_critical_ns=tt["stages"] * l_tile,
            tile_energy_j=e_tile,
            tile_latency_ns=l_tile,
            compile_count=self.compile_count,
            wall_seconds=wall,
        )


# one process-wide engine behind lasana.explore: sweeps share its program
# cache (and compile_count), mirroring the facade's network-engine cache
_DEFAULT_ENGINE: Optional[DSEEngine] = None


def dse_engine() -> DSEEngine:
    """The process-wide :class:`DSEEngine` serving ``lasana.explore``."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = DSEEngine()
    return _DEFAULT_ENGINE


def evaluate_candidates(candidates: CandidateSpec, surrogates,
                        *, engine: Optional[DSEEngine] = None) -> DSEReport:
    """Vectorized sweep: price ``candidates`` with the shared engine.

    The functional core of ``lasana.explore`` — see :class:`DSEEngine`
    for the compile-once contract and :class:`DSEReport` for the output
    table/Pareto API."""
    return (engine or dse_engine()).evaluate(candidates, surrogates)
