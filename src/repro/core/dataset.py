"""Automated dataset generation (paper §IV-A): randomized testbenches ->
golden transient simulation -> event processing -> circuit dataset.

The "SPICE farm" is a ``vmap`` over runs of the golden integrator under
``jit`` (and ``shard_map`` over the mesh at scale); testbench generation
mirrors the paper: each timestep is active w.p. alpha (fresh random inputs)
or static (inputs hold / no spikes), circuit parameters are sampled uniformly
per run and stay fixed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import CrossbarRow, LIFNeuron, get_circuit
from repro.core.events import EventSet, Trace, extract_events, split_runwise


@dataclasses.dataclass(frozen=True)
class TestbenchConfig:
    n_runs: int = 1000
    n_steps: int = 125              # 500 ns at 250 MHz
    alpha: float = 0.8              # P(timestep is active)
    seed: int = 0


def generate_testbench(circuit, cfg: TestbenchConfig):
    """Random inputs + params for all runs. Returns (active, inputs, params)."""
    key = jax.random.PRNGKey(cfg.seed)
    k_act, k_in, k_p = jax.random.split(key, 3)
    active = jax.random.bernoulli(k_act, cfg.alpha,
                                  (cfg.n_runs, cfg.n_steps))
    active = active.at[:, 0].set(True)            # first step always drives
    fresh = circuit.sample_inputs(k_in, (cfg.n_runs, cfg.n_steps))
    params = circuit.sample_params(k_p, cfg.n_runs)

    is_lif = isinstance(circuit, LIFNeuron)

    def hold_scan(prev, xs):
        a, x = xs
        if is_lif:
            cur = jnp.where(a[..., None], x, jnp.zeros_like(x))  # no spikes when idle
            return prev, cur
        cur = jnp.where(a[..., None], x, prev)                   # hold voltages
        return cur, cur

    _, inputs = jax.lax.scan(
        hold_scan, fresh[:, 0],
        (jnp.moveaxis(active, 1, 0), jnp.moveaxis(fresh, 1, 0)))
    inputs = jnp.moveaxis(inputs, 0, 1)            # (R, T, n_in)
    return active, inputs, params


def simulate_golden(circuit, active, inputs, params):
    """Golden transient sim of all runs. Returns host-side Trace."""
    circuit = get_circuit(circuit)
    n_runs = inputs.shape[0]

    def run_one(state0, xs_run, p_run):
        def step(state, x_t):
            new_state, obs = circuit.step(state[None], x_t[None], p_run[None])
            return new_state[0], (new_state[0], obs)
        return jax.lax.scan(step, state0, xs_run)

    @jax.jit
    def run_all(active, inputs, params):
        state0 = circuit.init_state(n_runs)

        def step(state, xs):
            x_t = xs
            new_state, obs = circuit.step(state, x_t, params)
            return new_state, (obs, new_state)

        final, (obs, states) = jax.lax.scan(
            step, state0, jnp.moveaxis(inputs, 1, 0))
        return obs, states

    obs, states = run_all(active, inputs, params)
    # exposed state: first state channel; boundary arrays include t=0
    st = np.asarray(states[..., 0])                     # (T, R)
    st = np.concatenate([np.zeros((1, n_runs), np.float32), st], axis=0).T
    out = np.asarray(obs["output"])                     # (T, R)
    out = np.concatenate([np.zeros((1, n_runs), np.float32), out], axis=0).T
    energy = np.asarray(obs["energy"]).T                # (R, T)
    latency = np.asarray(obs["latency"]).T
    spiked = np.asarray(obs["spiked"]).T

    if isinstance(circuit, LIFNeuron):
        out_changed = spiked
    else:
        out_changed = np.abs(out[:, 1:] - out[:, :-1]) > 0.02

    return Trace(
        active=np.asarray(active),
        inputs=np.asarray(inputs),
        state=st.astype(np.float32),
        output=out.astype(np.float32),
        energy=energy.astype(np.float64),
        latency=latency.astype(np.float32),
        out_changed=np.asarray(out_changed, bool),
        params=np.asarray(params, np.float32),
        clock_ns=circuit.clock_ns,
        idle_x_is_zero=isinstance(circuit, LIFNeuron),
    )


@dataclasses.dataclass
class CircuitDataset:
    circuit_name: str
    train: EventSet
    test: EventSet
    val: EventSet
    gen_seconds: float
    n_runs: int

    def counts(self) -> dict:
        from repro.core.events import EventKind
        full = EventSet.concat([self.train, self.test, self.val])
        return {k.name: int(np.sum(full.kind == int(k))) for k in EventKind}


def build_dataset(circuit_name: str, cfg: TestbenchConfig | None = None,
                  circuit=None) -> CircuitDataset:
    """End-to-end §IV-A flow: testbench -> golden sim -> events -> split."""
    circuit = get_circuit(circuit or circuit_name)
    if cfg is None:
        cfg = TestbenchConfig(
            n_runs=1000 if circuit_name == "crossbar" else 2000)
    t0 = time.time()
    active, inputs, params = generate_testbench(circuit, cfg)
    trace = simulate_golden(circuit, active, inputs, params)
    events = extract_events(trace)
    train, test, val = split_runwise(events, cfg.n_runs, seed=cfg.seed)
    return CircuitDataset(circuit_name=circuit_name, train=train, test=test,
                          val=val, gen_seconds=time.time() - t0,
                          n_runs=cfg.n_runs)
