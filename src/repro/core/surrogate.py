"""The deployable LASANA artifact: an immutable pytree of predictor arrays.

A :class:`Surrogate` is what the facade (``repro.lasana``) trains, persists,
and serves. It replaces the mutable :class:`~repro.core.predictors.
PredictorBank` at inference time: the five selected predictors are frozen
into flat arrays (one dict per predictor) plus a *static* :class:`Manifest`
(circuit kind, feature schema, per-predictor model family, unit scales,
format version). Because the arrays are pytree leaves and the manifest is
pytree aux data, a surrogate passes straight through ``jax.jit`` /
``shard_map`` **as a traced argument**:

  * one compiled simulation program serves any retrained surrogate whose
    manifest and array shapes match — swapping banks is a weight swap, not
    a recompile (see tests/test_facade.py);
  * predictor weights shard/donate like any other pytree of arrays.

Pytree layout (what ``jax.tree.leaves`` sees)::

    Surrogate
    ├─ aux:    Manifest(circuit, format_version, families, scales, features)
    └─ leaves: params["M_O"]["w0"], params["M_O"]["b0"], ...   # per family
               params["M_V"][...], params["M_ED"][...], ...

Per-family array schemas (mirrors ``models.SurrogateModel`` inference):

    mean    mu ()                       constant
    linear  w (F+1,), mu (F,), sd (F,)  standardized affine
    table   tx (R,F), ty (R,), mu, sd   1-nearest-neighbor
    gbdt    feat (T,N), thr (T,N), leaf (T,L), base ()   complete trees
    mlp     w0,b0,...  x_mu,x_sd (F,), y_mu,y_sd (1,)    MLP(100, 50)

Persistence is one ``.npz`` per surrogate: arrays keyed ``{pname}/{key}``
plus a JSON ``__manifest__`` carrying :data:`FORMAT_VERSION`; loading a
file with a different version raises (no silent misinterpretation of
arrays). :class:`SurrogateLibrary` maps circuit kinds to surrogates for
heterogeneous graphs and is itself a pytree.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import get_circuit

FORMAT_VERSION = 1


# --- static manifest ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Manifest:
    """Static (hashable) description of a :class:`Surrogate`.

    This is the pytree *aux data*: two surrogates with equal manifests and
    equal leaf shapes share one compiled program. Fields:

    circuit         registered circuit kind the predictors were trained for
    format_version  on-disk format tag (see :data:`FORMAT_VERSION`)
    families        ((predictor, model family), ...) sorted by predictor
    scales          ((predictor, training-unit scale), ...); predictions are
                    divided by the scale back into physical units (energies
                    are trained in femtojoules for conditioning)
    features        names of the raw feature columns every predictor sees
                    ("x0..", "v", "tau", "p0.."); transition-aware heads
                    append o_prev/o_new, and the circuit's derived
                    ``surrogate_features`` columns are appended at predict
                    time (identically to fit time)
    """

    circuit: str
    format_version: int
    families: tuple
    scales: tuple
    features: tuple

    def family_of(self, pname: str) -> str:
        """Model family serving predictor ``pname``."""
        return dict(self.families)[pname]

    def scale_of(self, pname: str) -> float:
        """Training-unit scale of predictor ``pname`` (1.0 = physical)."""
        return dict(self.scales)[pname]

    @property
    def predictors(self) -> tuple:
        """Predictor names carried by this surrogate, sorted."""
        return tuple(p for p, _ in self.families)


def _npz_path(path: str) -> str:
    """Normalize a surrogate artifact path to its on-disk ``.npz`` name.

    ``np.savez_compressed`` silently appends ``.npz`` to extension-less
    paths, so ``save("foo")`` used to write ``foo.npz`` while
    ``load("foo")`` looked for (and failed on) ``foo``. Both directions
    now resolve to the same file whether or not the caller spells the
    extension."""
    return path if path.endswith(".npz") else path + ".npz"


def _feature_names(circuit_name: str) -> tuple:
    try:
        circ = get_circuit(circuit_name)
    except KeyError:
        return ()
    return (tuple(f"x{i}" for i in range(circ.n_inputs)) + ("v", "tau")
            + tuple(f"p{i}" for i in range(circ.n_params)))


# --- per-family inference (pure functions of (arrays, features)) ---------------

def _predict_mean(a, x):
    return jnp.broadcast_to(jnp.asarray(a["mu"], jnp.float32).reshape(()),
                            (x.shape[0],))


def _predict_linear(a, x):
    xs = (x - a["mu"]) / a["sd"]
    return xs @ a["w"][:-1] + a["w"][-1]


def _predict_table(a, x):
    xs = (x - a["mu"]) / a["sd"]
    tx = a["tx"]
    d = jnp.sum(jnp.square(tx), -1)[None, :] - 2.0 * (xs @ tx.T)
    return a["ty"][jnp.argmin(d, axis=1)]


def _predict_gbdt(a, x):
    feat, thr, leaf = a["feat"], a["thr"], a["leaf"]
    max_depth = int(np.log2(feat.shape[1] + 1))        # nodes = 2^d - 1
    n_t = feat.shape[0]
    tree_ix = jnp.arange(n_t)[None, :]
    node = jnp.zeros((x.shape[0], n_t), jnp.int32)
    for _ in range(max_depth):
        nf = feat[tree_ix, node]
        th = thr[tree_ix, node]
        xv = jnp.take_along_axis(x, nf, axis=1)
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)
    leaf_idx = node - (2 ** max_depth - 1)
    return a["base"] + jnp.sum(leaf[tree_ix, leaf_idx], axis=-1)


def _predict_mlp(a, x):
    h = (x - a["x_mu"]) / a["x_sd"]
    n_layers = sum(1 for k in a if k.startswith("w"))
    for i in range(n_layers):
        h = h @ a[f"w{i}"] + a[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h[..., 0] * a["y_sd"][0] + a["y_mu"][0]


FAMILY_PREDICT = {
    "mean": _predict_mean,
    "linear": _predict_linear,
    "table": _predict_table,
    "gbdt": _predict_gbdt,
    "mlp": _predict_mlp,
}


# --- stacked (multi-head) family inference --------------------------------------
#
# The fused hot path (Surrogate.predict_heads) evaluates every same-family
# head that shares one feature matrix in ONE batched pass: per-head arrays
# stack along a new leading P axis AT TRACE TIME (pytree leaves are
# untouched, so the artifact format and the compiled-program cache keys
# stay exactly as before — XLA hoists the loop-invariant stacks out of the
# tick scan). Batched dots reassociate reductions, so stacked results may
# differ from the per-head functions by a few ULPs (documented tolerance:
# rtol 1e-5); single-head groups bypass stacking and stay bit-identical.

def _stack_arrays(heads) -> dict:
    """[{k: (..)}] x P -> {k: (P, ..)} — trace-time leaf stacking."""
    return {k: jnp.stack([a[k] for a in heads]) for k in heads[0]}


def _predict_mean_stacked(heads, x):
    mus = jnp.stack([jnp.asarray(a["mu"], jnp.float32).reshape(())
                     for a in heads])
    return jnp.broadcast_to(mus[:, None], (len(heads), x.shape[0]))


def _predict_linear_stacked(heads, x):
    s = _stack_arrays(heads)
    xs = (x[None] - s["mu"][:, None]) / s["sd"][:, None]
    return jnp.einsum("pnf,pf->pn", xs, s["w"][:, :-1]) + s["w"][:, -1:]


def _predict_table_stacked(heads, x):
    s = _stack_arrays(heads)
    xs = (x[None] - s["mu"][:, None]) / s["sd"][:, None]
    d = jnp.sum(jnp.square(s["tx"]), -1)[:, None, :] \
        - 2.0 * jnp.einsum("pnf,prf->pnr", xs, s["tx"])
    return jnp.take_along_axis(s["ty"], jnp.argmin(d, axis=2), axis=1)


def _predict_mlp_stacked(heads, x, fused_kernel=None):
    s = _stack_arrays(heads)
    n_layers = sum(1 for k in heads[0] if k.startswith("w"))
    if n_layers == 3 and _kernel_heads_enabled(fused_kernel):
        # production MLP(100, 50) config on the Pallas multi-head kernel:
        # all P heads' weights stay resident in VMEM, grid over N-blocks
        from repro.kernels import ops
        return ops.mlp_surrogate_heads(
            x, s["x_mu"], s["x_sd"], s["y_mu"], s["y_sd"],
            s["w0"], s["b0"], s["w1"], s["b1"], s["w2"], s["b2"])
    h = (x[None] - s["x_mu"][:, None]) / s["x_sd"][:, None]
    for i in range(n_layers):
        h = jnp.einsum("pnf,pfh->pnh", h, s[f"w{i}"]) + s[f"b{i}"][:, None]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h[..., 0] * s["y_sd"][:, :1] + s["y_mu"][:, :1]


FAMILY_PREDICT_STACKED = {
    "mean": _predict_mean_stacked,
    "linear": _predict_linear_stacked,
    "table": _predict_table_stacked,
    "mlp": _predict_mlp_stacked,
    # gbdt: per-head traversal only (tree tables rarely share shapes and
    # the gather-heavy walk gains nothing from a batch axis); it still
    # shares the once-built augmented features with every other family.
}


def _kernel_heads_enabled(override=None) -> bool:
    """Dispatch stacked MLP heads to the fused Pallas multi-head kernel.

    Off by default: the einsum path compiles to the same batched dots on
    every backend, while the kernel path (REPRO_FUSED_KERNEL=1, or an
    explicit ``fused_kernel=`` override — see
    ``ops.fused_kernel_enabled``, the single source of truth for the
    flag) keeps all heads' weights resident in VMEM and grids only over
    N-blocks — the layout built for real TPUs
    (kernels/mlp_surrogate.py)."""
    from repro.kernels import ops
    return ops.fused_kernel_enabled(override)


# the Algorithm-1 head schedule: which predictors read which of the three
# per-tick feature variants (wrapper.lasana_step builds exactly these)
ALG1_HEADS = {
    "idle": ("M_ES", "M_V"),
    "act": ("M_O", "M_V", "M_ES"),
    "tr": ("M_ED", "M_L"),
}


def _model_arrays(model) -> tuple:
    """Freeze a fitted ``models.SurrogateModel`` -> (family, arrays dict).

    Only inference state is kept (e.g. the GBDT's training-time bin edges
    are dropped); every entry is an array so the whole predictor is pytree
    leaves."""
    from repro.core.models import (GBDTModel, LinearModel, MLPModel,
                                   MeanModel, TableModel)
    if isinstance(model, MeanModel):
        return "mean", {"mu": np.float32(model.mu)}
    if isinstance(model, LinearModel):
        return "linear", {"w": model.w, "mu": model.sx.mu, "sd": model.sx.sd}
    if isinstance(model, TableModel):
        return "table", {"tx": model.tx, "ty": model.ty,
                         "mu": model.sx.mu, "sd": model.sx.sd}
    if isinstance(model, GBDTModel):
        return "gbdt", {"feat": model.feat, "thr": model.thr,
                        "leaf": model.leaf, "base": np.float32(model.base)}
    if isinstance(model, MLPModel):
        arrays = {}
        for i, lyr in enumerate(model.params):
            arrays[f"w{i}"] = np.asarray(lyr["w"])
            arrays[f"b{i}"] = np.asarray(lyr["b"])
        arrays.update({"x_mu": model.sx.mu, "x_sd": model.sx.sd,
                       "y_mu": model.sy.mu, "y_sd": model.sy.sd})
        return "mlp", arrays
    raise TypeError(f"cannot freeze {type(model).__name__} into a Surrogate")


def _augment(circuit_name: str, feats):
    """Append the circuit's derived interface features — the SAME
    ``circuits.augment_features`` call ``PredictorBank`` applies at fit
    time, so fit and serving can never drift apart."""
    from repro.core.circuits import augment_features
    try:
        circ = get_circuit(circuit_name)
    except KeyError:
        circ = None
    return augment_features(circ, feats)


# --- the artifact ---------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False, repr=False)
class Surrogate:
    """Immutable inference artifact: selected-predictor arrays + manifest.

    Treat instances as frozen — mutating ``params`` in place invalidates
    jit caches keyed on leaf identity. Build one with
    :meth:`from_bank` (or ``repro.lasana.train``), persist with
    :meth:`save` / :meth:`load`, and pass it *as an argument* through
    jitted simulation entry points (``lasana.simulate``,
    ``wrapper.lasana_step``, ``distributed.make_distributed_step``).

    ``fit_info`` carries optional training metrics (per-predictor val/test
    MSE); it is not a pytree leaf and not part of the compiled-program
    cache key, but it is persisted in the manifest JSON.
    """

    manifest: Manifest
    params: dict
    fit_info: Optional[dict] = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        """Leaves: the predictor arrays dict. Aux: the static manifest."""
        return (self.params,), self.manifest

    @classmethod
    def tree_unflatten(cls, manifest, children):
        """Rebuild from (manifest, (params,)); fit_info does not survive."""
        return cls(manifest=manifest, params=children[0])

    # -- construction -------------------------------------------------------
    @classmethod
    def from_bank(cls, bank) -> "Surrogate":
        """Freeze a fitted ``PredictorBank``'s selected models.

        Array shapes (and thus the compiled-program cache key) depend only
        on the selected family and its fitted dimensions, not on the
        training data."""
        families, scales, params = [], [], {}
        for pname in sorted(bank.selected):
            fam, arrays = _model_arrays(bank.selected[pname])
            families.append((pname, fam))
            scales.append((pname, float(bank.scales[pname])))
            params[pname] = {k: jnp.asarray(v) for k, v in arrays.items()}
        fit_info = None
        if bank.results:
            fit_info = {
                p: {f: {"val_mse": r.val_mse, "test_mse": r.test_mse,
                        "test_mape": r.test_mape}
                    for f, r in fams.items()}
                for p, fams in bank.results.items()}
        manifest = Manifest(
            circuit=bank.circuit_name, format_version=FORMAT_VERSION,
            families=tuple(families), scales=tuple(scales),
            features=_feature_names(bank.circuit_name))
        return cls(manifest=manifest, params=params, fit_info=fit_info)

    # -- inference ----------------------------------------------------------
    @property
    def circuit(self) -> str:
        """Registered circuit kind this surrogate was trained for."""
        return self.manifest.circuit

    def predict(self, pname: str, feats):
        """JAX prediction in physical units (energies back to joules).

        ``feats`` are raw ``(x, v, tau, params[, o_prev, o_new])`` rows;
        the circuit's derived interface features are appended here. Pure in
        the pytree leaves — traceable with ``self`` as a jit argument."""
        from repro.kernels import ops
        ops.record_dispatch("predict")
        feats = _augment(self.manifest.circuit, jnp.asarray(feats))
        y = FAMILY_PREDICT[self.manifest.family_of(pname)](
            self.params[pname], feats)
        return y / self.manifest.scale_of(pname)

    def predict_heads(self, feats_idle=None, feats_act=None, feats_tr=None,
                      *, heads=None, augmented: bool = False,
                      fused_kernel=None) -> dict:
        """Fused multi-head inference: one feature build + one batched pass
        per (variant, family) group, instead of one :meth:`predict`
        dispatch per head.

        This is Algorithm 1's hot path (see docs/architecture.md,
        "Inference hot path"): per digital tick the wrapper evaluates up
        to seven predictor heads over three feature variants —

        feats_idle  ``(N, F)`` merged-E2 catch-up rows (zero inputs,
                    stale state, idle tau)
        feats_act   ``(N, F)`` active-event rows (inputs at t, caught-up
                    state, one-clock tau)
        feats_tr    ``(N, F+2)`` transition rows (``feats_act`` plus
                    ``o_prev``/``o_new`` columns) for the
                    transition-aware M_ED/M_L heads

        Any subset may be passed. Each given matrix is augmented with the
        circuit's derived features ONCE (pass ``augmented=True`` when the
        caller already augmented them — e.g. the wrapper builds the
        transition matrix as a column splice of the augmented active one).

        ``heads`` maps variant name -> predictor tuple and defaults to the
        full Algorithm-1 schedule (:data:`ALG1_HEADS`) restricted to this
        surrogate's predictors. Same-family heads whose arrays share
        shapes are stacked along a new leading axis at trace time and
        evaluated in one batched pass (``gbdt`` always walks per head);
        stacking reorders float reductions, so batched results may differ
        from :meth:`predict` by a few ULPs (documented tolerance:
        ``rtol=1e-5``; single-head groups are bit-identical). Caveat for
        discontinuous families: a stacked ``table`` head whose query row
        sits within rounding distance of TWO table rows may resolve the
        nearest-neighbor argmin to the other, equally-near row — the
        deviation is then the gap between those two table entries, not
        ULPs (measure-zero for continuous features, but the rtol contract
        is per-distance, not per-output, at exact ties). Pure in the
        pytree leaves — traceable with ``self`` as a jit argument, and the
        stacks are built from existing leaves so compiled-program cache
        keys (manifest + leaf shapes) are unchanged.

        Returns ``{variant: {pname: (N,) predictions}}`` in physical
        units.
        """
        from repro.kernels import ops
        ops.record_dispatch("predict_heads")
        mats = {"idle": feats_idle, "act": feats_act, "tr": feats_tr}
        mats = {v: jnp.asarray(m) for v, m in mats.items() if m is not None}
        if not mats:
            raise ValueError("predict_heads needs at least one of "
                             "feats_idle / feats_act / feats_tr")
        avail = set(self.manifest.predictors)
        if heads is None:
            heads = {v: tuple(p for p in ALG1_HEADS[v] if p in avail)
                     for v in mats}
        unknown = [(v, p) for v, ps in heads.items() for p in ps
                   if p not in avail]
        if unknown:
            raise ValueError(f"predict_heads: unknown predictor(s) "
                             f"{unknown}; this surrogate carries "
                             f"{sorted(avail)}")
        missing = [v for v in heads if v not in mats]
        if missing:
            raise ValueError(f"predict_heads: heads requested for variant"
                             f"(s) {missing} but no matching feature "
                             "matrix was given")
        if not augmented:
            mats = {v: _augment(self.manifest.circuit, m)
                    for v, m in mats.items()}

        # group same-family heads per matrix; stack only when every array
        # shape matches (mismatched shapes — e.g. per-predictor table row
        # counts — fall back to the exact per-head functions)
        groups: dict = {}
        for v, pnames in heads.items():
            for p in pnames:
                fam = self.manifest.family_of(p)
                if fam in FAMILY_PREDICT_STACKED:
                    sig = tuple(sorted((k, tuple(a.shape))
                                       for k, a in self.params[p].items()))
                    key = (v, fam, sig)
                else:
                    key = (v, fam, p)
                groups.setdefault(key, []).append(p)

        out: dict = {v: {} for v in heads}
        for (v, fam, _), pnames in groups.items():
            x = mats[v]
            if len(pnames) == 1 or fam not in FAMILY_PREDICT_STACKED:
                for p in pnames:
                    out[v][p] = FAMILY_PREDICT[fam](self.params[p], x) \
                        / self.manifest.scale_of(p)
            else:
                fn = FAMILY_PREDICT_STACKED[fam]
                if fam == "mlp":
                    # only the MLP family has a Pallas kernel path; thread
                    # the explicit override so tests/callers can pick the
                    # path without env mutation (ops.fused_kernel_enabled)
                    ys = fn([self.params[p] for p in pnames], x,
                            fused_kernel=fused_kernel)
                else:
                    ys = fn([self.params[p] for p in pnames], x)
                for i, p in enumerate(pnames):
                    out[v][p] = ys[i] / self.manifest.scale_of(p)
        return out

    def predict_np(self, pname: str, feats) -> np.ndarray:
        """Host-side convenience wrapper around :meth:`predict`."""
        return np.asarray(self.predict(pname, np.asarray(feats)))

    def __repr__(self):
        fams = ", ".join(f"{p}:{f}" for p, f in self.manifest.families)
        return f"Surrogate({self.manifest.circuit!r}, {fams})"

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        """Write one versioned ``.npz``: arrays + JSON ``__manifest__``.

        ``path`` may omit the ``.npz`` extension; it is normalized so the
        :meth:`load` round trip works either way."""
        path = _npz_path(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        arrays = {f"{p}/{k}": np.asarray(v)
                  for p, d in self.params.items() for k, v in d.items()}
        manifest = {
            "format_version": self.manifest.format_version,
            "circuit": self.manifest.circuit,
            "families": dict(self.manifest.families),
            "scales": dict(self.manifest.scales),
            "features": list(self.manifest.features),
            "fit_info": self.fit_info,
        }
        arrays["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path: str) -> "Surrogate":
        """Load a surrogate saved by :meth:`save`.

        ``path`` may omit the ``.npz`` extension (mirroring :meth:`save`).
        Raises ``FileNotFoundError`` naming every path tried when neither
        spelling exists (``np.load`` used to leak a raw error naming only
        the post-normalization path). Raises ``ValueError`` if the file's
        format version differs from :data:`FORMAT_VERSION` — array
        schemas are version-specific, so a mismatched file must be
        regenerated, never reinterpreted."""
        if not os.path.isfile(path):
            alt = _npz_path(path)
            if alt == path or not os.path.isfile(alt):
                tried = sorted({path, alt})
                raise FileNotFoundError(
                    "no surrogate artifact at "
                    + " or ".join(repr(p) for p in tried)
                    + " (expected an .npz written by Surrogate.save)")
            path = alt
        with np.load(path) as z:
            if "__manifest__" not in z.files:
                raise ValueError(f"{path}: not a Surrogate artifact "
                                 "(missing __manifest__)")
            meta = json.loads(bytes(z["__manifest__"].tobytes()).decode())
            version = meta.get("format_version")
            if version != FORMAT_VERSION:
                raise ValueError(
                    f"{path}: surrogate format version {version!r} is not "
                    f"supported (this build reads version {FORMAT_VERSION}); "
                    "regenerate the artifact with Surrogate.save")
            params = {}
            for pname in meta["families"]:
                params[pname] = {
                    k.split("/", 1)[1]: jnp.asarray(z[k]) for k in z.files
                    if k.startswith(pname + "/")}
        manifest = Manifest(
            circuit=meta["circuit"], format_version=version,
            families=tuple(sorted(meta["families"].items())),
            scales=tuple(sorted(meta["scales"].items())),
            features=tuple(meta.get("features", ())))
        return cls(manifest=manifest, params=params,
                   fit_info=meta.get("fit_info"))


def structure_key(surrogates) -> tuple:
    """Hashable structure key of a surrogate pytree (or library of them).

    ``(treedef, ((leaf shape, dtype), ...))`` — two artifacts with equal
    keys are weight swaps of one another and may share a compiled
    program; anything else (different family mix, different fitted
    dimensions) must compile its own. This is THE cache-key convention
    for every compiled surrogate-serving program (``NetworkEngine``
    network programs, the DSE sweep evaluator), so the zero-recompile
    hot-swap contract cannot drift between engines."""
    leaves, treedef = jax.tree.flatten(surrogates)
    return treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves)


def as_surrogate(obj) -> Surrogate:
    """Coerce a legacy ``PredictorBank`` (or pass through a Surrogate)."""
    if isinstance(obj, Surrogate):
        return obj
    from repro.core.predictors import PredictorBank
    if isinstance(obj, PredictorBank):
        return Surrogate.from_bank(obj)
    raise ValueError(
        f"cannot use {type(obj).__name__!r} as a surrogate; pass a "
        "repro.lasana.Surrogate (or a legacy fitted PredictorBank)")


# --- per-circuit-kind library ---------------------------------------------------

@jax.tree_util.register_pytree_node_class
class SurrogateLibrary:
    """Circuit kind -> :class:`Surrogate` mapping for heterogeneous graphs.

    Itself a pytree (kinds are aux data, surrogates are subtrees), so a
    whole library passes through jitted simulation programs as one traced
    argument — mixed crossbar/LIF graphs stop sharing a single ``bank=``.
    """

    def __init__(self, surrogates=()):
        self._by_kind = dict(surrogates)
        for kind, s in self._by_kind.items():
            if isinstance(s, Surrogate) and s.circuit != kind:
                raise ValueError(
                    f"surrogate trained for circuit {s.circuit!r} registered "
                    f"under kind {kind!r}")

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        """Leaves: the surrogates (sorted by kind). Aux: the kind names."""
        kinds = tuple(sorted(self._by_kind))
        return tuple(self._by_kind[k] for k in kinds), kinds

    @classmethod
    def tree_unflatten(cls, kinds, surrogates):
        """Rebuild the mapping from sorted kinds + surrogate subtrees."""
        lib = cls.__new__(cls)          # skip kind validation on tracers
        lib._by_kind = dict(zip(kinds, surrogates))
        return lib

    # -- mapping surface ----------------------------------------------------
    def __getitem__(self, kind: str) -> Surrogate:
        return self._by_kind[kind]

    def get(self, kind: str, default=None):
        """Surrogate registered for ``kind``, or ``default``."""
        return self._by_kind.get(kind, default)

    def __contains__(self, kind: str) -> bool:
        return kind in self._by_kind

    def __len__(self) -> int:
        return len(self._by_kind)

    def kinds(self) -> tuple:
        """Registered circuit kinds, sorted."""
        return tuple(sorted(self._by_kind))

    def items(self):
        """(kind, surrogate) pairs, sorted by kind."""
        return tuple((k, self._by_kind[k]) for k in sorted(self._by_kind))

    def __repr__(self):
        return f"SurrogateLibrary({', '.join(self.kinds()) or 'empty'})"

    # -- persistence --------------------------------------------------------
    def save(self, directory: str) -> None:
        """Write one ``{kind}.npz`` per surrogate into ``directory``."""
        os.makedirs(directory, exist_ok=True)
        for kind, s in self._by_kind.items():
            s.save(os.path.join(directory, f"{kind}.npz"))

    @classmethod
    def load(cls, directory: str) -> "SurrogateLibrary":
        """Load every ``*.npz`` in ``directory`` saved by :meth:`save`."""
        lib = {}
        for name in sorted(os.listdir(directory)):
            if name.endswith(".npz"):
                lib[name[:-4]] = Surrogate.load(os.path.join(directory, name))
        return cls(lib)
