"""Golden transient circuit models — the SPICE stand-in (see DESIGN.md §1).

Each circuit is a dataclass of physical constants exposing:

  - ``n_inputs`` / ``n_params``: feature dimensions for the surrogate models
  - ``init_state(n)``: initial internal state
  - ``derivs(state, v_in, params)``: continuous dynamics (sub-step integrator)
  - ``step(state, v_in, params)``: integrate ONE digital clock period with
    ``n_substeps`` exponential-Euler sub-steps under ``lax.scan``; returns the
    new state plus per-period observables (output, energy integral, latency
    markers) — everything the event processor needs.

Both models are calibrated so headline magnitudes land where the paper's do:
crossbar latency clusters near 0.45 ns with fJ-scale dynamic energy;
the LIF neuron fires on ~ns latency with pJ-scale dynamic energy and
state/output in [0, 1.5] V.

``step`` is pure JAX: ``vmap`` over circuit instances and ``shard_map`` over
the mesh turn this into the "SPICE farm" used for dataset generation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CrossbarRow:
    """One n-input differential PCM crossbar row driving a TIA (cf. [3]).

    inputs  x[i] in [-0.8, 0.8] V
    params  w[i] in {-1, 0, 1} (n weights + 1 bias row)
    state   none (combinational + output pole); state feature is 0
    output  V_out in [-2, 2] V
    """

    n_inputs: int = 32
    clock_ns: float = 4.0            # 250 MHz digital clock
    n_substeps: int = 64
    g_unit: float = 12e-6            # PCM on-conductance per pair (S)
    g_leak: float = 1e-6             # parasitic leak per column (S)
    r_f: float = 40e3                # TIA feedback (ohm)
    v_sat: float = 2.0               # output saturation (V)
    c_load: float = 500e-15          # load capacitance (F)
    tau_base_ns: float = 0.15        # output pole (ns); t90 ~ 2.3*tau
    v_bias: float = 0.8              # bias row drive voltage
    vdd: float = 1.2                 # supply for the TIA stage

    @property
    def n_params(self) -> int:
        return self.n_inputs + 1

    @property
    def input_lo(self):
        return -0.8

    @property
    def input_hi(self):
        return 0.8

    def sample_params(self, key, n):
        return jax.random.randint(
            key, (n, self.n_params), -1, 2).astype(jnp.float32)

    def sample_inputs(self, key, shape):
        """Mixture testbench: 70% uniform analog levels, 30% full-swing
        "digital" patterns ({-0.8, 0, 0.8}) — covers both the generic analog
        regime and the binary/ternary DAC patterns accelerators actually
        drive (paper §IV-A1 tailors input ranges per application)."""
        ku, kb, km, kd = jax.random.split(key, 4)
        uni = jax.random.uniform(ku, (*shape, self.n_inputs), jnp.float32,
                                 self.input_lo, self.input_hi)
        lvl = jax.random.randint(kd, (*shape, self.n_inputs), -1, 2)
        dig = lvl.astype(jnp.float32) * self.input_hi
        is_dig = jax.random.bernoulli(km, 0.3, (*shape, 1))
        return jnp.where(is_dig, dig, uni)

    def init_state(self, n: int):
        return jnp.zeros((n, 1), jnp.float32)   # V_out is the only memory

    def surrogate_features(self, x, params):
        """Physics-informed derived interface feature: the aggregate row
        current drive (w . x + bias * v_bias), the only path through which
        inputs enter the DC solution. Still strictly an interface signal —
        it is computed from x and the fixed row weights, both of which the
        wrapper already has — but it turns the surrogate's 32-way bilinear
        learning problem into a nearly 1-D regression (M_O test MSE drops
        ~200x with it; see docs/adding_a_circuit.md)."""
        w = params[..., : self.n_inputs]
        bias = params[..., self.n_inputs]
        i_sig = (w * x).sum(axis=-1) + bias * self.v_bias
        return i_sig[..., None]

    def _target(self, v_in, params):
        w = params[..., : self.n_inputs]
        bias = params[..., self.n_inputs]
        i_sig = self.g_unit * (jnp.sum(w * v_in, axis=-1) + bias * self.v_bias)
        v_lin = -self.r_f * i_sig
        # weight-dependent pole: heavier rows are slower (more BL capacitance)
        load = jnp.mean(jnp.abs(w), axis=-1)
        tau = self.tau_base_ns * (1.0 + 0.5 * load)
        return self.v_sat * jnp.tanh(v_lin / self.v_sat), tau

    def behavioral_step(self, v, v_in, params):
        """SV-RNM-style ideal update: instant settle to the DC target.

        v (N,) exposed state; v_in (N, n_in); params (N, n_p).
        Returns (v_new, output) — no energy/latency (needs ML annotation).
        """
        tgt, _ = self._target(v_in, params)
        return tgt, tgt

    def step(self, state, v_in, params):
        """One clock period. state: (N,1); v_in: (N,n_in); params: (N,n_p)."""
        v_out0 = state[..., 0]
        v_tgt, tau = self._target(v_in, params)
        dt = self.clock_ns / self.n_substeps

        w = params[..., : self.n_inputs]
        # resistive power: signal path + parasitic leak (W)
        g_row = jnp.abs(w) * self.g_unit + self.g_leak
        p_res = jnp.sum(jnp.square(v_in) * g_row, axis=-1)

        def sub(carry, i):
            v, energy, t90 = carry
            a = jnp.exp(-dt / tau)
            v_new = v_tgt + (v - v_tgt) * a
            # capacitor charging power + resistive
            p_cap = self.c_load * jnp.abs(v_new - v) / (dt * 1e-9) * jnp.abs(v_new)
            energy = energy + (p_cap + p_res) * dt * 1e-9
            # 90%% settling marker (first sub-step within 10%% of target)
            settled = jnp.abs(v_new - v_tgt) <= 0.1 * jnp.abs(v_tgt - v_out0) + 1e-6
            t_now = (i + 1) * dt
            t90 = jnp.where((t90 < 0) & settled, t_now, t90)
            return (v_new, energy, t90), None

        init = (v_out0, jnp.zeros_like(v_out0), -jnp.ones_like(v_out0))
        (v_end, energy, t90), _ = jax.lax.scan(
            sub, init, jnp.arange(self.n_substeps))
        t90 = jnp.where(t90 < 0, self.clock_ns, t90)
        obs = {
            "output": v_end,
            "energy": energy,                 # joules over the period
            "latency": t90,                   # ns to 90% settle
            "spiked": jnp.abs(v_end - v_out0) > 0.02,
        }
        return v_end[..., None], obs


@dataclasses.dataclass(frozen=True)
class LIFNeuron:
    """Adaptive leaky-integrate-and-fire neuron (cf. Indiveri [16]).

    inputs  x in [0, 1.5] V spike amplitude, n_spk in [0,5] spikes/period,
            w in [-1, 1] synapse weight -> drive = w * x * n_spk
    params  (V_leak, V_th, V_adap, V_refrac) in [0.5, 0.8] V
    state   (V_mem, I_adap, t_refrac) — V_mem is the exposed state feature
    output  pulse amplitude in {0, 1.5} V (V_dd spike)
    """

    n_inputs: int = 3                # (w, x_amplitude, n_spikes)
    clock_ns: float = 5.0            # 200 MHz digital clock
    n_substeps: int = 64
    vdd: float = 1.5
    c_mem: float = 250e-15           # membrane cap (F)
    g_syn: float = 260e-6            # synapse transconductance (S)
    i_leak0: float = 5e-6            # leak scale (A)
    ut: float = 0.13                 # leak-knob slope (V)
    c_spike: float = 900e-15         # switched cap per spike (F)
    g_static: float = 0.8e-6         # static bias path (S)

    @property
    def n_params(self) -> int:
        return 4

    def sample_params(self, key, n):
        return jax.random.uniform(key, (n, 4), jnp.float32, 0.5, 0.8)

    def sample_inputs(self, key, shape):
        """Mixture testbench: 70% independent (w, x, n) draws + 30%
        aggregated-drive patterns (x=V_dd, n=5, signed w) — the operating
        point SNN layers present after summing presynaptic spikes through
        a weight row (simulate.drive_to_circuit_inputs)."""
        kw, kx, kn, km, kd = jax.random.split(key, 5)
        w = jax.random.uniform(kw, shape, jnp.float32, -1.0, 1.0)
        x = jax.random.uniform(kx, shape, jnp.float32, 0.0, 1.5)
        n = jax.random.randint(kn, shape, 0, 6).astype(jnp.float32)
        uni = jnp.stack([w, x, n], axis=-1)
        w_agg = jax.random.uniform(kd, shape, jnp.float32, -1.0, 1.0)
        agg = jnp.stack([w_agg, jnp.full(shape, 1.5), jnp.full(shape, 5.0)],
                        axis=-1)
        is_agg = jax.random.bernoulli(km, 0.3, (*shape, 1))
        return jnp.where(is_agg, agg, uni)

    def init_state(self, n: int):
        return jnp.zeros((n, 3), jnp.float32)    # (V_mem, I_adap, t_ref)

    def surrogate_features(self, x, params):
        """Physics-informed derived interface feature: the aggregate
        synaptic drive w * x_amp * n_spikes / 5 (the same reduction the
        behavioral model applies), computed purely from interface inputs."""
        drive = x[..., 0] * x[..., 1] * x[..., 2] / 5.0
        return drive[..., None]

    def _thresh(self, params, i_adap):
        # V_th knob maps to an effective threshold plus adaptation raise
        v_th = 0.55 + 0.9 * (params[..., 1] - 0.5)          # 0.55..0.82 V... scaled below
        v_adapt_gain = 1.0 + 2.0 * (params[..., 2] - 0.5)
        return 0.9 * v_th / 0.55 * 0.55 + v_adapt_gain * i_adap * 0.25

    def behavioral_step(self, v, v_in, params):
        """SV-RNM-style ideal discrete LIF update for one clock period.

        v (N,) membrane voltage; v_in (N, 3) = (w, x_amp, n_spikes);
        params (N, 4). Returns (v_new, output in {0, V_dd}) — no
        energy/latency (those require the LASANA annotation pass). Idle
        neurons are driven with v_in = 0 (drive term vanishes, leak stays).
        """
        thresh = 0.8 + 1.0 * (params[:, 1] - 0.5)
        leak = jnp.exp(-(self.i_leak0 / self.c_mem) * jnp.exp(
            (params[:, 0] - 0.5) / self.ut) * 1e-9 * self.clock_ns)
        drive = (self.g_syn * v_in[:, 0] * v_in[:, 1] * v_in[:, 2] / 5.0
                 / self.c_mem * self.clock_ns * 1e-9)
        v_new = (v + drive) * leak
        fire = v_new >= thresh
        v_new = jnp.where(fire, 0.0, jnp.clip(v_new, 0.0, self.vdd))
        out = jnp.where(fire, self.vdd, 0.0)
        return v_new, out

    def step(self, state, v_in, params):
        """One clock period. state: (N,3); v_in: (N,3); params: (N,4)."""
        v0, adap0, ref0 = state[..., 0], state[..., 1], state[..., 2]
        w, x, n_spk = v_in[..., 0], v_in[..., 1], v_in[..., 2]
        dt = self.clock_ns / self.n_substeps

        i_in = self.g_syn * w * x * n_spk / 5.0              # amps, signed
        v_leak, v_th_knob, v_adap, v_ref = (params[..., 0], params[..., 1],
                                            params[..., 2], params[..., 3])
        leak_rate = (self.i_leak0 / self.c_mem) * jnp.exp(
            (v_leak - 0.5) / self.ut) * 1e-9                  # 1/ns scale
        tau_ref_ns = 2.0 + 10.0 * (v_ref - 0.5)               # 2..5 ns
        thresh = 0.8 + 1.0 * (v_th_knob - 0.5)                # 0.8..1.1 V
        adap_gain = 0.15 * (1.0 + 2.0 * (v_adap - 0.5))

        def sub(carry, i):
            v, adap, ref, out, energy, t_spk = carry
            in_ref = ref > 0.0
            dv = (i_in / self.c_mem) * 1e-9 * dt              # V per sub-step
            decay = jnp.exp(-leak_rate * dt)
            v_new = jnp.where(in_ref, 0.0, (v + dv) * decay)
            v_new = jnp.clip(v_new, 0.0, self.vdd)
            eff_th = thresh + adap * 1.0
            fire = (v_new >= eff_th) & (~in_ref)
            # spike: reset, enter refractory, bump adaptation
            v_new = jnp.where(fire, 0.0, v_new)
            ref_new = jnp.where(fire, tau_ref_ns, jnp.maximum(ref - dt, 0.0))
            adap_new = adap * jnp.exp(-dt / 8.0) + jnp.where(fire, adap_gain, 0.0)
            out_new = jnp.where(fire, self.vdd, out)
            t_now = (i + 1) * dt
            t_spk = jnp.where(fire & (t_spk < 0), t_now, t_spk)
            # energy: static bias + integration + spike switching
            p_static = self.g_static * jnp.square(v_leak + v_new * 0.3)
            e_sub = p_static * dt * 1e-9
            e_sub = e_sub + jnp.abs(i_in) * jnp.abs(v_new) * dt * 1e-9 * 0.5
            e_spk = jnp.where(fire, self.c_spike * self.vdd ** 2, 0.0)
            return (v_new, adap_new, ref_new, out_new, energy + e_sub + e_spk,
                    t_spk), None

        zeros = jnp.zeros_like(v0)
        init = (v0, adap0, ref0, zeros, zeros, -jnp.ones_like(v0))
        (v_end, adap_end, ref_end, out, energy, t_spk), _ = jax.lax.scan(
            sub, init, jnp.arange(self.n_substeps))
        spiked = t_spk > 0
        obs = {
            "output": out,                        # 0 or V_dd pulse
            "energy": energy,
            "latency": jnp.where(spiked, t_spk, self.clock_ns),
            "spiked": spiked,
        }
        return jnp.stack([v_end, adap_end, ref_end], axis=-1), obs


CIRCUITS = {"crossbar": CrossbarRow(), "lif": LIFNeuron()}


def get_circuit(name: str):
    if isinstance(name, str):
        return CIRCUITS[name]
    return name


def augment_features(circuit, feats):
    """Append ``circuit``'s derived interface features to raw feature rows.

    THE single implementation of the fit/predict feature-symmetry
    contract: ``PredictorBank`` applies it when fitting and
    ``Surrogate.predict`` when serving, so the two can never drift apart.
    ``circuit`` is an instance from :data:`CIRCUITS` (or None /
    featureless, in which case ``feats`` pass through untouched); rows are
    ``(x[:n_inputs], v, tau, params[:n_params], ...)`` and the derived
    columns are computed from the interface slices only."""
    import numpy as np
    if circuit is None:
        return feats
    fn = getattr(circuit, "surrogate_features", None)
    if fn is None:
        return feats
    n_in, n_p = circuit.n_inputs, circuit.n_params
    x = feats[:, :n_in]
    p = feats[:, n_in + 2: n_in + 2 + n_p]
    extra = fn(x, p)
    xp = np if isinstance(feats, np.ndarray) else jnp
    return xp.concatenate([feats, extra], axis=1)
