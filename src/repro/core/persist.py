"""Deprecated predictor-bank persistence shims.

The deployable artifact is now :class:`repro.core.surrogate.Surrogate`
(one versioned ``.npz`` of pytree leaves + a JSON manifest) — created by
``repro.lasana.train`` and persisted with ``Surrogate.save`` /
``Surrogate.load``. The old per-family ``isinstance`` chain that lived
here was replaced by the surrogate's pytree serialization.

:func:`save_bank` / :func:`load_bank` remain as thin shims: saving freezes
the bank into a surrogate first, and loading returns a :class:`Surrogate`
(drop-in at inference time — it exposes the same ``predict`` /
``predict_np`` surface the bank did). ``load_bank`` also still reads
artifacts written by the PRE-facade ``save_bank`` (manifest with a
``predictors`` key and no ``format_version``), migrating them to a
:class:`Surrogate` in memory — re-``save`` to upgrade the file on disk.
"""

from __future__ import annotations

import json
import warnings

import numpy as np

from repro.core.surrogate import (FORMAT_VERSION, Manifest, Surrogate,
                                  _feature_names, as_surrogate)


def save_bank(bank, path: str) -> None:
    """Deprecated: freeze ``bank`` into a Surrogate and save that."""
    warnings.warn("persist.save_bank is deprecated; use "
                  "Surrogate.from_bank(bank).save(path) (repro.lasana)",
                  DeprecationWarning, stacklevel=2)
    as_surrogate(bank).save(path)


def _load_legacy(z, meta: dict) -> Surrogate:
    """Migrate a pre-facade ``save_bank`` npz into a :class:`Surrogate`.

    The old manifest stored per-predictor family metadata under
    ``predictors`` and no unit scales (the old loader rebuilt them from
    ``PREDICTOR_DEFS``, which we mirror here); scalar model state (mean
    ``mu``, gbdt ``base``) lived in the manifest instead of the arrays.
    ``z`` is the already-open npz file."""
    import jax.numpy as jnp

    from repro.core.predictors import PREDICTOR_DEFS

    families, scales, params = [], [], {}
    for pname, m in sorted(meta["predictors"].items()):
        arrays = {k.split("/", 1)[1]: z[k] for k in z.files
                  if k.startswith(pname + "/")}
        if m["family"] == "mean":
            arrays = {"mu": np.float32(m["mu"])}
        elif m["family"] == "gbdt":
            arrays["base"] = np.float32(m["base"])
            arrays.pop("edges", None)              # training-only state
        families.append((pname, m["family"]))
        scales.append((pname, float(PREDICTOR_DEFS[pname]["scale"])))
        params[pname] = {k: jnp.asarray(v) for k, v in arrays.items()}
    manifest = Manifest(circuit=meta["circuit"],
                        format_version=FORMAT_VERSION,
                        families=tuple(families), scales=tuple(scales),
                        features=_feature_names(meta["circuit"]))
    return Surrogate(manifest=manifest, params=params)


def load_bank(path: str) -> Surrogate:
    """Deprecated: load the artifact at ``path`` as a :class:`Surrogate`.

    Reads both current-format surrogates and legacy ``save_bank`` files."""
    warnings.warn("persist.load_bank is deprecated; use "
                  "Surrogate.load(path) (repro.lasana)",
                  DeprecationWarning, stacklevel=2)
    with np.load(path) as z:
        meta = (json.loads(bytes(z["__manifest__"].tobytes()).decode())
                if "__manifest__" in z.files else {})
        if "predictors" in meta and "format_version" not in meta:
            return _load_legacy(z, meta)
    return Surrogate.load(path)
