"""Predictor-bank persistence.

A trained ``PredictorBank`` is LASANA's deployable artifact (the paper ships
C++ inference models; we ship the selected models' arrays). Format: one
``.npz`` per bank with a JSON manifest — loadable without retraining, e.g.
on the serving fleet that annotates a digital simulator.
"""

from __future__ import annotations

import io
import json
import os

import numpy as np

from repro.core.models import (GBDTModel, LinearModel, MLPModel, MeanModel,
                               Standardizer, TableModel)
from repro.core.predictors import PredictorBank


def _dump_model(m) -> dict:
    """-> (meta dict, arrays dict) folded together with 'arrays' keys."""
    if isinstance(m, MeanModel):
        return {"family": "mean", "mu": m.mu}
    if isinstance(m, LinearModel):
        return {"family": "linear",
                "arrays": {"w": m.w, "mu": m.sx.mu, "sd": m.sx.sd}}
    if isinstance(m, TableModel):
        return {"family": "table",
                "arrays": {"tx": m.tx, "ty": m.ty, "mu": m.sx.mu,
                           "sd": m.sx.sd}}
    if isinstance(m, GBDTModel):
        return {"family": "gbdt", "base": m.base, "max_depth": m.max_depth,
                "arrays": {"feat": m.feat, "thr": m.thr, "leaf": m.leaf,
                           "edges": m.edges}}
    if isinstance(m, MLPModel):
        arrays = {}
        for i, lyr in enumerate(m.params):
            arrays[f"w{i}"] = np.asarray(lyr["w"])
            arrays[f"b{i}"] = np.asarray(lyr["b"])
        arrays.update({"x_mu": m.sx.mu, "x_sd": m.sx.sd,
                       "y_mu": m.sy.mu, "y_sd": m.sy.sd})
        return {"family": "mlp", "n_layers": len(m.params), "arrays": arrays}
    raise TypeError(type(m))


def _load_model(meta: dict, arrays: dict):
    fam = meta["family"]
    if fam == "mean":
        m = MeanModel()
        m.mu = float(meta["mu"])
        return m
    if fam == "linear":
        m = LinearModel()
        m.w = arrays["w"]
        m.sx = Standardizer(arrays["mu"], arrays["sd"])
        return m
    if fam == "table":
        m = TableModel()
        m.tx, m.ty = arrays["tx"], arrays["ty"]
        m.sx = Standardizer(arrays["mu"], arrays["sd"])
        return m
    if fam == "gbdt":
        m = GBDTModel(max_depth=int(meta["max_depth"]))
        m.base = float(meta["base"])
        m.feat, m.thr, m.leaf = arrays["feat"], arrays["thr"], arrays["leaf"]
        m.edges = arrays["edges"]
        return m
    if fam == "mlp":
        m = MLPModel()
        m.params = [{"w": arrays[f"w{i}"], "b": arrays[f"b{i}"]}
                    for i in range(int(meta["n_layers"]))]
        m.sx = Standardizer(arrays["x_mu"], arrays["x_sd"])
        m.sy = Standardizer(arrays["y_mu"], arrays["y_sd"])
        return m
    raise ValueError(fam)


def save_bank(bank: PredictorBank, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    manifest = {"circuit": bank.circuit_name, "predictors": {}}
    arrays: dict[str, np.ndarray] = {}
    for pname, model in bank.selected.items():
        meta = _dump_model(model)
        arrs = meta.pop("arrays", {})
        manifest["predictors"][pname] = meta
        for k, v in arrs.items():
            arrays[f"{pname}/{k}"] = np.asarray(v)
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    np.savez_compressed(path, **arrays)


def load_bank(path: str) -> PredictorBank:
    with np.load(path) as z:
        manifest = json.loads(bytes(z["__manifest__"].tobytes()).decode())
        bank = PredictorBank(manifest["circuit"], families=())
        for pname, meta in manifest["predictors"].items():
            arrays = {k.split("/", 1)[1]: z[k] for k in z.files
                      if k.startswith(pname + "/")}
            bank.selected[pname] = _load_model(meta, arrays)
    return bank
