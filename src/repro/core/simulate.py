"""Event-driven simulation of single circuit banks (layer-level runners).

Three simulation backends over identical stimuli (the paper's comparison
set), unified at network level by :func:`repro.lasana.simulate`:

  golden      — sub-step ODE integration (the SPICE stand-in; slow, exact)
  behavioral  — SV-RNM-style ideal discrete update (fast, no energy/latency)
  lasana      — Algorithm 1 over a trained :class:`Surrogate`; standalone
                surrogate or annotation mode (energy/latency on top of the
                behavioral state), LASANA-P (predicted state feedback) or
                LASANA-O (oracle state from golden, for Table III)

All are (T, N)-vectorized and jit-compiled. The LASANA program takes the
surrogate as a *traced pytree argument*, so sweeping retrained surrogates
reuses one compiled program. Every runner reports compile and steady-state
wall time separately (``LayerRun.compile_seconds`` / ``wall_seconds``) —
benchmark numbers never include first-call compilation.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import LIFNeuron, get_circuit
from repro.core.surrogate import Surrogate, as_surrogate
from repro.core.wrapper import LasanaState, init_state, lasana_step


@dataclasses.dataclass
class LayerRun:
    """Per-tick record of one simulated bank of N circuits."""

    outputs: np.ndarray    # (T, N)
    states: np.ndarray     # (T, N)
    energy: np.ndarray     # (T, N) joules
    latency: np.ndarray    # (T, N) ns (0 when no output event)
    wall_seconds: float    # steady-state execution time (compile excluded)
    compile_seconds: float = 0.0   # trace+compile time (0 on cache hits)


def make_stimulus(circuit, n: int, t_steps: int, *, alpha=0.8, seed=0):
    """Random per-tick stimulus: (active (T,N), x (T,N,n_in), params (N,p))."""
    circuit = get_circuit(circuit)
    key = jax.random.PRNGKey(seed)
    ka, kx, kp = jax.random.split(key, 3)
    active = jax.random.bernoulli(ka, alpha, (t_steps, n))
    active = active.at[0].set(True)
    x = circuit.sample_inputs(kx, (t_steps, n))
    if not isinstance(circuit, LIFNeuron):
        # voltages hold between active ticks
        def hold(prev, ax):
            a, xi = ax
            cur = jnp.where(a[:, None], xi, prev)
            return cur, cur
        _, x = jax.lax.scan(hold, x[0], (active, x))
    else:
        x = jnp.where(active[..., None], x, 0.0)
    params = circuit.sample_params(kp, n)
    return active, x, params


def _timed_aot(jitted, *args):
    """AOT-compile a jitted closure, then execute: (out, compile_s, wall_s).

    The explicit ``lower().compile()`` warmup is what keeps compile time
    out of every benchmark's steady-state number."""
    t0 = time.time()
    compiled = jitted.lower(*args).compile()
    compile_s = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(compiled(*args))
    return out, compile_s, time.time() - t0


def _timed_cached(jitted, *args, **static):
    """Execute a module-level jitted fn, separating compile from steady.

    If this call populated the jit cache (first time this program shape is
    seen), the call is repeated once so the reported wall time is pure
    steady-state execution. ``_cache_size`` is private jax API; when a jax
    upgrade removes it we can no longer DETECT first-call compilation, so
    we must assume it and always re-time — never silently fold compile
    time into the steady-state number."""
    size = getattr(jitted, "_cache_size", None)
    n0 = size() if size else -1
    t0 = time.time()
    out = jax.block_until_ready(jitted(*args, **static))
    t_first = time.time() - t0
    if size is not None and size() == n0:     # provably a cache hit
        return out, 0.0, t_first
    t0 = time.time()
    out = jax.block_until_ready(jitted(*args, **static))
    wall = time.time() - t0
    return out, max(t_first - wall, 0.0), wall


# --- golden -------------------------------------------------------------------

def run_golden(circuit, active, x, params) -> LayerRun:
    circuit = get_circuit(circuit)
    n = params.shape[0]

    def sim(active, x, params):
        def step(state, xs):
            x_t = xs
            new_state, obs = circuit.step(state, x_t, params)
            return new_state, (obs["output"], new_state[..., 0],
                               obs["energy"], obs["latency"], obs["spiked"])
        _, out = jax.lax.scan(step, circuit.init_state(n), x)
        return out

    out, compile_s, wall = _timed_aot(jax.jit(sim), active, x, params)
    outputs, states, energy, latency, spiked = out
    lat = np.where(np.asarray(spiked), np.asarray(latency), 0.0)
    return LayerRun(outputs=np.asarray(outputs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=lat,
                    wall_seconds=wall, compile_seconds=compile_s)


# --- behavioral (SV-RNM stand-in) ------------------------------------------------

def run_behavioral(circuit, active, x, params) -> LayerRun:
    """Ideal discrete update; no energy/latency (requires ML annotation)."""
    circuit = get_circuit(circuit)
    n = params.shape[0]
    is_lif = isinstance(circuit, LIFNeuron)

    def sim(active, x, params):
        def step(v, xs):
            a, xi = xs
            if is_lif:                  # no drive on idle ticks, leak stays
                xi = jnp.where(a[:, None], xi, 0.0)
            v_new, out = circuit.behavioral_step(v, xi, params)
            return v_new, (out, v_new)

        _, (outs, states) = jax.lax.scan(step, jnp.zeros((n,)), (active, x))
        return outs, states

    (outs, states), compile_s, wall = _timed_aot(jax.jit(sim),
                                                 active, x, params)
    z = np.zeros_like(np.asarray(outs))
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=z, latency=z, wall_seconds=wall,
                    compile_seconds=compile_s)


# --- LASANA -----------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("clock", "spiking", "oracle", "annotate",
                                    "vdd", "fused", "fused_kernel",
                                    "tick_pallas"))
def _lasana_sim(surrogate, active, x, params, times, v_oracle, known_out, *,
                clock, spiking, oracle, annotate, vdd=1.5, fused=True,
                fused_kernel=False, tick_pallas=False):
    """Algorithm 1 over T ticks; ``surrogate`` is a traced pytree argument.

    One compiled program per (shapes, manifest, flags): sweeping retrained
    surrogates through this entry point never recompiles. ``fused``
    selects the fused ``predict_heads`` tick body (default) vs the
    per-``predict``-call baseline. ``fused_kernel`` is the RESOLVED
    fused-kernel switch (``ops.fused_kernel_enabled``), genuinely threaded
    into every tick — it engages the whole-tick megakernel when the
    surrogate is packable and doubles as the program cache key the old
    env-read-at-trace-time scheme needed. ``tick_pallas`` is cache-key
    only (``lasana_step`` resolves the launcher itself)."""
    state0 = init_state(params.shape[0], params)

    def step(state, xs):
        a, xi, t, v_o, k_o = xs
        if oracle or annotate:
            state = state._replace(v=v_o)
        new_state, e, l, o = lasana_step(surrogate, state, a, xi, t, clock,
                                         spiking=spiking, vdd=vdd,
                                         known_out=k_o if annotate else None,
                                         fused=fused,
                                         fused_kernel=fused_kernel)
        if annotate:
            # the behavioral model owns outputs AND state; LASANA only
            # annotates energy/latency (cf. the network engine's _lif_tick)
            new_state = new_state._replace(o=k_o)
            o = k_o
        return new_state, (o, new_state.v, e, l)

    _, out = jax.lax.scan(step, state0,
                          (active, x, times, v_oracle, known_out))
    return out


def run_lasana(surrogate, circuit, active, x, params, *,
               oracle_states: Optional[np.ndarray] = None,
               annotate_outputs: Optional[np.ndarray] = None,
               fused: bool = True,
               fused_kernel: Optional[bool] = None) -> LayerRun:
    """Algorithm 1 over T ticks.

    surrogate        — a trained :class:`Surrogate` (legacy ``PredictorBank``
                       values are frozen with ``Surrogate.from_bank``)
    oracle_states    — LASANA-O (Table III): feed golden state as v' each tick
    annotate_outputs — annotation mode: a behavioral model supplies outputs,
                       LASANA adds energy/latency estimates. The matching
                       behavioral states MUST be passed via
                       ``oracle_states`` (annotation has no staleness to
                       predict; running it at v=0 would silently corrupt
                       the energy/latency features, so that is an error).
    fused            — fused ``predict_heads`` tick body (default) vs the
                       per-``predict``-call baseline (A/B benchmarks).
    fused_kernel     — tri-state fused-kernel override (None defers to
                       ``REPRO_FUSED_KERNEL``; resolved once through
                       ``kernels.ops.fused_kernel_enabled``); when on,
                       packable surrogates take the whole-tick megakernel.
    """
    if annotate_outputs is not None and oracle_states is None:
        raise ValueError(
            "annotate_outputs requires the behavioral states as "
            "oracle_states= (annotation mode predicts energy/latency at "
            "the externally supplied state, not at v=0)")
    surrogate = as_surrogate(surrogate)
    circuit = get_circuit(circuit)
    n = params.shape[0]
    spiking = isinstance(circuit, LIFNeuron)
    clock = circuit.clock_ns
    t_steps = active.shape[0]
    times = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * clock

    oracle = oracle_states is not None
    annotate = annotate_outputs is not None
    if oracle:
        # state BEFORE tick t = golden state at boundary t (prepend 0)
        v_oracle = jnp.asarray(
            np.concatenate([np.zeros((1, n), np.float32),
                            oracle_states[:-1]], axis=0))
    else:
        v_oracle = jnp.zeros((t_steps, n), jnp.float32)
    known = (jnp.asarray(annotate_outputs, jnp.float32) if annotate
             else jnp.zeros((t_steps, n), jnp.float32))

    from repro.kernels import ops
    out, compile_s, wall = _timed_cached(
        _lasana_sim, surrogate, active, x, params, times, v_oracle, known,
        clock=clock, spiking=spiking, oracle=oracle, annotate=annotate,
        vdd=float(getattr(circuit, "vdd", 1.5)), fused=fused,
        fused_kernel=ops.fused_kernel_enabled(fused_kernel),
        tick_pallas=ops.tick_pallas_enabled())
    outs, states, energy, latency = out
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=np.asarray(latency),
                    wall_seconds=wall, compile_seconds=compile_s)


# --- SNN network (deprecation shims over the repro.lasana facade) -------------
#
# The hand-rolled per-layer loops that used to live here moved into the
# network-level engine (core/network.py), now fronted by repro.lasana.
# These wrappers keep the historical (counts, total_energy) signature for
# callers that don't need the full NetworkRun report.

def drive_to_circuit_inputs(drive, *, spike_amp: float = 1.5,
                            n_spk: float = 5.0):
    """Aggregate synaptic drive -> (w, x, n) circuit inputs (see DESIGN.md)."""
    from repro.core.network import drive_to_circuit_inputs as _impl
    return _impl(drive, spike_amp=spike_amp, n_spk=n_spk)


def run_snn_lasana(surrogate, weights: list, spike_seq, params_per_layer, *,
                   clock_ns=5.0, mode="standalone", edges=()):
    """Deprecated shim: feed-forward SNN via ``repro.lasana.simulate``.

    weights[i]: (n_in_i, n_out_i); ``edges`` are optional one-tick-delayed
    recurrent connections (network.EdgeSpec / network.recurrent_edge).
    Returns (spike counts (B, n_cls), total energy incl. the end-of-run
    idle flush). Prefer ``repro.lasana.simulate`` for new code.
    """
    import warnings

    import repro.lasana as lasana
    from repro.core.network import snn_spec
    warnings.warn("run_snn_lasana is deprecated; use repro.lasana."
                  "simulate(snn_spec(...), x, surrogates=...)",
                  DeprecationWarning, stacklevel=2)
    spec = snn_spec(weights, params_per_layer, edges=edges)
    run = lasana.simulate(spec, spike_seq, backend="lasana", mode=mode,
                          surrogates=as_surrogate(surrogate),
                          record_hidden=False)
    return run.outputs, run.energy.sum() + run.flush_energy.sum()


def run_snn_golden(circuit, weights: list, spike_seq, params_per_layer, *,
                   edges=()):
    """Deprecated shim: same network through the golden integrator.

    Prefer ``repro.lasana.simulate(spec, x, backend="golden")``."""
    import warnings

    import repro.lasana as lasana
    from repro.core.network import snn_spec
    warnings.warn("run_snn_golden is deprecated; use repro.lasana."
                  "simulate(snn_spec(...), x, backend='golden')",
                  DeprecationWarning, stacklevel=2)
    spec = snn_spec(weights, params_per_layer, edges=edges)
    run = lasana.simulate(spec, spike_seq, backend="golden",
                          record_hidden=False)
    return run.outputs, run.energy.sum()
