"""Event-driven simulation of circuit banks and spiking networks.

Three simulation backends over identical stimuli (the paper's comparison
set):

  golden      — sub-step ODE integration (the SPICE stand-in; slow, exact)
  behavioral  — SV-RNM-style ideal discrete update (fast, no energy/latency)
  lasana      — Algorithm 1 over the trained PredictorBank; standalone
                surrogate or annotation mode (energy/latency on top of the
                behavioral state), LASANA-P (predicted state feedback) or
                LASANA-O (oracle state from golden, for Table III)

All are (T, N)-vectorized and jit-compiled; the LASANA path is the one that
shard_maps to the production mesh (core/distributed.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import LIFNeuron, get_circuit
from repro.core.wrapper import LasanaState, init_state, lasana_step


@dataclasses.dataclass
class LayerRun:
    """Per-tick record of one simulated bank of N circuits."""

    outputs: np.ndarray    # (T, N)
    states: np.ndarray     # (T, N)
    energy: np.ndarray     # (T, N) joules
    latency: np.ndarray    # (T, N) ns (0 when no output event)
    wall_seconds: float


def make_stimulus(circuit, n: int, t_steps: int, *, alpha=0.8, seed=0):
    """Random per-tick stimulus: (active (T,N), x (T,N,n_in), params (N,p))."""
    circuit = get_circuit(circuit)
    key = jax.random.PRNGKey(seed)
    ka, kx, kp = jax.random.split(key, 3)
    active = jax.random.bernoulli(ka, alpha, (t_steps, n))
    active = active.at[0].set(True)
    x = circuit.sample_inputs(kx, (t_steps, n))
    if not isinstance(circuit, LIFNeuron):
        # voltages hold between active ticks
        def hold(prev, ax):
            a, xi = ax
            cur = jnp.where(a[:, None], xi, prev)
            return cur, cur
        _, x = jax.lax.scan(hold, x[0], (active, x))
    else:
        x = jnp.where(active[..., None], x, 0.0)
    params = circuit.sample_params(kp, n)
    return active, x, params


# --- golden -------------------------------------------------------------------

def run_golden(circuit, active, x, params) -> LayerRun:
    circuit = get_circuit(circuit)
    n = params.shape[0]

    @jax.jit
    def sim(active, x, params):
        def step(state, xs):
            x_t = xs
            new_state, obs = circuit.step(state, x_t, params)
            return new_state, (obs["output"], new_state[..., 0],
                               obs["energy"], obs["latency"], obs["spiked"])
        _, out = jax.lax.scan(step, circuit.init_state(n), x)
        return out

    t0 = time.time()
    outputs, states, energy, latency, spiked = jax.block_until_ready(
        sim(active, x, params))
    wall = time.time() - t0
    lat = np.where(np.asarray(spiked), np.asarray(latency), 0.0)
    return LayerRun(outputs=np.asarray(outputs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=lat,
                    wall_seconds=wall)


# --- behavioral (SV-RNM stand-in) ------------------------------------------------

def run_behavioral(circuit, active, x, params) -> LayerRun:
    """Ideal discrete update; no energy/latency (requires ML annotation)."""
    circuit = get_circuit(circuit)
    n = params.shape[0]
    is_lif = isinstance(circuit, LIFNeuron)

    @jax.jit
    def sim(active, x, params):
        if is_lif:
            thresh = 0.8 + 1.0 * (params[:, 1] - 0.5)
            leak = jnp.exp(-(5e-6 / circuit.c_mem) * jnp.exp(
                (params[:, 0] - 0.5) / circuit.ut) * 1e-9 * circuit.clock_ns)

            def step(v, xs):
                a, xi = xs
                drive = (circuit.g_syn * xi[:, 0] * xi[:, 1] * xi[:, 2] / 5.0
                         / circuit.c_mem * circuit.clock_ns * 1e-9)
                v_new = (v + jnp.where(a, drive, 0.0)) * leak
                fire = v_new >= thresh
                v_new = jnp.where(fire, 0.0, jnp.clip(v_new, 0.0, circuit.vdd))
                out = jnp.where(fire, circuit.vdd, 0.0)
                return v_new, (out, v_new)
        else:
            def step(v, xs):
                a, xi = xs
                tgt, _ = circuit._target(xi, params)
                return tgt, (tgt, tgt)

        _, (outs, states) = jax.lax.scan(step, jnp.zeros((n,)), (active, x))
        return outs, states

    t0 = time.time()
    outs, states = jax.block_until_ready(sim(active, x, params))
    wall = time.time() - t0
    z = np.zeros_like(np.asarray(outs))
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=z, latency=z, wall_seconds=wall)


# --- LASANA -----------------------------------------------------------------------

def run_lasana(bank, circuit, active, x, params, *,
               oracle_states: Optional[np.ndarray] = None,
               annotate_outputs: Optional[np.ndarray] = None) -> LayerRun:
    """Algorithm 1 over T ticks.

    oracle_states    — LASANA-O (Table III): feed golden state as v' each tick
    annotate_outputs — annotation mode: behavioral model supplies outputs &
                       states, LASANA only adds energy/latency estimates
    """
    circuit = get_circuit(circuit)
    n = params.shape[0]
    spiking = isinstance(circuit, LIFNeuron)
    clock = circuit.clock_ns
    t_steps = active.shape[0]
    times = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * clock

    oracle = None
    if oracle_states is not None:
        # state BEFORE tick t = golden state at boundary t (prepend 0)
        oracle = jnp.asarray(
            np.concatenate([np.zeros((1, n), np.float32),
                            oracle_states[:-1]], axis=0))

    @jax.jit
    def sim(active, x, params, oracle):
        state0 = init_state(n, params)

        def step(state, xs):
            if oracle is None:
                a, xi, t = xs
            else:
                a, xi, t, v_oracle = xs
                state = state._replace(v=v_oracle)
            new_state, e, l, o = lasana_step(bank, state, a, xi, t, clock,
                                             spiking=spiking)
            return new_state, (o, new_state.v, e, l)

        xs = (active, x, times) if oracle is None else (active, x, times, oracle)
        _, out = jax.lax.scan(step, state0, xs)
        return out

    t0 = time.time()
    outs, states, energy, latency = jax.block_until_ready(
        sim(active, x, params, oracle))
    wall = time.time() - t0
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=np.asarray(latency),
                    wall_seconds=wall)


# --- SNN network (layers of LIF banks wired by weight matrices) --------------------

def drive_to_circuit_inputs(drive):
    """Aggregate synaptic drive -> (w, x, n) circuit inputs (see DESIGN.md)."""
    w = jnp.clip(drive, -1.0, 1.0)
    x = jnp.full_like(drive, 1.5)
    n = jnp.full_like(drive, 5.0)
    return jnp.stack([w, x, n], axis=-1)


def run_snn_lasana(bank, weights: list, spike_seq, params_per_layer, *,
                   clock_ns=5.0):
    """Feed-forward SNN: spike_seq (T, B, n_in) -> per-layer LASANA banks.

    weights[i]: (n_in_i, n_out_i). Neurons are flattened (B * n_out_i) per
    layer. Returns (spike counts per output neuron (B, n_cls), total energy).
    """
    t_steps, b, _ = spike_seq.shape
    n_layers = len(weights)

    def _tile_params(p, n_out):
        p = jnp.asarray(p)
        if p.ndim == 1:                      # one knob set for the layer
            return jnp.broadcast_to(p[None], (b * n_out, p.shape[0]))
        return jnp.tile(p, (b, 1))           # per-neuron knobs

    states = [init_state(b * w.shape[1],
                         _tile_params(params_per_layer[i], w.shape[1]))
              for i, w in enumerate(weights)]

    @jax.jit
    def sim(spike_seq, states):
        def step(carry, xs):
            states = carry
            spikes, t = xs                               # (B, n_in)
            energy = 0.0
            new_states = []
            s = spikes
            for i, w in enumerate(weights):
                drive = (s @ w) / 1.5                    # spike amp 1.5 -> unit
                xin = drive_to_circuit_inputs(drive).reshape(-1, 3)
                changed = jnp.ones((xin.shape[0],), bool)
                ns, e, l, o = lasana_step(bank, states[i], changed, xin, t,
                                          clock_ns, spiking=True)
                new_states.append(ns)
                s = o.reshape(b, w.shape[1])
                energy = energy + jnp.sum(e)
            return new_states, (s, energy)

        times = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * clock_ns
        states, (out_spikes, energy) = jax.lax.scan(step, states,
                                                    (spike_seq, times))
        counts = jnp.sum(out_spikes > 0.75, axis=0)      # (B, n_cls)
        return counts, jnp.sum(energy)

    return sim(spike_seq, states)


def run_snn_golden(circuit, weights: list, spike_seq, params_per_layer):
    """Same network through the golden integrator (the SPICE reference)."""
    circuit = get_circuit(circuit)
    t_steps, b, _ = spike_seq.shape

    def _tile_params(p, n_out):
        p = jnp.asarray(p)
        if p.ndim == 1:
            return jnp.broadcast_to(p[None], (b * n_out, p.shape[0]))
        return jnp.tile(p, (b, 1))

    @jax.jit
    def sim(spike_seq):
        states = [circuit.init_state(b * w.shape[1]) for w in weights]
        params = [_tile_params(params_per_layer[i], w.shape[1])
                  for i, w in enumerate(weights)]

        def step(carry, spikes):
            states = carry
            energy = 0.0
            s = spikes
            new_states = []
            for i, w in enumerate(weights):
                drive = (s @ w) / 1.5
                xin = drive_to_circuit_inputs(drive).reshape(-1, 3)
                ns, obs = circuit.step(states[i], xin, params[i])
                new_states.append(ns)
                s = jnp.where(obs["spiked"], circuit.vdd, 0.0).reshape(
                    b, w.shape[1])
                energy = energy + jnp.sum(obs["energy"])
            return new_states, (s, energy)

        states, (out_spikes, energy) = jax.lax.scan(step, states, spike_seq)
        counts = jnp.sum(out_spikes > 0.75, axis=0)
        return counts, jnp.sum(energy)

    return sim(spike_seq)
