"""Event-driven simulation of circuit banks and spiking networks.

Three simulation backends over identical stimuli (the paper's comparison
set):

  golden      — sub-step ODE integration (the SPICE stand-in; slow, exact)
  behavioral  — SV-RNM-style ideal discrete update (fast, no energy/latency)
  lasana      — Algorithm 1 over the trained PredictorBank; standalone
                surrogate or annotation mode (energy/latency on top of the
                behavioral state), LASANA-P (predicted state feedback) or
                LASANA-O (oracle state from golden, for Table III)

All are (T, N)-vectorized and jit-compiled; the LASANA path is the one that
shard_maps to the production mesh (core/distributed.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circuits import LIFNeuron, get_circuit
from repro.core.wrapper import LasanaState, init_state, lasana_step


@dataclasses.dataclass
class LayerRun:
    """Per-tick record of one simulated bank of N circuits."""

    outputs: np.ndarray    # (T, N)
    states: np.ndarray     # (T, N)
    energy: np.ndarray     # (T, N) joules
    latency: np.ndarray    # (T, N) ns (0 when no output event)
    wall_seconds: float


def make_stimulus(circuit, n: int, t_steps: int, *, alpha=0.8, seed=0):
    """Random per-tick stimulus: (active (T,N), x (T,N,n_in), params (N,p))."""
    circuit = get_circuit(circuit)
    key = jax.random.PRNGKey(seed)
    ka, kx, kp = jax.random.split(key, 3)
    active = jax.random.bernoulli(ka, alpha, (t_steps, n))
    active = active.at[0].set(True)
    x = circuit.sample_inputs(kx, (t_steps, n))
    if not isinstance(circuit, LIFNeuron):
        # voltages hold between active ticks
        def hold(prev, ax):
            a, xi = ax
            cur = jnp.where(a[:, None], xi, prev)
            return cur, cur
        _, x = jax.lax.scan(hold, x[0], (active, x))
    else:
        x = jnp.where(active[..., None], x, 0.0)
    params = circuit.sample_params(kp, n)
    return active, x, params


# --- golden -------------------------------------------------------------------

def run_golden(circuit, active, x, params) -> LayerRun:
    circuit = get_circuit(circuit)
    n = params.shape[0]

    @jax.jit
    def sim(active, x, params):
        def step(state, xs):
            x_t = xs
            new_state, obs = circuit.step(state, x_t, params)
            return new_state, (obs["output"], new_state[..., 0],
                               obs["energy"], obs["latency"], obs["spiked"])
        _, out = jax.lax.scan(step, circuit.init_state(n), x)
        return out

    t0 = time.time()
    outputs, states, energy, latency, spiked = jax.block_until_ready(
        sim(active, x, params))
    wall = time.time() - t0
    lat = np.where(np.asarray(spiked), np.asarray(latency), 0.0)
    return LayerRun(outputs=np.asarray(outputs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=lat,
                    wall_seconds=wall)


# --- behavioral (SV-RNM stand-in) ------------------------------------------------

def run_behavioral(circuit, active, x, params) -> LayerRun:
    """Ideal discrete update; no energy/latency (requires ML annotation)."""
    circuit = get_circuit(circuit)
    n = params.shape[0]
    is_lif = isinstance(circuit, LIFNeuron)

    @jax.jit
    def sim(active, x, params):
        def step(v, xs):
            a, xi = xs
            if is_lif:                  # no drive on idle ticks, leak stays
                xi = jnp.where(a[:, None], xi, 0.0)
            v_new, out = circuit.behavioral_step(v, xi, params)
            return v_new, (out, v_new)

        _, (outs, states) = jax.lax.scan(step, jnp.zeros((n,)), (active, x))
        return outs, states

    t0 = time.time()
    outs, states = jax.block_until_ready(sim(active, x, params))
    wall = time.time() - t0
    z = np.zeros_like(np.asarray(outs))
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=z, latency=z, wall_seconds=wall)


# --- LASANA -----------------------------------------------------------------------

def run_lasana(bank, circuit, active, x, params, *,
               oracle_states: Optional[np.ndarray] = None,
               annotate_outputs: Optional[np.ndarray] = None) -> LayerRun:
    """Algorithm 1 over T ticks.

    oracle_states    — LASANA-O (Table III): feed golden state as v' each tick
    annotate_outputs — annotation mode: behavioral model supplies outputs &
                       states, LASANA only adds energy/latency estimates
    """
    circuit = get_circuit(circuit)
    n = params.shape[0]
    spiking = isinstance(circuit, LIFNeuron)
    clock = circuit.clock_ns
    t_steps = active.shape[0]
    times = (jnp.arange(t_steps, dtype=jnp.float32) + 1.0) * clock

    oracle = None
    if oracle_states is not None:
        # state BEFORE tick t = golden state at boundary t (prepend 0)
        oracle = jnp.asarray(
            np.concatenate([np.zeros((1, n), np.float32),
                            oracle_states[:-1]], axis=0))

    @jax.jit
    def sim(active, x, params, oracle):
        state0 = init_state(n, params)

        def step(state, xs):
            if oracle is None:
                a, xi, t = xs
            else:
                a, xi, t, v_oracle = xs
                state = state._replace(v=v_oracle)
            new_state, e, l, o = lasana_step(bank, state, a, xi, t, clock,
                                             spiking=spiking)
            return new_state, (o, new_state.v, e, l)

        xs = (active, x, times) if oracle is None else (active, x, times, oracle)
        _, out = jax.lax.scan(step, state0, xs)
        return out

    t0 = time.time()
    outs, states, energy, latency = jax.block_until_ready(
        sim(active, x, params, oracle))
    wall = time.time() - t0
    return LayerRun(outputs=np.asarray(outs), states=np.asarray(states),
                    energy=np.asarray(energy), latency=np.asarray(latency),
                    wall_seconds=wall)


# --- SNN network (compat wrappers over core/network.py) -----------------------
#
# The hand-rolled per-layer loops that used to live here moved into the
# network-level event-driven engine (core/network.py); these wrappers keep
# the historical (counts, total_energy) signature for callers that don't
# need the full NetworkRun report.

def drive_to_circuit_inputs(drive):
    """Aggregate synaptic drive -> (w, x, n) circuit inputs (see DESIGN.md)."""
    from repro.core.network import drive_to_circuit_inputs as _impl
    return _impl(drive)


def run_snn_lasana(bank, weights: list, spike_seq, params_per_layer, *,
                   clock_ns=5.0, mode="standalone", edges=()):
    """Feed-forward SNN via the network engine's LASANA backend.

    weights[i]: (n_in_i, n_out_i); ``edges`` are optional one-tick-delayed
    recurrent connections (network.EdgeSpec / network.recurrent_edge).
    Returns (spike counts (B, n_cls), total energy incl. the end-of-run
    idle flush).
    """
    from repro.core.network import NetworkEngine, snn_spec
    eng = NetworkEngine(snn_spec(weights, params_per_layer, edges=edges),
                        backend="lasana", bank=bank, mode=mode,
                        record_hidden=False)
    run = eng.run(spike_seq)
    return run.outputs, run.energy.sum() + run.flush_energy.sum()


def run_snn_golden(circuit, weights: list, spike_seq, params_per_layer, *,
                   edges=()):
    """Same network through the golden integrator (the SPICE reference)."""
    from repro.core.network import NetworkEngine, snn_spec
    eng = NetworkEngine(snn_spec(weights, params_per_layer, edges=edges),
                        backend="golden", record_hidden=False)
    run = eng.run(spike_seq)
    return run.outputs, run.energy.sum()
