"""Event processing: split transient traces into E1/E2/E3 events (paper §IV-A3).

  E1 — one timestep, input changed, output changed  (dynamic energy, latency)
  E3 — one timestep, input changed, output did NOT change (static energy)
  E2 — variable-length idle period before an active timestep (static
       energy), including the idle span before a run's FIRST active step
       (start boundary = the run's initial state/output)

Events always start/end on timestep boundaries. Energy is integrated over
the event; latency is only defined for E1 (start of input to 90% settle /
spike peak). Extraction is vectorized over (runs, T) trace arrays, and
event-set energy sums exactly to the trace energy over [0, last active
step] — only the trailing idle span (nothing reactivates the circuit
inside the trace) is excluded.

Public API
----------
:class:`Trace`
    the (R runs, T timesteps) golden-simulation record handed to extraction
    (``idle_x_is_zero`` distinguishes spiking inputs, which vanish between
    events, from sample-and-hold voltage inputs)
:func:`extract_events` -> :class:`EventSet`
    flat struct-of-arrays event table; slice with ``of_kind``/``select``,
    merge with ``EventSet.concat``
:func:`split_runwise`
    the paper's run-wise 70/15/15 train/test/val split

Downstream, predictors.build_features turns an EventSet into the
(x, v', tau, params[, o_prev, o_new]) feature rows the five predictors
train on; see docs/architecture.md for the event taxonomy's role in
Algorithm 1.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np


class EventKind(enum.IntEnum):
    E1 = 1
    E2 = 2
    E3 = 3


@dataclasses.dataclass
class EventSet:
    """Flat struct-of-arrays event table (one per event kind is sliceable)."""

    kind: np.ndarray        # (M,) EventKind
    x: np.ndarray           # (M, n_inputs) inputs during the event (0 if none)
    v_start: np.ndarray     # (M,) exposed state at event start
    v_end: np.ndarray       # (M,)
    o_prev: np.ndarray      # (M,) output before the event
    o_end: np.ndarray       # (M,) output at event end
    tau: np.ndarray         # (M,) event length (ns)
    params: np.ndarray      # (M, n_params)
    energy: np.ndarray      # (M,) joules over the event
    latency: np.ndarray     # (M,) ns (E1 only; else clock period)
    run_id: np.ndarray      # (M,) originating run (for run-wise splits)

    def __len__(self) -> int:
        return int(self.kind.shape[0])

    def select(self, mask: np.ndarray) -> "EventSet":
        return EventSet(**{f.name: getattr(self, f.name)[mask]
                           for f in dataclasses.fields(self)})

    def of_kind(self, *kinds: EventKind) -> "EventSet":
        mask = np.isin(self.kind, [int(k) for k in kinds])
        return self.select(mask)

    @staticmethod
    def concat(sets: list["EventSet"]) -> "EventSet":
        return EventSet(**{
            f.name: np.concatenate([getattr(s, f.name) for s in sets])
            for f in dataclasses.fields(EventSet)})


@dataclasses.dataclass
class Trace:
    """(R runs, T timesteps) golden-simulation record."""

    active: np.ndarray      # (R,T) bool: input changed at t
    inputs: np.ndarray      # (R,T,n_in) input applied during step t
    state: np.ndarray       # (R,T+1) exposed state at step boundaries
    output: np.ndarray      # (R,T+1) output at step boundaries
    energy: np.ndarray      # (R,T) energy in step t
    latency: np.ndarray     # (R,T) 90%-settle / spike latency in step t
    out_changed: np.ndarray # (R,T) bool
    params: np.ndarray      # (R,n_p)
    clock_ns: float
    idle_x_is_zero: bool    # LIF: no input between spikes; crossbar: held


def extract_events(trace: Trace) -> EventSet:
    r, t = trace.active.shape
    kinds, xs, v0s, v1s, ops, oes, taus, ps, es, ls, rids = (
        [], [], [], [], [], [], [], [], [], [], [])
    ck = trace.clock_ns

    e_cum = np.concatenate([np.zeros((r, 1)), np.cumsum(trace.energy, axis=1)],
                           axis=1)                      # (R, T+1)

    act = trace.active
    for run in range(r):
        idx = np.flatnonzero(act[run])
        for j, t0 in enumerate(idx):
            # idle gap before this active step -> one merged E2 event.
            # j == 0 covers a trace-LEADING gap: its start boundary is the
            # run's initial state/output (prev_end == 0), so static energy
            # before the first active step is still emitted and event-set
            # energy sums to the trace energy over [0, last active step].
            prev_end = idx[j - 1] + 1 if j > 0 else 0
            gap = t0 - prev_end
            if gap > 0:
                xs.append(np.zeros_like(trace.inputs[run, t0])
                          if trace.idle_x_is_zero else trace.inputs[run, t0 - 1])
                kinds.append(int(EventKind.E2))
                v0s.append(trace.state[run, prev_end])
                v1s.append(trace.state[run, t0])
                ops.append(trace.output[run, prev_end])
                oes.append(trace.output[run, t0])
                taus.append(gap * ck)
                ps.append(trace.params[run])
                es.append(e_cum[run, t0] - e_cum[run, prev_end])
                ls.append(ck)
                rids.append(run)
            # the active step itself: E1 or E3
            changed = bool(trace.out_changed[run, t0])
            kinds.append(int(EventKind.E1 if changed else EventKind.E3))
            xs.append(trace.inputs[run, t0])
            v0s.append(trace.state[run, t0])
            v1s.append(trace.state[run, t0 + 1])
            ops.append(trace.output[run, t0])
            oes.append(trace.output[run, t0 + 1])
            taus.append(ck)
            ps.append(trace.params[run])
            es.append(trace.energy[run, t0])
            ls.append(trace.latency[run, t0])
            rids.append(run)

    if not kinds:
        # keep the column shapes of the 2-D fields so feature building on
        # an empty event set (all-idle traces) stays well-formed
        return EventSet(
            kind=np.zeros((0,), np.int32),
            x=np.zeros((0, trace.inputs.shape[-1]), np.float32),
            v_start=np.zeros((0,), np.float32),
            v_end=np.zeros((0,), np.float32),
            o_prev=np.zeros((0,), np.float32),
            o_end=np.zeros((0,), np.float32),
            tau=np.zeros((0,), np.float32),
            params=np.zeros((0, trace.params.shape[-1]), np.float32),
            energy=np.zeros((0,), np.float64),
            latency=np.zeros((0,), np.float32),
            run_id=np.zeros((0,), np.int32),
        )
    return EventSet(
        kind=np.asarray(kinds, np.int32),
        x=np.asarray(xs, np.float32),
        v_start=np.asarray(v0s, np.float32),
        v_end=np.asarray(v1s, np.float32),
        o_prev=np.asarray(ops, np.float32),
        o_end=np.asarray(oes, np.float32),
        tau=np.asarray(taus, np.float32),
        params=np.asarray(ps, np.float32),
        energy=np.asarray(es, np.float64),
        latency=np.asarray(ls, np.float32),
        run_id=np.asarray(rids, np.int32),
    )


def split_runwise(events: EventSet, n_runs: int, *, train=0.7, test=0.15,
                  seed=0):
    """Paper's run-wise 70/15/15 split."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_runs)
    n_tr = int(train * n_runs)
    n_te = int(test * n_runs)
    tr = set(perm[:n_tr].tolist())
    te = set(perm[n_tr:n_tr + n_te].tolist())
    is_tr = np.isin(events.run_id, list(tr))
    is_te = np.isin(events.run_id, list(te))
    is_va = ~(is_tr | is_te)
    return events.select(is_tr), events.select(is_te), events.select(is_va)
