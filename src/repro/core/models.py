"""Surrogate model families (paper Table I): Mean, Table (nearest-neighbor),
Linear, GBDT (CatBoost stand-in, from scratch), MLP (100, 50).

Every model exposes both a numpy ``predict`` (benchmarks) and a JAX-traceable
``jax_predict`` so selected predictors can run *inside* the jitted,
shard_map'd simulation step — the TPU adaptation of the paper's C++ wrapper.

The GBDT uses 256-bin histogram split finding and **complete binary trees**
stored as dense per-depth (feature, threshold) arrays: prediction is
max_depth gathers+compares per tree, no pointer chasing — that is the
MXU/VPU-friendly reformulation of CatBoost inference (see DESIGN.md §4/§8).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --- standardization -----------------------------------------------------------

@dataclasses.dataclass
class Standardizer:
    mu: np.ndarray
    sd: np.ndarray

    @staticmethod
    def fit(x: np.ndarray) -> "Standardizer":
        mu = x.mean(axis=0)
        sd = x.std(axis=0)
        sd = np.where(sd < 1e-12, 1.0, sd)
        return Standardizer(mu.astype(np.float32), sd.astype(np.float32))

    def apply(self, x):
        return (x - self.mu) / self.sd

    def apply_jax(self, x):
        return (x - jnp.asarray(self.mu)) / jnp.asarray(self.sd)


class SurrogateModel:
    name: str = "base"
    train_time: float = 0.0

    def fit(self, xtr, ytr, xva, yva):  # pragma: no cover - interface
        raise NotImplementedError

    def predict(self, x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def jax_predict(self, x):
        raise NotImplementedError


# --- mean ------------------------------------------------------------------------

class MeanModel(SurrogateModel):
    name = "mean"

    def fit(self, xtr, ytr, xva, yva):
        t0 = time.time()
        self.mu = float(np.mean(ytr))
        self.train_time = time.time() - t0
        return self

    def predict(self, x):
        return np.full((x.shape[0],), self.mu, np.float32)

    def jax_predict(self, x):
        return jnp.full((x.shape[0],), self.mu, jnp.float32)


# --- table (1-NN) -----------------------------------------------------------------

class TableModel(SurrogateModel):
    """Nearest-neighbor estimator (table-based models in circuit simulators).

    Inference cost is dominated by the distance computation — the paper's
    Table I shows exactly this blowing up with crossbar dimensionality.
    """

    name = "table"

    def __init__(self, max_rows: int = 20000):
        self.max_rows = max_rows

    def fit(self, xtr, ytr, xva, yva):
        t0 = time.time()
        n = min(len(ytr), self.max_rows)
        idx = np.random.default_rng(0).permutation(len(ytr))[:n]
        self.sx = Standardizer.fit(xtr)
        self.tx = self.sx.apply(xtr[idx]).astype(np.float32)
        self.ty = ytr[idx].astype(np.float32)
        self.train_time = time.time() - t0
        return self

    def predict(self, x):
        xs = self.sx.apply(x).astype(np.float32)
        out = np.empty((x.shape[0],), np.float32)
        t_sq = (self.tx ** 2).sum(-1)
        step = max(1, int(4e7) // max(self.tx.shape[0], 1))  # chunk queries
        for i in range(0, x.shape[0], step):
            blk = xs[i : i + step]
            # |a-b|^2 = |a|^2 - 2ab + |b|^2 (argmin ignores |a|^2)
            d = t_sq[None, :] - 2.0 * (blk @ self.tx.T)
            out[i : i + step] = self.ty[np.argmin(d, axis=1)]
        return out

    def jax_predict(self, x):
        xs = self.sx.apply_jax(x)
        tx = jnp.asarray(self.tx)
        d = jnp.sum(jnp.square(tx), -1)[None, :] - 2.0 * (xs @ tx.T)
        return jnp.asarray(self.ty)[jnp.argmin(d, axis=1)]


# --- linear ------------------------------------------------------------------------

class LinearModel(SurrogateModel):
    name = "linear"

    def fit(self, xtr, ytr, xva, yva):
        t0 = time.time()
        self.sx = Standardizer.fit(xtr)
        a = np.concatenate([self.sx.apply(xtr),
                            np.ones((len(ytr), 1), np.float32)], axis=1)
        w, *_ = np.linalg.lstsq(a.astype(np.float64), ytr.astype(np.float64),
                                rcond=None)
        self.w = w.astype(np.float32)
        self.train_time = time.time() - t0
        return self

    def predict(self, x):
        a = np.concatenate([self.sx.apply(x),
                            np.ones((x.shape[0], 1), np.float32)], axis=1)
        return a @ self.w

    def jax_predict(self, x):
        xs = self.sx.apply_jax(x)
        w = jnp.asarray(self.w)
        return xs @ w[:-1] + w[-1]


# --- GBDT --------------------------------------------------------------------------

class GBDTModel(SurrogateModel):
    """Histogram gradient-boosted complete trees (CatBoost stand-in)."""

    name = "gbdt"

    def __init__(self, n_trees=80, max_depth=8, lr=0.12, n_bins=256,
                 subsample=0.7, min_leaf=8, l2=1.0, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.lr = lr
        self.n_bins = n_bins
        self.subsample = subsample
        self.min_leaf = min_leaf
        self.l2 = l2
        self.seed = seed

    # binning ---------------------------------------------------------------
    def _fit_bins(self, x):
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        self.edges = np.quantile(x, qs, axis=0).astype(np.float32)  # (B-1, F)

    def _binize(self, x):
        out = np.zeros(x.shape, np.int32)
        for f in range(x.shape[1]):
            out[:, f] = np.searchsorted(self.edges[:, f], x[:, f], side="right")
        return out

    def fit(self, xtr, ytr, xva, yva):
        t0 = time.time()
        rng = np.random.default_rng(self.seed)
        x = np.asarray(xtr, np.float32)
        y = np.asarray(ytr, np.float64)
        n, f = x.shape
        self._fit_bins(x)
        bins = self._binize(x)
        self.base = float(np.mean(y))
        pred = np.full(n, self.base)
        n_nodes = 2 ** self.max_depth - 1          # internal nodes
        n_leaves = 2 ** self.max_depth
        self.feat = np.zeros((self.n_trees, n_nodes), np.int32)
        self.thr = np.full((self.n_trees, n_nodes), np.inf, np.float32)
        self.leaf = np.zeros((self.n_trees, n_leaves), np.float32)

        best_va = np.inf
        va_pred = np.full(len(yva), self.base)
        xva_np = np.asarray(xva, np.float32)
        self._kept = self.n_trees

        for t in range(self.n_trees):
            g = (y - pred)                                       # residuals
            if self.subsample < 1.0:
                mask = rng.random(n) < self.subsample
            else:
                mask = np.ones(n, bool)
            node = np.zeros(n, np.int32)                         # current node per sample
            for d in range(self.max_depth):
                lo = 2 ** d - 1
                n_level = 2 ** d
                # histograms over (level-node, feature, bin) in one shot
                rel = node[mask] - lo
                flat = (rel[:, None] * f + np.arange(f)[None, :]) * self.n_bins \
                    + bins[mask]
                gs = np.zeros(n_level * f * self.n_bins)
                cs = np.zeros(n_level * f * self.n_bins)
                np.add.at(gs, flat.ravel(),
                          np.repeat(g[mask], f))
                np.add.at(cs, flat.ravel(), 1.0)
                gs = gs.reshape(n_level, f, self.n_bins)
                cs = cs.reshape(n_level, f, self.n_bins)
                gc = np.cumsum(gs, axis=2)
                cc = np.cumsum(cs, axis=2)
                g_tot = gc[:, :, -1:]
                c_tot = cc[:, :, -1:]
                gl, cl = gc, cc
                gr, cr = g_tot - gc, c_tot - cc
                gain = (gl ** 2 / (cl + self.l2) + gr ** 2 / (cr + self.l2)
                        - g_tot ** 2 / (c_tot + self.l2))
                gain[(cl < self.min_leaf) | (cr < self.min_leaf)] = -np.inf
                gain = gain[:, :, :-1]                           # last bin can't split
                best = gain.reshape(n_level, -1).argmax(axis=1)
                bf = (best // (self.n_bins - 1)).astype(np.int32)
                bb = (best % (self.n_bins - 1)).astype(np.int32)
                ok = np.take_along_axis(
                    gain.reshape(n_level, -1), best[:, None], 1)[:, 0] > 1e-12
                # thresholds from bin edges; dead nodes stay (f=0, thr=inf)
                for j in range(n_level):
                    ni = lo + j
                    if ok[j]:
                        self.feat[t, ni] = bf[j]
                        self.thr[t, ni] = self.edges[min(bb[j], self.n_bins - 2), bf[j]]
                # descend (x <= thr -> left)
                nf = self.feat[t, node]
                nt = self.thr[t, node]
                go_right = x[np.arange(n), nf] > nt
                node = 2 * node + 1 + go_right.astype(np.int32)
            leaf_idx = node - (2 ** self.max_depth - 1)
            sums = np.zeros(n_leaves)
            cnts = np.zeros(n_leaves)
            np.add.at(sums, leaf_idx[mask], g[mask])
            np.add.at(cnts, leaf_idx[mask], 1.0)
            vals = self.lr * sums / (cnts + self.l2)
            self.leaf[t] = vals.astype(np.float32)
            pred = pred + vals[leaf_idx]
            # early stopping on validation
            va_pred = va_pred + self._tree_predict(xva_np, t)
            mse = float(np.mean((va_pred - yva) ** 2))
            if mse < best_va - 1e-12:
                best_va = mse
                self._kept = t + 1
        self.feat = self.feat[: self._kept]
        self.thr = self.thr[: self._kept]
        self.leaf = self.leaf[: self._kept]
        self.train_time = time.time() - t0
        return self

    def _tree_predict(self, x, t):
        node = np.zeros(x.shape[0], np.int32)
        for _ in range(self.max_depth):
            nf = self.feat[t, node]
            nt = self.thr[t, node]
            node = 2 * node + 1 + (x[np.arange(x.shape[0]), nf] > nt)
        return self.leaf[t, node - (2 ** self.max_depth - 1)]

    def predict(self, x):
        x = np.asarray(x, np.float32)
        out = np.full(x.shape[0], self.base, np.float32)
        for t in range(self.feat.shape[0]):
            out = out + self._tree_predict(x, t)
        return out

    def jax_predict(self, x):
        """Depth-unrolled vectorized walk over ALL trees at once.

        Complete trees = dense (tree, node) tables: the walk is max_depth
        gathers + compares, fully vectorized over (samples x trees).
        """
        feat = jnp.asarray(self.feat)            # (T, nodes)
        thr = jnp.asarray(self.thr)
        leaf = jnp.asarray(self.leaf)            # (T, L)
        n_t = feat.shape[0]
        tree_ix = jnp.arange(n_t)[None, :]       # (1, T)
        node = jnp.zeros((x.shape[0], n_t), jnp.int32)
        for _ in range(self.max_depth):
            nf = feat[tree_ix, node]             # (N, T)
            th = thr[tree_ix, node]
            xv = jnp.take_along_axis(x, nf, axis=1)
            node = 2 * node + 1 + (xv > th).astype(jnp.int32)
        leaf_idx = node - (2 ** self.max_depth - 1)
        out = jnp.sum(leaf[tree_ix, leaf_idx], axis=-1)
        return self.base + out


# --- MLP ---------------------------------------------------------------------------

class MLPModel(SurrogateModel):
    """Pure-JAX MLP(100, 50), Adam, early stopping on validation loss."""

    name = "mlp"

    def __init__(self, hidden=(100, 50), lr=2e-3, batch=1024, max_epochs=120,
                 patience=12, l2=1e-6, seed=0):
        self.hidden = hidden
        self.lr = lr
        self.batch = batch
        self.max_epochs = max_epochs
        self.patience = patience
        self.l2 = l2
        self.seed = seed

    def _init(self, key, dims):
        params = []
        for i in range(len(dims) - 1):
            key, k = jax.random.split(key)
            w = jax.random.normal(k, (dims[i], dims[i + 1])) * np.sqrt(2.0 / dims[i])
            params.append({"w": w.astype(jnp.float32),
                           "b": jnp.zeros((dims[i + 1],), jnp.float32)})
        return params

    @staticmethod
    def _apply(params, x):
        h = x
        for i, lyr in enumerate(params):
            h = h @ lyr["w"] + lyr["b"]
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return h[..., 0]

    def fit(self, xtr, ytr, xva, yva):
        t0 = time.time()
        self.sx = Standardizer.fit(xtr)
        self.sy = Standardizer.fit(ytr[:, None])
        x = jnp.asarray(self.sx.apply(xtr), jnp.float32)
        y = jnp.asarray(self.sy.apply(ytr[:, None])[:, 0], jnp.float32)
        xv = jnp.asarray(self.sx.apply(xva), jnp.float32)
        yv = jnp.asarray(self.sy.apply(yva[:, None])[:, 0], jnp.float32)
        dims = (x.shape[1], *self.hidden, 1)
        key = jax.random.PRNGKey(self.seed)
        params = self._init(key, dims)
        opt = [{"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params)}]
        l2 = self.l2
        lr = self.lr

        @jax.jit
        def step(params, m, v, t, xb, yb):
            def loss_fn(p):
                pred = self._apply(p, xb)
                return jnp.mean(jnp.square(pred - yb)) + l2 * sum(
                    jnp.sum(jnp.square(l["w"])) for l in p)
            loss, g = jax.value_and_grad(loss_fn)(params)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b), v, g)
            mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
            return params, m, v, loss

        @jax.jit
        def val_loss(params):
            return jnp.mean(jnp.square(self._apply(params, xv) - yv))

        m, v = opt[0]["m"], opt[0]["v"]
        rng = np.random.default_rng(self.seed)
        n = x.shape[0]
        best = (np.inf, params)
        bad = 0
        t = 0
        for epoch in range(self.max_epochs):
            perm = rng.permutation(n)
            for i in range(0, n - self.batch + 1, self.batch):
                idx = perm[i : i + self.batch]
                t += 1
                params, m, v, _ = step(params, m, v, t, x[idx], y[idx])
            vl = float(val_loss(params))
            if vl < best[0] - 1e-7:
                best = (vl, jax.tree.map(lambda a: a, params))
                bad = 0
            else:
                bad += 1
                if bad >= self.patience:
                    break
        self.params = jax.tree.map(np.asarray, best[1])
        self.train_time = time.time() - t0
        return self

    def predict(self, x):
        return np.asarray(self.jax_predict(jnp.asarray(x, jnp.float32)))

    def jax_predict(self, x):
        xs = self.sx.apply_jax(x)
        p = jax.tree.map(jnp.asarray, self.params)
        yn = self._apply(p, xs)
        return yn * jnp.asarray(self.sy.sd[0]) + jnp.asarray(self.sy.mu[0])


MODEL_FAMILIES = {
    "mean": MeanModel,
    "table": TableModel,
    "linear": LinearModel,
    "gbdt": GBDTModel,
    "mlp": MLPModel,
}
