"""LASANA core: event-level ML surrogate modeling of analog sub-blocks
(the paper's primary contribution), implemented as composable JAX modules.

This package namespace is the curated public surface. The high-level
pipeline (train -> persist -> simulate) is the ``repro.lasana`` facade,
re-exported here; graph construction and the circuit registry come from
the core submodules. Everything else under ``repro.core.*`` is composable
but considered internal plumbing (import the submodule explicitly if you
need it).
"""

# the deployable artifact (repro.lasana re-exports these as well)
from repro.core.surrogate import Manifest, Surrogate, SurrogateLibrary

# circuit registry (golden transient models, the SPICE stand-in)
from repro.core.circuits import CIRCUITS, CrossbarRow, LIFNeuron, get_circuit

# graph construction + the engine behind lasana.simulate
from repro.core.network import (EdgeSpec, LayerSpec, NetworkEngine,
                                NetworkRun, NetworkSpec, StreamingRun,
                                crossbar_layer, crossbar_mlp_spec,
                                graph_spec, lif_layer, recurrent_edge,
                                snn_spec)

# facade callables (train/engine/save/load/TrainConfig) are re-exported
# lazily: repro.lasana itself imports repro.core.network, so a top-level
# import here would be circular (PEP 562 keeps the surface flat). The
# ``simulate`` and ``explore`` entry points are deliberately NOT
# re-exported by name — the ``repro.core.simulate`` / ``repro.core.
# explore`` *submodules* would shadow them; reach them as
# ``repro.core.lasana.simulate`` or (canonically) ``repro.lasana.*``.
_FACADE = ("CandidateSpec", "DSEReport", "TrainConfig", "engine",
           "lasana", "load", "save", "simulate_stream", "stream", "train")

__all__ = [
    # facade (repro.lasana; ``lasana`` is the module itself)
    "CandidateSpec",
    "DSEReport",
    "Manifest",
    "Surrogate",
    "SurrogateLibrary",
    "TrainConfig",
    "engine",
    "lasana",
    "load",
    "save",
    "simulate_stream",
    "stream",
    "train",
    # circuits
    "CIRCUITS",
    "CrossbarRow",
    "LIFNeuron",
    "get_circuit",
    # network graphs
    "EdgeSpec",
    "LayerSpec",
    "NetworkEngine",
    "NetworkRun",
    "NetworkSpec",
    "StreamingRun",
    "crossbar_layer",
    "crossbar_mlp_spec",
    "graph_spec",
    "lif_layer",
    "recurrent_edge",
    "snn_spec",
]


def __getattr__(name):
    if name in _FACADE:
        import repro.lasana as _lasana
        return _lasana if name == "lasana" else getattr(_lasana, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
