# LASANA: event-level ML surrogate modeling of analog sub-blocks
# (the paper's primary contribution), implemented as a composable JAX module.

from repro.core.circuits import CIRCUITS, CrossbarRow, LIFNeuron, get_circuit

__all__ = ["CIRCUITS", "CrossbarRow", "LIFNeuron", "get_circuit"]
