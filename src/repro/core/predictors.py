"""The five LASANA predictors (paper §IV-B) and model selection.

  M_O   output predictor        — E1+E3 events (input-change events)
  M_V   state predictor         — all events
  M_E_D dynamic energy          — E1 only; + previous output feature
  M_E_S static energy           — E2+E3
  M_L   latency                 — E1 only; + previous output feature

All take features (x, v', tau, p); energies are trained in femtojoules for
conditioning (factor recorded on the bank). Several model families are fit
per predictor and the best validation-MSE model is selected (paper §IV-B).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core.circuits import get_circuit
from repro.core.events import EventKind, EventSet
from repro.core.models import MODEL_FAMILIES, SurrogateModel

FJ = 1e15      # joules -> femtojoules

PREDICTOR_DEFS: dict[str, dict] = {
    "M_O": dict(kinds=(EventKind.E1, EventKind.E3), target="o_end",
                prev_out=False, scale=1.0),
    "M_V": dict(kinds=(EventKind.E1, EventKind.E2, EventKind.E3),
                target="v_end", prev_out=False, scale=1.0),
    "M_ED": dict(kinds=(EventKind.E1,), target="energy", prev_out=True,
                 scale=FJ, chain_out=True),
    "M_ES": dict(kinds=(EventKind.E2, EventKind.E3), target="energy",
                 prev_out=False, scale=FJ),
    "M_L": dict(kinds=(EventKind.E1,), target="latency", prev_out=True,
                scale=1.0, chain_out=True),
}
# chain_out (beyond-paper; EXPERIMENTS §Perf-LASANA): M_ED/M_L additionally
# take the NEW output as a feature — the paper already feeds them the
# previous output "since dynamic energy and latency depend on the output
# voltage transition" (§IV-B); completing the transition with M_O's
# prediction (teacher-forced with the golden output at training time)
# halves crossbar M_ED error. Still strictly interface signals.


def build_features(events: EventSet, *, prev_out: bool,
                   chain_out: bool = False) -> np.ndarray:
    cols = [events.x, events.v_start[:, None], events.tau[:, None],
            events.params]
    if prev_out:
        cols.append(events.o_prev[:, None])
    if chain_out:
        cols.append(events.o_end[:, None])   # teacher forcing at fit time
    return np.concatenate(cols, axis=1).astype(np.float32)


def build_target(events: EventSet, name: str, scale: float) -> np.ndarray:
    return (getattr(events, name) * scale).astype(np.float32)


def feature_dim(n_inputs: int, n_params: int, *, prev_out: bool,
                chain_out: bool = False) -> int:
    return (n_inputs + 1 + 1 + n_params + (1 if prev_out else 0)
            + (1 if chain_out else 0))


@dataclasses.dataclass
class FitResult:
    model: SurrogateModel
    family: str
    val_mse: float
    test_mse: float
    test_mape: float
    train_time: float
    test_time: float


def _mape(y, yh, floor=None):
    denom = np.abs(y)
    if floor is None:
        floor = max(np.percentile(denom, 10), 1e-9)
    return float(np.mean(np.abs(yh - y) / np.maximum(denom, floor)) * 100)


class PredictorBank:
    """Trains, selects, and serves the five predictors for one circuit."""

    def __init__(self, circuit_name: str,
                 families: tuple[str, ...] = ("mean", "table", "linear",
                                              "gbdt", "mlp")):
        self.circuit_name = circuit_name
        self.families = families
        self.results: dict[str, dict[str, FitResult]] = {}
        self.selected: dict[str, SurrogateModel] = {}
        self.scales = {k: d["scale"] for k, d in PREDICTOR_DEFS.items()}
        try:
            self._circuit = get_circuit(circuit_name)
        except KeyError:
            self._circuit = None

    def augment_features(self, feats):
        """Append the circuit's physics-informed derived interface features.

        Circuits may expose ``surrogate_features(x, params)`` (see
        circuits.py): derived columns computed purely from interface
        signals, e.g. the crossbar row current w . x. The augmentation is
        ONE shared implementation (``circuits.augment_features``) applied
        here at fit time and inside ``Surrogate.predict`` at serving time,
        so callers (wrapper.py's Algorithm 1, the network engine) keep
        passing raw (x, v, tau, params[, o_prev, o_new]) feature rows."""
        from repro.core.circuits import augment_features
        return augment_features(self._circuit, feats)

    def fit(self, dataset, *, families: Optional[tuple[str, ...]] = None,
            verbose: bool = False) -> "PredictorBank":
        families = families or self.families
        for pname, d in PREDICTOR_DEFS.items():
            tr = dataset.train.of_kind(*d["kinds"])
            va = dataset.val.of_kind(*d["kinds"])
            te = dataset.test.of_kind(*d["kinds"])
            chain = d.get("chain_out", False)
            xtr = self.augment_features(
                build_features(tr, prev_out=d["prev_out"], chain_out=chain))
            ytr = build_target(tr, d["target"], d["scale"])
            xva = self.augment_features(
                build_features(va, prev_out=d["prev_out"], chain_out=chain))
            yva = build_target(va, d["target"], d["scale"])
            xte = self.augment_features(
                build_features(te, prev_out=d["prev_out"], chain_out=chain))
            yte = build_target(te, d["target"], d["scale"])
            self.results[pname] = {}
            for fam in families:
                model = MODEL_FAMILIES[fam]()
                model.fit(xtr, ytr, xva, yva)
                t0 = time.time()
                yh_va = model.predict(xva)
                yh_te = model.predict(xte)
                t_test = time.time() - t0
                res = FitResult(
                    model=model, family=fam,
                    val_mse=float(np.mean((yh_va - yva) ** 2)),
                    test_mse=float(np.mean((yh_te - yte) ** 2)),
                    test_mape=_mape(yte, yh_te),
                    train_time=model.train_time, test_time=t_test)
                self.results[pname][fam] = res
                if verbose:
                    print(f"  {pname:5s} {fam:7s} val_mse={res.val_mse:.4g} "
                          f"test_mse={res.test_mse:.4g} mape={res.test_mape:.2f}% "
                          f"({res.train_time:.1f}s train)")
            best = min(self.results[pname].values(), key=lambda r: r.val_mse)
            self.selected[pname] = best.model
            if verbose:
                print(f"  {pname}: selected {best.family}")
        return self

    def to_surrogate(self):
        """Freeze the selected predictors into an immutable, pytree
        :class:`repro.core.surrogate.Surrogate` — the deployable artifact
        served by ``repro.lasana.simulate`` (and the only form that passes
        through jit as a traced argument)."""
        from repro.core.surrogate import Surrogate
        return Surrogate.from_bank(self)

    # --- inference (jit-friendly; deprecated in favor of Surrogate) ---------

    def predict(self, pname: str, feats):
        """JAX prediction in physical units (energy back to joules).

        ``feats`` are the raw (x, v, tau, params[, ...]) rows; the circuit's
        derived interface features are appended here (augment_features).

        Deprecated for serving: prefer ``to_surrogate().predict`` — the
        surrogate computes the identical result but is swappable through a
        compiled program without retracing (a bank is a mutable Python
        closure; a surrogate is a traced pytree argument)."""
        y = self.selected[pname].jax_predict(self.augment_features(feats))
        return y / self.scales[pname]

    def predict_np(self, pname: str, feats: np.ndarray) -> np.ndarray:
        return (self.selected[pname].predict(self.augment_features(feats))
                / self.scales[pname])

    # --- reporting ------------------------------------------------------------

    def table_rows(self) -> list[dict]:
        rows = []
        for pname, fams in self.results.items():
            for fam, r in fams.items():
                rows.append(dict(circuit=self.circuit_name, predictor=pname,
                                 family=fam, val_mse=r.val_mse,
                                 test_mse=r.test_mse, test_mape=r.test_mape,
                                 train_s=r.train_time, test_s=r.test_time,
                                 selected=self.selected[pname] is r.model))
        return rows
