"""Multi-pod distributed LASANA simulation.

Circuits are embarrassingly parallel: Algorithm 1 has no cross-circuit
communication, so the (N, ...) state/stimulus arrays shard over EVERY mesh
axis flattened (pod x data x model = 512 ways). ``shard_map`` makes the
locality explicit — the per-shard body is exactly ``lasana_step`` on N/512
circuits — and diagnostics (total energy, spike counts) are the only psums.

This module also provides the LASANA dry-run used in EXPERIMENTS §Dry-run:
lowering one simulation tick for 2^20..2^27 circuits on the production mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.wrapper import LasanaState, lasana_step


def circuit_spec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))      # shard circuits over all axes


def batch_spec(mesh: Mesh, ndim: int = 1, axis: int = 0) -> P:
    """PartitionSpec sharding dim ``axis`` of an ndim array over ALL mesh
    axes flattened (the network engine's batch-parallel layout: batch-major
    flattened circuit arrays shard contiguously)."""
    spec: list = [None] * ndim
    spec[axis] = tuple(mesh.axis_names)
    return P(*spec)


def shard_over_batch(fn, mesh: Mesh, in_specs, out_specs):
    """jit(shard_map(fn)) — the network engine's batch-parallel wrapper.

    ``fn`` must be batch-local except for explicit psum/pmax collectives
    (Algorithm 1 has zero cross-circuit communication, so a whole network
    tick is batch-local; only diagnostics reduce)."""
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))


def make_distributed_step(bank, mesh: Mesh, *, clock_ns: float,
                          spiking: bool = False):
    """(state, changed, x, t) -> (state, e_total, spikes_total) shard-mapped."""
    cspec = circuit_spec(mesh)
    state_spec = LasanaState(v=cspec, o=cspec, t_last=cspec, params=cspec)

    def body(state, changed, x, t):
        new_state, e, l, o = lasana_step(bank, state, changed, x, t[0],
                                         clock_ns, spiking=spiking)
        e_tot = jax.lax.psum(jnp.sum(e), tuple(mesh.axis_names))
        n_out = jax.lax.psum(jnp.sum((o > 0.75).astype(jnp.float32)),
                             tuple(mesh.axis_names))
        return new_state, e_tot, n_out

    sm = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, cspec, cspec, P()),
                   out_specs=(state_spec, P(), P()))
    return jax.jit(sm)


def abstract_sim_inputs(n_circuits: int, n_in: int, n_params: int):
    f32 = jnp.float32
    state = LasanaState(
        v=jax.ShapeDtypeStruct((n_circuits,), f32),
        o=jax.ShapeDtypeStruct((n_circuits,), f32),
        t_last=jax.ShapeDtypeStruct((n_circuits,), f32),
        params=jax.ShapeDtypeStruct((n_circuits, n_params), f32),
    )
    changed = jax.ShapeDtypeStruct((n_circuits,), jnp.bool_)
    x = jax.ShapeDtypeStruct((n_circuits, n_in), f32)
    t = jax.ShapeDtypeStruct((1,), f32)
    return state, changed, x, t


def lower_distributed_step(bank, mesh: Mesh, n_circuits: int, n_in: int,
                           n_params: int, *, clock_ns: float,
                           spiking: bool = False):
    """Lower one sharded simulation tick from ShapeDtypeStructs (dry-run)."""
    step = make_distributed_step(bank, mesh, clock_ns=clock_ns,
                                 spiking=spiking)
    args = abstract_sim_inputs(n_circuits, n_in, n_params)
    with mesh:
        return step.lower(*args)
