"""Multi-pod distributed LASANA simulation.

Circuits are embarrassingly parallel: Algorithm 1 has no cross-circuit
communication, so the (N, ...) state/stimulus arrays shard over EVERY mesh
axis flattened (pod x data x model = 512 ways). ``shard_map`` makes the
locality explicit — the per-shard body is exactly ``lasana_step`` on N/512
circuits — and diagnostics (total energy, spike counts) are the only psums.

The trained :class:`Surrogate` enters the sharded program as a *traced
pytree argument* with replicated (``P()``) specs: one compiled step serves
every retrained surrogate whose manifest and array shapes match, and the
predictor weights participate in the mesh like any other arrays.

This module also provides the LASANA dry-run used in EXPERIMENTS §Dry-run:
lowering one simulation tick for 2^20..2^27 circuits on the production mesh.
"""

from __future__ import annotations

import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.surrogate import Surrogate, as_surrogate
from repro.core.wrapper import LasanaState, lasana_step


def circuit_spec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))      # shard circuits over all axes


def batch_spec(mesh: Mesh, ndim: int = 1, axis: int = 0) -> P:
    """PartitionSpec sharding dim ``axis`` of an ndim array over ALL mesh
    axes flattened (the network engine's batch-parallel layout: batch-major
    flattened circuit arrays shard contiguously)."""
    spec: list = [None] * ndim
    spec[axis] = tuple(mesh.axis_names)
    return P(*spec)


def shard_over_batch(fn, mesh: Mesh, in_specs, out_specs,
                     donate_argnums=()):
    """jit(shard_map(fn)) — the network engine's batch-parallel wrapper.

    ``fn`` must be batch-local except for explicit psum/pmax collectives
    (Algorithm 1 has zero cross-circuit communication, so a whole network
    tick is batch-local; only diagnostics reduce). Pytree arguments whose
    in_spec leaves are ``P()`` — e.g. a :class:`Surrogate` — replicate
    across the mesh while remaining traced (swap-without-recompile).

    ``donate_argnums`` is forwarded to ``jax.jit``: the network engine's
    streaming path donates its chunk-to-chunk carries (and the surrogate
    leaves) so XLA aliases them in place instead of copying per chunk.

    ``check_rep=False``: jax 0.4 has no replication rule for
    ``pallas_call``, so the static replication checker rejects any body
    that launches a kernel (e.g. the tick megakernel under
    ``REPRO_TICK_PALLAS=1``); disabling the check changes no numerics —
    the per-shard body and its collectives run identically."""
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False),
                   donate_argnums=donate_argnums)


def _sharded_step(mesh: Mesh, surrogate_template, *, clock_ns: float,
                  spiking: bool = False, vdd: float = 1.5,
                  fused: bool = True, fused_kernel: bool = False):
    """jit(shard_map) of one Algorithm-1 tick; surrogate is argument 0.

    ``surrogate_template`` supplies only the pytree *structure* for the
    replicated in_specs. ``fused_kernel`` is the RESOLVED fused-kernel
    switch — the per-shard body is exactly ``lasana_step``, so the
    megakernel runs shard-local on N/devices circuits with the head pack
    replicated like every other surrogate leaf."""
    cspec = circuit_spec(mesh)
    state_spec = LasanaState(v=cspec, o=cspec, t_last=cspec, params=cspec)
    sur_spec = jax.tree.map(lambda _: P(), surrogate_template)

    def body(surrogate, state, changed, x, t):
        new_state, e, l, o = lasana_step(surrogate, state, changed, x, t[0],
                                         clock_ns, spiking=spiking, vdd=vdd,
                                         fused=fused,
                                         fused_kernel=fused_kernel)
        e_tot = jax.lax.psum(jnp.sum(e), tuple(mesh.axis_names))
        # spike counts are integers: fp32 accumulation silently loses
        # whole events past 2^24 per tick at dry-run scales (2^27 circuits)
        n_out = jax.lax.psum(jnp.sum(o > 0.5 * vdd, dtype=jnp.int32),
                             tuple(mesh.axis_names))
        return new_state, e_tot, n_out

    sm = shard_map(body, mesh=mesh,
                   in_specs=(sur_spec, state_spec, cspec, cspec, P()),
                   out_specs=(state_spec, P(), P()),
                   check_rep=False)     # pallas_call has no replication rule
    return jax.jit(sm)


def make_distributed_step(mesh, _legacy_mesh=None, *, clock_ns: float,
                          spiking: bool = False, vdd: float = 1.5,
                          fused: bool = True,
                          fused_kernel: bool | None = None):
    """(surrogate, state, changed, x, t) -> (state, e_total, spikes_total).

    Returns a callable that shard_maps one tick over ``mesh``. The
    surrogate rides along as a traced, replicated pytree: calls with
    retrained surrogates of identical structure reuse one compiled program
    (the program cache is keyed on the surrogate's treedef).
    ``spikes_total`` is an exact int32 count; ``vdd`` is the spiking
    circuit's supply voltage (spike resolution + discriminator level);
    ``fused`` selects the fused ``predict_heads`` tick body (default) vs
    the per-``predict``-call baseline; ``fused_kernel`` is the tri-state
    fused-kernel override (None defers to ``REPRO_FUSED_KERNEL``,
    re-resolved per call so env flips recompile cleanly).

    Legacy call style ``make_distributed_step(bank, mesh, ...)`` (surrogate
    closed over, returned callable takes ``(state, changed, x, t)``) is
    still accepted, with a DeprecationWarning.
    """
    if _legacy_mesh is None and not isinstance(mesh, Mesh):
        raise TypeError(
            "make_distributed_step expects a jax.sharding.Mesh as its "
            f"first argument, got {type(mesh).__name__}; the surrogate is "
            "passed to the returned step, not here")
    if _legacy_mesh is not None:
        if not isinstance(_legacy_mesh, Mesh):
            raise TypeError("legacy make_distributed_step(bank, mesh, ...) "
                            "call: second argument must be a "
                            f"jax.sharding.Mesh, got "
                            f"{type(_legacy_mesh).__name__}")
        warnings.warn(
            "make_distributed_step(bank, mesh, ...) is deprecated; call "
            "make_distributed_step(mesh, ...) and pass the Surrogate as "
            "the step's first argument", DeprecationWarning, stacklevel=2)
        surrogate = as_surrogate(mesh)
        from repro.kernels import ops
        fn = _sharded_step(_legacy_mesh, surrogate, clock_ns=clock_ns,
                           spiking=spiking, vdd=vdd, fused=fused,
                           fused_kernel=ops.fused_kernel_enabled(
                               fused_kernel))
        return lambda state, changed, x, t: fn(surrogate, state, changed,
                                               x, t)

    cache: dict = {}

    def step(surrogate, state, changed, x, t):
        from repro.kernels import ops
        surrogate = as_surrogate(surrogate)
        # the fused-kernel switch and the megakernel launcher each select
        # a different traced body, so they join the treedef in the
        # program cache key — flipping either mid-process recompiles
        # cleanly instead of silently reusing the old program
        fk = ops.fused_kernel_enabled(fused_kernel)
        key = (jax.tree.structure(surrogate), fk,
               ops.tick_pallas_enabled())
        fn = cache.get(key)
        if fn is None:
            fn = _sharded_step(mesh, surrogate, clock_ns=clock_ns,
                               spiking=spiking, vdd=vdd, fused=fused,
                               fused_kernel=fk)
            cache[key] = fn
        return fn(surrogate, state, changed, x, t)

    return step


def abstract_sim_inputs(n_circuits: int, n_in: int, n_params: int):
    f32 = jnp.float32
    state = LasanaState(
        v=jax.ShapeDtypeStruct((n_circuits,), f32),
        o=jax.ShapeDtypeStruct((n_circuits,), f32),
        t_last=jax.ShapeDtypeStruct((n_circuits,), f32),
        params=jax.ShapeDtypeStruct((n_circuits, n_params), f32),
    )
    changed = jax.ShapeDtypeStruct((n_circuits,), jnp.bool_)
    x = jax.ShapeDtypeStruct((n_circuits, n_in), f32)
    t = jax.ShapeDtypeStruct((1,), f32)
    return state, changed, x, t


def lower_distributed_step(surrogate, mesh: Mesh, n_circuits: int, n_in: int,
                           n_params: int, *, clock_ns: float,
                           spiking: bool = False, vdd: float = 1.5,
                           fused: bool = True,
                           fused_kernel: bool | None = None):
    """Lower one sharded simulation tick from ShapeDtypeStructs (dry-run).

    ``surrogate`` may be a Surrogate or a legacy PredictorBank; its arrays
    stay concrete (they are the weights), the simulation inputs are
    abstract."""
    from repro.kernels import ops
    surrogate = as_surrogate(surrogate)
    step = _sharded_step(mesh, surrogate, clock_ns=clock_ns, spiking=spiking,
                         vdd=vdd, fused=fused,
                         fused_kernel=ops.fused_kernel_enabled(fused_kernel))
    args = abstract_sim_inputs(n_circuits, n_in, n_params)
    with mesh:
        return step.lower(surrogate, *args)
