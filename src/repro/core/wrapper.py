"""Algorithm 1 — the ML inference wrapper, vectorized for TPUs.

The paper's wrapper walks a *set* S of circuits whose input changed at tick
t; TPUs want fixed shapes, so S becomes a boolean mask and both the
idle-catch-up path (lines 3-9) and the active path (lines 10-22) are
evaluated for all N circuits with ``where``-selection (lines 23-29).
Semantics are identical — verified against a per-circuit reference loop in
tests/test_wrapper.py — and the two systems optimizations fall out for free:

  * batching across the system: the whole tick is ONE batched inference per
    predictor (the (N, F) feature matrices below);
  * idle-period merging: stale circuits are caught up with a single E2 event
    of length t - t' - T rather than per-tick updates (line 5).

``lasana_step`` is pure and jit/shard_map-friendly: circuits shard over the
flattened mesh with zero cross-circuit communication.

Public API
----------
:class:`LasanaState` / :func:`init_state`
    per-circuit simulator state: predicted state ``v``, last output ``o``,
    last-update time ``t_last``, fixed ``params``
:func:`lasana_step`
    one digital tick of Algorithm 1 for N circuits; ``known_out=`` switches
    annotation mode (external behavioral outputs, LASANA energy/latency).
    By default the tick takes the FUSED inference path
    (``Surrogate.predict_heads``): features are derived once per variant
    and same-family predictor heads evaluate in batched stacked passes —
    three fused dispatches per tick (idle heads -> active-variant heads
    -> transition heads, which consume M_O's resolved output) instead of
    seven ``predict`` calls, and a single dispatch in annotation mode.
    ``fused=False`` keeps the original one-``predict``-per-head
    formulation (the benchmark A/B baseline; results agree within a few
    ULPs — see docs/architecture.md, "Inference hot path").
:func:`lasana_step_reference`
    literal per-circuit numpy transcription, the parity oracle for tests

The network-level composition of this wrapper (event queues between
layers, mixed circuit kinds, recurrent edges) lives in core/network.py;
see docs/architecture.md for the full dataflow.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class LasanaState(NamedTuple):
    """Per-circuit simulator state (all (N,) or (N, k))."""

    v: jax.Array          # latest predicted state v'
    o: jax.Array          # latest output
    t_last: jax.Array     # latest update time t'
    params: jax.Array     # (N, n_p) fixed circuit parameters


def init_state(n: int, params) -> LasanaState:
    return LasanaState(
        v=jnp.zeros((n,), jnp.float32),
        o=jnp.zeros((n,), jnp.float32),
        t_last=jnp.zeros((n,), jnp.float32),
        params=params,
    )


def _features(x, v, tau, params, o_prev=None, o_new=None):
    cols = [x, v[:, None], tau[:, None], params]
    if o_prev is not None:
        cols.append(o_prev[:, None])
    if o_new is not None:
        cols.append(o_new[:, None])     # chained M_O prediction (§IV-B ext.)
    return jnp.concatenate(cols, axis=1)


def _splice_transition(aug_act, f_base: int, o_prev, o_new):
    """Augmented transition matrix as a column splice of the active one.

    The transition variant is the active variant plus ``o_prev``/``o_new``
    columns inserted BEFORE the circuit's derived features (which depend
    only on the shared x/params columns) — so the already-augmented active
    matrix is reused instead of re-deriving anything."""
    return jnp.concatenate(
        [aug_act[:, :f_base], o_prev[:, None], o_new[:, None],
         aug_act[:, f_base:]], axis=1)


def _resolve_output(o_hat, o_prev, *, out_eps, spiking, vdd):
    """Lines 23-25: classify the event and resolve the published output."""
    if spiking:
        out_changed = o_hat > 0.5 * vdd          # spike fired this tick
        return out_changed, jnp.where(out_changed, vdd, 0.0)
    return jnp.abs(o_hat - o_prev) > out_eps, o_hat


def lasana_step(surrogate, state: LasanaState, changed, x, t, clock_ns, *,
                out_eps: float = 0.02, spiking: bool = False,
                known_out=None, vdd: float = 1.5, fused: bool = True,
                fused_kernel: bool | None = None, megakernel_pack=None,
                megakernel_layout=None):
    """One digital tick for N circuits (Algorithm 1).

    surrogate  a :class:`repro.core.surrogate.Surrogate` — an immutable
             pytree of selected-predictor arrays. Because it is a pytree,
             it can (and should) be passed through ``jax.jit`` as a TRACED
             ARGUMENT alongside ``state``: the compiled step then serves
             any retrained surrogate with matching shapes without
             recompiling. A legacy ``PredictorBank`` also works (duck-typed
             ``.predict``) but only as a closed-over constant, and always
             on the per-call path.
    state    LasanaState
    changed  (N,) bool — set S as a mask
    x        (N, n_in) inputs applied at t (rows of X)
    t        scalar time (ns)
    known_out  (N,) optional — annotation mode: the output this tick is
             supplied by an external behavioral model, so M_O/M_V are
             skipped and LASANA only resolves the event class and predicts
             energy/latency. Callers substitute the behavioral state into
             ``state.v`` each tick (there is no staleness to catch up, but
             the merged-E2 *energy* of idle gaps is still accounted).
    vdd      spiking circuits only: the circuit's supply voltage. A fired
             spike is resolved to exactly ``vdd`` volts and the spike
             discriminator sits at ``vdd / 2`` — callers simulating a
             non-1.5-V_dd circuit MUST thread the circuit's own supply
             here or outputs silently diverge across backends.
    fused    take the fused inference hot path
             (``Surrogate.predict_heads``): derive features once per
             variant and evaluate same-family heads in batched stacked
             passes — three fused dispatches per tick instead of seven
             ``predict`` calls (one dispatch in annotation mode). Head
             stacking reorders float reductions, so fused and per-call
             results may differ by a few ULPs (rtol 1e-5; see
             docs/architecture.md "Inference hot path" and
             tests/test_fused.py). ``fused=False`` — or a surrogate
             without ``predict_heads`` — keeps the original
             one-``predict``-per-head formulation, the benchmark A/B
             baseline.
    fused_kernel  kernel-path override threaded to
             ``ops.fused_kernel_enabled`` (None = the
             ``REPRO_FUSED_KERNEL`` env default). When the kernel path is
             on AND the surrogate's heads are packable, the whole tick
             collapses further — from three stacked dispatches to ONE
             megakernel evaluation with all stages chained in VMEM (see
             kernels/tick_megakernel.py); otherwise the stacked
             ``predict_heads`` path routes its 3-layer MLP heads through
             the multi-head Pallas kernel as before.
    megakernel_pack / megakernel_layout  a pre-built
             ``tick_megakernel.pack_heads``/``pack_library`` pack —
             callers ticking many banks (network cascades) build one
             cross-kind pack and thread each kind's slice here; when
             None, the pack is derived from ``surrogate`` on the fly.
    returns  (new_state, e (N,), l (N,), o (N,))
    """
    if fused and hasattr(surrogate, "predict_heads"):
        from repro.kernels import ops
        if ops.fused_kernel_enabled(fused_kernel):
            from repro.kernels import tick_megakernel as mk
            pack, layout = megakernel_pack, megakernel_layout
            if pack is None:
                pack, layout = mk.pack_heads(surrogate)
            if pack is not None:
                return mk.megakernel_step(
                    pack, surrogate.manifest.circuit, state, changed, x, t,
                    clock_ns, out_eps=out_eps, spiking=spiking,
                    known_out=known_out, vdd=vdd, layout=layout)
        return _lasana_step_fused(surrogate, state, changed, x, t, clock_ns,
                                  out_eps=out_eps, spiking=spiking,
                                  known_out=known_out, vdd=vdd,
                                  fused_kernel=fused_kernel)
    return _lasana_step_percall(surrogate, state, changed, x, t, clock_ns,
                                out_eps=out_eps, spiking=spiking,
                                known_out=known_out, vdd=vdd)


def _lasana_step_fused(surrogate, state, changed, x, t, clock_ns, *,
                       out_eps, spiking, known_out, vdd,
                       fused_kernel=None):
    """Algorithm 1 via ``Surrogate.predict_heads`` (the fused hot path).

    Head schedule (standalone mode) — the data dependencies allow at most
    three fused dispatches per tick:

      1. idle variant: M_ES + M_V stacked (the v' catch-up feeds the
         active features)
      2. active variant: M_O + M_V + M_ES stacked (only M_O's resolved
         output is needed downstream, but M_V/M_ES don't depend on it —
         so the whole variant is one pass)
      3. transition variant: M_ED + M_L stacked (these DO consume M_O's
         resolved output through the o_new column)

    Annotation mode has no data dependencies (state and outputs are
    external), so the whole tick is ONE dispatch across all variants."""
    from repro.core.surrogate import _augment

    n = state.v.shape[0]
    annotate = known_out is not None
    circuit = surrogate.manifest.circuit

    # --- lines 3-9: catch up stale circuits with one merged idle event
    stale = changed & (state.t_last < t - clock_ns)
    tau_idle = jnp.maximum(t - state.t_last - clock_ns, 0.0)
    feats_idle = _features(jnp.zeros_like(x), state.v, tau_idle,
                           state.params)
    tau_act = jnp.full((n,), clock_ns, jnp.float32)

    if annotate:
        v_cur = state.v            # behavioral state: never stale
        v_new = v_cur              # caller overwrites with behavioral state
        o_hat = known_out
        feats = _features(x, v_cur, tau_act, state.params)
        out_changed, o_resolved = _resolve_output(
            o_hat, state.o, out_eps=out_eps, spiking=spiking, vdd=vdd)
        aug_act = _augment(circuit, feats)
        aug_tr = _splice_transition(aug_act, feats.shape[1], state.o,
                                    o_resolved)
        r = surrogate.predict_heads(
            feats_idle=_augment(circuit, feats_idle), feats_act=aug_act,
            feats_tr=aug_tr,
            heads={"idle": ("M_ES",), "act": ("M_ES",),
                   "tr": ("M_ED", "M_L")},
            augmented=True, fused_kernel=fused_kernel)
        e_s_idle = r["idle"]["M_ES"]
        e_s, e_d, lat = r["act"]["M_ES"], r["tr"]["M_ED"], r["tr"]["M_L"]
    else:
        r1 = surrogate.predict_heads(feats_idle=feats_idle,
                                     heads={"idle": ("M_ES", "M_V")},
                                     fused_kernel=fused_kernel)
        e_s_idle = r1["idle"]["M_ES"]
        v_cur = jnp.where(stale, r1["idle"]["M_V"], state.v)

        # --- lines 10-22: one stacked pass over the whole active variant
        # (M_O's prediction chains into the transition-aware heads, but
        # M_V/M_ES don't consume it — so they ride the same dispatch)
        feats = _features(x, v_cur, tau_act, state.params)
        aug_act = _augment(circuit, feats)
        r2 = surrogate.predict_heads(feats_act=aug_act,
                                     heads={"act": ("M_O", "M_V", "M_ES")},
                                     augmented=True,
                                     fused_kernel=fused_kernel)
        o_hat, v_new, e_s = (r2["act"]["M_O"], r2["act"]["M_V"],
                             r2["act"]["M_ES"])
        out_changed, o_resolved = _resolve_output(
            o_hat, state.o, out_eps=out_eps, spiking=spiking, vdd=vdd)
        aug_tr = _splice_transition(aug_act, feats.shape[1], state.o,
                                    o_resolved)
        r3 = surrogate.predict_heads(feats_tr=aug_tr,
                                     heads={"tr": ("M_ED", "M_L")},
                                     augmented=True,
                                     fused_kernel=fused_kernel)
        e_d, lat = r3["tr"]["M_ED"], r3["tr"]["M_L"]

    return _finish_tick(state, changed, stale, e_s_idle, e_d, e_s, lat,
                        out_changed, o_hat, v_cur, v_new, t,
                        spiking=spiking, vdd=vdd)


def _lasana_step_percall(surrogate, state, changed, x, t, clock_ns, *,
                         out_eps, spiking, known_out, vdd):
    """Algorithm 1 with one ``predict`` dispatch per head (pre-fusion
    formulation; the fused-vs-unfused benchmark baseline)."""
    n = state.v.shape[0]
    zeros_x = jnp.zeros_like(x)
    annotate = known_out is not None

    # --- lines 3-9: catch up stale circuits with one merged idle event
    stale = changed & (state.t_last < t - clock_ns)
    tau_idle = jnp.maximum(t - state.t_last - clock_ns, 0.0)
    feats_idle = _features(zeros_x, state.v, tau_idle, state.params)
    e_s_idle = surrogate.predict("M_ES", feats_idle)
    if annotate:
        v_cur = state.v            # behavioral state: never stale
    else:
        v_hat = surrogate.predict("M_V", feats_idle)
        v_cur = jnp.where(stale, v_hat, state.v)

    # --- lines 10-22: run all predictors on the active batch.
    # M_O runs first so its prediction can chain into the transition-aware
    # energy/latency predictors (beyond-paper; see predictors.py).
    tau_act = jnp.full((n,), clock_ns, jnp.float32)
    feats = _features(x, v_cur, tau_act, state.params)
    if annotate:
        o_hat = known_out
        v_new = v_cur              # caller overwrites with behavioral state
    else:
        o_hat = surrogate.predict("M_O", feats)
        v_new = surrogate.predict("M_V", feats)

    # --- lines 23-29: select dynamic vs static by output behaviour
    out_changed, o_resolved = _resolve_output(
        o_hat, state.o, out_eps=out_eps, spiking=spiking, vdd=vdd)
    # chain the event-RESOLVED output (matches the E1 training distribution,
    # where spiking outputs are exactly V_dd) into the transition predictors
    feats_tr = _features(x, v_cur, tau_act, state.params, o_prev=state.o,
                         o_new=o_resolved)
    e_d = surrogate.predict("M_ED", feats_tr)
    e_s = surrogate.predict("M_ES", feats)
    lat = surrogate.predict("M_L", feats_tr)
    return _finish_tick(state, changed, stale, e_s_idle, e_d, e_s, lat,
                        out_changed, o_hat, v_cur, v_new, t,
                        spiking=spiking, vdd=vdd)


def _finish_tick(state, changed, stale, e_s_idle, e_d, e_s, lat,
                 out_changed, o_hat, v_cur, v_new, t, *, spiking, vdd):
    """Lines 23-30 tail shared by both inference paths: select dynamic vs
    static records and write back the masked state update."""
    e = jnp.where(stale, e_s_idle, 0.0)
    e_evt = jnp.where(out_changed, e_d, e_s)
    l_evt = jnp.where(out_changed, lat, 0.0)
    e = e + jnp.where(changed, e_evt, 0.0)
    l = jnp.where(changed, l_evt, 0.0)
    if spiking:
        o_out = jnp.where(changed, jnp.where(out_changed, vdd, 0.0), state.o)
    else:
        o_out = jnp.where(changed, o_hat, state.o)

    new_state = LasanaState(
        v=jnp.where(changed, v_new, v_cur),
        o=o_out,
        t_last=jnp.where(changed, t, state.t_last),   # line 30
        params=state.params,
    )
    return new_state, e, l, o_out


def lasana_step_reference(surrogate, state: LasanaState, changed, x, t,
                          clock_ns, *, out_eps: float = 0.02,
                          spiking: bool = False, vdd: float = 1.5):
    """Literal per-circuit transcription of Algorithm 1 (numpy, for tests)."""
    import numpy as np

    n = state.v.shape[0]
    v = np.asarray(state.v).copy()
    o = np.asarray(state.o).copy()
    t_last = np.asarray(state.t_last).copy()
    params = np.asarray(state.params)
    x = np.asarray(x)
    e = np.zeros(n)
    l = np.zeros(n)
    changed = np.asarray(changed)

    for i in range(n):
        if not changed[i]:
            continue
        if t_last[i] < t - clock_ns:                      # lines 4-6
            tau = t - t_last[i] - clock_ns
            fi = np.concatenate([np.zeros_like(x[i]), [v[i]], [tau], params[i]])
            v[i] = float(surrogate.predict_np("M_V", fi[None])[0])
            e[i] += float(surrogate.predict_np("M_ES", fi[None])[0])
        f = np.concatenate([x[i], [v[i]], [clock_ns], params[i]])
        o_hat = float(surrogate.predict_np("M_O", f[None])[0])
        v_new = float(surrogate.predict_np("M_V", f[None])[0])
        if spiking:
            changed_out = o_hat > 0.5 * vdd
            o_res = vdd if changed_out else 0.0
        else:
            changed_out = abs(o_hat - o[i]) > out_eps
            o_res = o_hat
        fp = np.concatenate([x[i], [v[i]], [clock_ns], params[i], [o[i]],
                             [o_res]])
        e_d = float(surrogate.predict_np("M_ED", fp[None])[0])
        e_s = float(surrogate.predict_np("M_ES", f[None])[0])
        lat = float(surrogate.predict_np("M_L", fp[None])[0])
        if changed_out:                                    # lines 24-27
            e[i] += e_d
            l[i] = lat
        else:
            e[i] += e_s
        v[i] = v_new
        if spiking:
            o[i] = vdd if changed_out else 0.0
        else:
            o[i] = o_hat
        t_last[i] = t
    new_state = LasanaState(v=jnp.asarray(v, jnp.float32),
                            o=jnp.asarray(o, jnp.float32),
                            t_last=jnp.asarray(t_last, jnp.float32),
                            params=state.params)
    return new_state, e, l, np.asarray(new_state.o)
