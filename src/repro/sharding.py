"""Logical-axis sharding substrate.

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "mlp", "experts", "batch", "seq", ...). A `ShardingRules`
table maps each logical axis onto zero or more *mesh* axes. Physical
`NamedSharding`s are derived on demand, MaxText-style, so the same model
definition runs on any mesh (single host, 16x16 pod, 2x16x16 multi-pod)
by swapping rule tables rather than editing the model.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis vocabulary (documented here; rules may omit entries = replicated)
#
#   batch        global batch dim of activations
#   seq          sequence dim of activations (context parallelism for long seq)
#   embed        model dimension d_model
#   heads        attention head dim of params/activations
#   kv_heads     kv-head dim (GQA)
#   qk_dim       per-head feature dim (optional TP fallback)
#   kv_seq       decode-cache sequence dim (optional TP; serving)
#   attn_q_seq   per-chunk query rows (optional TP; seq-parallel attention)
#   mlp          FFN hidden dim
#   experts      MoE expert dim (expert parallelism)
#   vocab        embedding/vocab rows
#   ssm_inner    mamba inner channels
#   ssm_state    SSM state dim (never sharded)
#   layers       stacked-scan leading layer dim (never sharded)
#   circuits     LASANA circuit instance dim (pure data parallel)
#   features     LASANA feature dim
# ---------------------------------------------------------------------------

LogicalAxis = str | None
LogicalSpec = tuple[LogicalAxis, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, str | tuple[str, ...] | None]

    def mesh_axes(self, logical: LogicalAxis):
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def spec(self, logical_spec: Sequence[LogicalAxis]) -> P:
        """Translate a logical spec into a PartitionSpec, dropping conflicts.

        A mesh axis may appear at most once in a PartitionSpec; later logical
        axes that would reuse an already-consumed mesh axis degrade to
        replicated (standard GSPMD rule resolution).
        """
        used: set[str] = set()
        out = []
        for logical in logical_spec:
            axes = self.mesh_axes(logical)
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            keep = tuple(a for a in axes if a not in used)
            if not keep:
                out.append(None)
                continue
            used.update(keep)
            out.append(keep if len(keep) > 1 else keep[0])
        # Trim trailing Nones (canonical form).
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def spec_for_shape(self, mesh: Mesh, logical_spec: Sequence[LogicalAxis],
                       shape: Sequence[int]) -> P:
        """Like ``spec`` but drops mesh axes that do not divide the dim.

        GSPMD requires every explicitly-sharded dim to be divisible by the
        product of its mesh axes; small dims (kv_heads=2 on a 16-way model
        axis, batch=1 decode) degrade gracefully to replicated.
        """
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used: set[str] = set()
        out = []
        for logical, dim in zip(logical_spec, shape):
            axes = self.mesh_axes(logical)
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            keep: list[str] = []
            prod = 1
            for a in axes:
                if a in used:
                    continue
                if dim % (prod * sizes[a]) == 0:
                    keep.append(a)
                    prod *= sizes[a]
            if not keep:
                out.append(None)
                continue
            used.update(keep)
            out.append(tuple(keep) if len(keep) > 1 else keep[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, mesh: Mesh, logical_spec: Sequence[LogicalAxis],
                 shape: Sequence[int] | None = None) -> NamedSharding:
        if shape is not None:
            return NamedSharding(mesh, self.spec_for_shape(mesh, logical_spec, shape))
        return NamedSharding(mesh, self.spec(logical_spec))


# --- Canonical rule tables --------------------------------------------------

def train_rules(mesh: Mesh, *, fsdp: bool = True, shard_seq: bool = False,
                qk_dim_fallback: bool = False,
                seq_parallel_attn: bool = False,
                kv_seq_sharding: bool = False) -> ShardingRules:
    """Rules for training on a ('pod','data','model') or ('data','model') mesh.

    - activations: batch over (pod, data); optionally seq over data
      (context parallelism, used when batch < data axis size).
    - params: TP over 'model' on heads/mlp/experts/vocab; FSDP over
      ('pod','data') on the embed dim when ``fsdp``.
    - ``qk_dim_fallback``: shard head_dim over TP when head counts don't
      divide the model axis. Measured in EXPERIMENTS §Perf: cuts attention
      compute 4.7x but all-reduces fp32 (S,T) logits every chunk — wire cost
      explodes 40x. Kept as a switch for the perf log; OFF by default.
    - ``seq_parallel_attn``: shard the *query sequence* of attention over the
      model axis instead (each TP shard owns S/tp queries against the full
      K/V). Used by the hillclimbed configs for head counts that don't
      divide TP.
    """
    axes = mesh.axis_names
    dp: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    rules: dict[str, Any] = {
        "batch": dp if not shard_seq else dp,
        "seq": dp if shard_seq else None,
        "embed": dp if fsdp else None,
        "heads": tp,
        "kv_heads": tp,
        "qk_dim": tp if qk_dim_fallback else None,
        "attn_q_seq": tp if seq_parallel_attn else None,
        # decode caches: shard the KV sequence dim over TP. GQA kv-head
        # counts (1-8) never divide a 16-way model axis, so head-sharding
        # degrades to replication; seq-sharding divides the whole cache and
        # the per-step attention reduction (softmax stats all-reduce).
        "kv_seq": tp if kv_seq_sharding else None,
        "mlp": tp,
        "experts": tp,
        "vocab": tp,
        "ssm_inner": tp,
        "circuits": dp + ((tp,) if tp else ()),
        "features": None,
    }
    return ShardingRules(rules=rules)


def serve_rules(mesh: Mesh, *, kv_seq_sharding: bool = False) -> ShardingRules:
    """Decode rules: caches shard batch over dp; optionally seq over tp."""
    return train_rules(mesh, fsdp=True, shard_seq=False,
                       kv_seq_sharding=kv_seq_sharding)


# --- Pytree annotation helpers ----------------------------------------------

@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Logical:
    """A static marker carried alongside arrays: its logical PartitionSpec."""

    spec: LogicalSpec

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Logical{self.spec}"


def logical_to_sharding(tree_of_logical, mesh: Mesh, rules: ShardingRules):
    """Map a pytree of Logical markers to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda l: rules.sharding(mesh, l.spec),
        tree_of_logical,
        is_leaf=lambda x: isinstance(x, Logical),
    )


def logical_like(tree_of_arrays, tree_of_logical):
    """Structural zip check: every array leaf has a Logical partner."""
    arr_leaves = jax.tree.leaves(tree_of_arrays)
    log_leaves = jax.tree.leaves(
        tree_of_logical, is_leaf=lambda x: isinstance(x, Logical)
    )
    if len(arr_leaves) != len(log_leaves):
        raise ValueError(
            f"array tree has {len(arr_leaves)} leaves but logical tree has "
            f"{len(log_leaves)}"
        )
    return True


def constraint(x, mesh: Mesh, rules: ShardingRules, logical_spec: Sequence[LogicalAxis]):
    """with_sharding_constraint via logical names (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.sharding(mesh, logical_spec))
    except (ValueError, RuntimeError):
        return x


def num_devices(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
