"""Fault tolerance: watchdog, failure-injection restart, elastic re-mesh.

Multi-device behaviour runs in subprocesses (forcing host device counts must
happen before jax initializes)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ft.watchdog import StepWatchdog

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_watchdog_flags_straggler():
    import time
    wd = StepWatchdog(threshold=2.0, hang_timeout=1e9)
    for _ in range(5):
        wd.step_begin()
        time.sleep(0.01)
        wd.step_end(0)
    wd.step_begin()
    time.sleep(0.1)
    out = wd.step_end(5)
    assert out["straggler"]
    assert wd.stragglers == 1


def _run_train(tmp, devices, extra):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "starcoder2-3b", "--reduced", "--batch", "8", "--seq", "32",
           "--ckpt-dir", str(tmp), "--ckpt-every", "4", "--log-every", "2",
           "--warmup", "2"] + extra
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=_ROOT, timeout=600)


@pytest.mark.slow
def test_failure_restart_and_elastic_resume(tmp_path):
    # run on 8 devices, crash at step 6 (after the step-4 checkpoint)
    r1 = _run_train(tmp_path, 8, ["--steps", "10", "--fail-at-step", "6",
                                  "--model-parallel", "2"])
    assert "injected failure" in (r1.stderr + r1.stdout)
    # resume on 4 devices (pod loss): must pick up from step 4
    r2 = _run_train(tmp_path, 4, ["--steps", "10", "--model-parallel", "2"])
    out = r2.stdout + r2.stderr
    assert "resumed from step 4" in out, out
    assert "done" in out
