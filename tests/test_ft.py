"""Fault tolerance: watchdog, failure-injection restart, elastic re-mesh.

Multi-device behaviour runs in subprocesses (forcing host device counts must
happen before jax initializes)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ft.watchdog import StepWatchdog

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_watchdog_flags_straggler():
    import time
    wd = StepWatchdog(threshold=2.0, hang_timeout=1e9)
    for _ in range(5):
        wd.step_begin()
        time.sleep(0.01)
        wd.step_end(0)
    wd.step_begin()
    time.sleep(0.1)
    out = wd.step_end(5)
    assert out["straggler"]
    assert wd.stragglers == 1


def test_watchdog_uses_monotonic_clock(monkeypatch):
    """A wall-clock jump must not corrupt timing: the watchdog never
    reads time.time() (NTP slew / manual reset immunity)."""
    import time

    def _wall_clock_banned():
        raise AssertionError("watchdog read time.time()")

    monkeypatch.setattr(time, "time", _wall_clock_banned)
    wd = StepWatchdog(hang_timeout=1e9)
    wd.step_begin()
    out = wd.step_end(0)
    assert out["step_seconds"] >= 0.0


def test_watchdog_hang_fires_once_for_real_hang():
    import time
    fired = []
    wd = StepWatchdog(hang_timeout=0.02, on_hang=lambda: fired.append(1))
    wd.step_begin()
    time.sleep(0.15)                 # step genuinely overruns the limit
    assert fired == [1]
    assert wd.hangs == 1
    wd.step_end(0)                   # completion after the fire is fine


def test_watchdog_never_fires_after_completion():
    """The step_end/timer race: a timer thread already past its wait when
    cancel lands must still see the step closed (generation + open flag
    re-checked under the lock) and stay silent."""
    import time
    fired = []
    wd = StepWatchdog(hang_timeout=60.0, on_hang=lambda: fired.append(1))
    wd.step_begin()
    gen = wd._gen
    wd.step_end(0)
    # simulate the losing timer thread firing after cancel was too late
    wd._fire(gen)
    assert fired == [] and wd.hangs == 0
    # a stale generation must also be inert while a NEW step is open
    wd.step_begin()
    wd._fire(gen)                    # old gen, new step in flight
    assert fired == [] and wd.hangs == 0
    wd.step_end(1)


def _run_train(tmp, devices, extra):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "starcoder2-3b", "--reduced", "--batch", "8", "--seq", "32",
           "--ckpt-dir", str(tmp), "--ckpt-every", "4", "--log-every", "2",
           "--warmup", "2"] + extra
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=_ROOT, timeout=600)


@pytest.mark.slow
def test_failure_restart_and_elastic_resume(tmp_path):
    # run on 8 devices, crash at step 6 (after the step-4 checkpoint)
    r1 = _run_train(tmp_path, 8, ["--steps", "10", "--fail-at-step", "6",
                                  "--model-parallel", "2"])
    assert "injected failure" in (r1.stderr + r1.stdout)
    # resume on 4 devices (pod loss): must pick up from step 4
    r2 = _run_train(tmp_path, 4, ["--steps", "10", "--model-parallel", "2"])
    out = r2.stdout + r2.stderr
    assert "resumed from step 4" in out, out
    assert "done" in out
