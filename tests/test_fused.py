"""Fused inference hot path (ISSUE-5): ``Surrogate.predict_heads`` and the
fused ``lasana_step`` must reproduce the per-``predict``-call formulation.

Documented tolerance: stacked same-family head evaluation batches several
heads into one einsum, which reorders float reductions — fused results may
differ from per-call results by a few ULPs (observed <= ~1e-6 relative on
CPU XLA). Single-head groups reuse the exact per-head functions and are
asserted BIT-identical. Network-level: spike decisions threshold far from
the ULP scale, so fused and unfused runs must agree exactly on discrete
records (outputs, events) and to rtol=1e-5 on energy/latency.

Determinism caveat (why exact asserts are safe here): everything is
seeded and jax is pinned, and the workloads sit away from the two
discontinuities — nearest-neighbor ties in stacked table heads and
spike thresholds within ULPs of o_hat — where the reassociation could
amplify into a whole-entry / whole-spike difference. If these asserts
trip after a jax upgrade, check those edges before suspecting the fused
implementation (see docs/architecture.md, "Inference hot path").
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.circuits import LIFNeuron
from repro.core.surrogate import (ALG1_HEADS, FORMAT_VERSION, Manifest,
                                  Surrogate, _augment)
from repro.core.wrapper import (_features, _splice_transition, init_state,
                                lasana_step)

# documented fused-vs-percall tolerance (see module docstring)
RTOL = 1e-5
ATOL = 1e-7

N_IN, N_P = 3, 4                    # lif raw interface dims
F_RAW = N_IN + 1 + 1 + N_P          # x, v, tau, params
F_AUG = F_RAW + 1                   # + lif derived drive column
F_TR = F_AUG + 2                    # + o_prev, o_new (augmented order)


def _mk_mlp(rng, f, hidden=(24, 12)):
    dims = (f, *hidden, 1)
    a = {}
    for i in range(len(dims) - 1):
        a[f"w{i}"] = rng.normal(size=(dims[i], dims[i + 1])).astype(np.float32)
        a[f"b{i}"] = rng.normal(size=(dims[i + 1],)).astype(np.float32)
    a.update(x_mu=rng.normal(size=(f,)).astype(np.float32),
             x_sd=(1 + rng.random(f)).astype(np.float32),
             y_mu=rng.normal(size=(1,)).astype(np.float32),
             y_sd=(1 + rng.random(1)).astype(np.float32))
    return a


def _mk_linear(rng, f):
    return {"w": rng.normal(size=(f + 1,)).astype(np.float32),
            "mu": rng.normal(size=(f,)).astype(np.float32),
            "sd": (1 + rng.random(f)).astype(np.float32)}


def _mk_table(rng, f, rows=32):
    return {"tx": rng.normal(size=(rows, f)).astype(np.float32),
            "ty": rng.normal(size=(rows,)).astype(np.float32),
            "mu": rng.normal(size=(f,)).astype(np.float32),
            "sd": (1 + rng.random(f)).astype(np.float32)}


def _mk_gbdt(rng, f, n_trees=3, depth=2):
    nodes, leaves = 2 ** depth - 1, 2 ** depth
    return {"feat": rng.integers(0, f, (n_trees, nodes)).astype(np.int32),
            "thr": rng.normal(size=(n_trees, nodes)).astype(np.float32),
            "leaf": rng.normal(size=(n_trees, leaves)).astype(np.float32),
            "base": np.float32(rng.normal())}


def _mk_mean(rng, f):
    return {"mu": np.float32(rng.normal())}


MAKERS = {"mlp": _mk_mlp, "linear": _mk_linear, "table": _mk_table,
          "gbdt": _mk_gbdt, "mean": _mk_mean}

# transition-aware heads see the two extra output columns
_HEAD_DIMS = {"M_O": F_AUG, "M_V": F_AUG, "M_ES": F_AUG,
              "M_ED": F_TR, "M_L": F_TR}


def _make_surrogate(family_per_predictor: dict, seed=0) -> Surrogate:
    """Synthetic lif Surrogate — inference parity needs arrays, not MSE."""
    rng = np.random.default_rng(seed)
    params = {p: {k: jnp.asarray(v) for k, v in
                  MAKERS[fam](rng, _HEAD_DIMS[p]).items()}
              for p, fam in family_per_predictor.items()}
    manifest = Manifest(
        circuit="lif", format_version=FORMAT_VERSION,
        families=tuple(sorted(family_per_predictor.items())),
        scales=tuple(sorted((p, 1e15 if p.startswith("M_E") else 1.0)
                            for p in family_per_predictor)),
        features=())
    return Surrogate(manifest=manifest, params=params)


def _variant_feats(seed=1, n=41):
    """Raw (un-augmented) idle/act/tr matrices with consistent columns."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, N_IN)).astype(np.float32)
    v = rng.normal(size=(n,)).astype(np.float32)
    p = rng.normal(size=(n, N_P)).astype(np.float32)
    tau_i = rng.random(n).astype(np.float32) * 40
    o_prev = rng.normal(size=(n,)).astype(np.float32)
    o_new = rng.normal(size=(n,)).astype(np.float32)
    idle = _features(np.zeros_like(x), v, tau_i, p)
    act = _features(x, v, np.full((n,), 5.0, np.float32), p)
    tr = _features(x, v, np.full((n,), 5.0, np.float32), p,
                   o_prev=o_prev, o_new=o_new)
    return jnp.asarray(idle), jnp.asarray(act), jnp.asarray(tr)


ALL_FAMILY_ASSIGNMENTS = [
    # every predictor on one family each — covers all five families, and
    # every stacked group has >= 2 members somewhere across the variants
    {"M_O": "mlp", "M_V": "mlp", "M_ES": "mlp", "M_ED": "mlp", "M_L": "mlp"},
    {"M_O": "linear", "M_V": "linear", "M_ES": "linear",
     "M_ED": "linear", "M_L": "linear"},
    {"M_O": "table", "M_V": "table", "M_ES": "table",
     "M_ED": "table", "M_L": "table"},
    {"M_O": "mean", "M_V": "mean", "M_ES": "mean",
     "M_ED": "mean", "M_L": "mean"},
    {"M_O": "gbdt", "M_V": "gbdt", "M_ES": "gbdt",
     "M_ED": "gbdt", "M_L": "gbdt"},
    # mixed: one of each family in a single surrogate
    {"M_O": "mlp", "M_V": "linear", "M_ES": "table",
     "M_ED": "gbdt", "M_L": "mean"},
]


@pytest.mark.parametrize("fams", ALL_FAMILY_ASSIGNMENTS,
                         ids=lambda f: "-".join(sorted(set(f.values()))))
def test_predict_heads_matches_predict_all_families(fams):
    """Fused output == per-call predict for every head on every variant
    (documented tolerance; gbdt/mean and single-head groups bit-exact)."""
    sur = _make_surrogate(fams)
    fi, fa, ftr = _variant_feats()
    out = sur.predict_heads(fi, fa, ftr)
    assert set(out) == {"idle", "act", "tr"}
    for variant, mat in (("idle", fi), ("act", fa), ("tr", ftr)):
        assert set(out[variant]) == set(ALG1_HEADS[variant])
        for pname in out[variant]:
            ref = np.asarray(sur.predict(pname, mat))
            got = np.asarray(out[variant][pname])
            np.testing.assert_allclose(
                got, ref, rtol=RTOL, atol=ATOL,
                err_msg=f"{variant}/{pname} ({fams[pname]})")


def test_predict_heads_single_head_groups_bit_identical():
    """A group of one bypasses stacking entirely -> bit-identical."""
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[-1])   # one family each
    fi, fa, ftr = _variant_feats(seed=3)
    out = sur.predict_heads(fi, fa, ftr)
    for variant, mat in (("idle", fi), ("act", fa), ("tr", ftr)):
        for pname in out[variant]:
            np.testing.assert_array_equal(
                np.asarray(out[variant][pname]),
                np.asarray(sur.predict(pname, mat)),
                err_msg=f"{variant}/{pname}")


def test_predict_heads_annotation_schedule():
    """The annotation-mode subset (no M_O/M_V) evaluates exactly the
    requested heads — nothing more."""
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[0])
    fi, fa, ftr = _variant_feats(seed=4)
    out = sur.predict_heads(
        fi, fa, ftr,
        heads={"idle": ("M_ES",), "act": ("M_ES",), "tr": ("M_ED", "M_L")})
    assert set(out["idle"]) == {"M_ES"}
    assert set(out["act"]) == {"M_ES"}
    assert set(out["tr"]) == {"M_ED", "M_L"}
    np.testing.assert_allclose(np.asarray(out["act"]["M_ES"]),
                               np.asarray(sur.predict("M_ES", fa)),
                               rtol=RTOL, atol=ATOL)


def test_predict_heads_augmented_passthrough_and_splice():
    """Pre-augmented matrices skip re-augmentation, and the wrapper's
    transition column splice equals building + augmenting from scratch."""
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[0])
    fi, fa, ftr = _variant_feats(seed=5)
    aug_act = _augment("lif", fa)
    o_prev, o_new = ftr[:, F_RAW], ftr[:, F_RAW + 1]
    spliced = _splice_transition(aug_act, F_RAW, o_prev, o_new)
    np.testing.assert_array_equal(np.asarray(spliced),
                                  np.asarray(_augment("lif", ftr)))
    a = sur.predict_heads(feats_act=fa, heads={"act": ("M_O",)})
    b = sur.predict_heads(feats_act=aug_act, heads={"act": ("M_O",)},
                          augmented=True)
    np.testing.assert_array_equal(np.asarray(a["act"]["M_O"]),
                                  np.asarray(b["act"]["M_O"]))


def test_predict_heads_misuse_raises():
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[0])
    fi, fa, _ = _variant_feats(seed=6)
    with pytest.raises(ValueError, match="at least one"):
        sur.predict_heads()
    with pytest.raises(ValueError, match="unknown predictor"):
        sur.predict_heads(feats_idle=fi, heads={"idle": ("M_NOPE",)})
    with pytest.raises(ValueError, match="no matching feature"):
        sur.predict_heads(feats_idle=fi, heads={"act": ("M_O",)})


@pytest.mark.parametrize("spiking", [True, False])
def test_lasana_step_fused_matches_percall(spiking):
    """One full Algorithm-1 tick: fused vs per-call within tolerance."""
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[0], seed=8)
    circ = LIFNeuron()
    key = jax.random.PRNGKey(11)
    n = 24
    k1, k2, k3 = jax.random.split(key, 3)
    params = circ.sample_params(k1, n)
    state = init_state(n, params)._replace(
        v=jax.random.uniform(k2, (n,), jnp.float32, 0.0, 1.2),
        t_last=jnp.asarray(np.random.default_rng(0)
                           .choice([0.0, 5.0, 15.0], n).astype(np.float32)))
    changed = jax.random.bernoulli(k3, 0.7, (n,))
    x = circ.sample_inputs(k3, (n,))
    out_f = lasana_step(sur, state, changed, x, 20.0, 5.0, spiking=spiking,
                        fused=True)
    out_u = lasana_step(sur, state, changed, x, 20.0, 5.0, spiking=spiking,
                        fused=False)
    for a, b, name in zip(out_f, out_u, ("state", "e", "l", "o")):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        for xa, xb in zip(la, lb):
            np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                                       rtol=RTOL, atol=ATOL, err_msg=name)


def test_lasana_step_fused_annotation_single_dispatch_matches():
    """Annotation mode (the one-dispatch schedule) vs per-call."""
    sur = _make_surrogate(ALL_FAMILY_ASSIGNMENTS[0], seed=9)
    circ = LIFNeuron()
    key = jax.random.PRNGKey(13)
    n = 16
    params = circ.sample_params(key, n)
    state = init_state(n, params)._replace(
        t_last=jnp.full((n,), 5.0), v=jnp.linspace(0, 1, n))
    changed = jnp.ones((n,), bool)
    x = circ.sample_inputs(key, (n,))
    known = jnp.where(jax.random.bernoulli(key, 0.5, (n,)), 1.5, 0.0)
    out_f = lasana_step(sur, state, changed, x, 25.0, 5.0, spiking=True,
                        known_out=known, fused=True)
    out_u = lasana_step(sur, state, changed, x, 25.0, 5.0, spiking=True,
                        known_out=known, fused=False)
    for a, b in zip(jax.tree.leaves(out_f), jax.tree.leaves(out_u)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=RTOL, atol=ATOL)


def test_legacy_bank_without_predict_heads_still_steps(lif_bank):
    """Duck-typed PredictorBank (no predict_heads) silently takes the
    per-call path even with fused=True — no hard requirement on the new
    method for legacy callers."""
    circ = LIFNeuron()
    key = jax.random.PRNGKey(5)
    n = 8
    params = circ.sample_params(key, n)
    state = init_state(n, params)
    changed = jnp.ones((n,), bool)
    x = circ.sample_inputs(key, (n,))
    out_default = lasana_step(lif_bank, state, changed, x, 5.0, 5.0)
    out_percall = lasana_step(lif_bank, state, changed, x, 5.0, 5.0,
                              fused=False)
    for a, b in zip(jax.tree.leaves(out_default),
                    jax.tree.leaves(out_percall)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- network level ------------------------------------------------------------

def _small_net(seed=0, layers=(12, 8, 4)):
    rng = np.random.default_rng(seed)
    ws = [(rng.normal(0, (2.0 / a) ** 0.5, (a, b)) * 2.2).astype(np.float32)
          for a, b in zip(layers[:-1], layers[1:])]
    params = [np.array([0.58, 0.5, 0.5, 0.5], np.float32) for _ in ws]
    spikes = (rng.random((20, 2, layers[0])) < 0.3).astype(np.float32) * 1.5
    return ws, params, spikes


@pytest.mark.parametrize("mode", ["standalone", "annotation"])
def test_network_fused_vs_unfused_parity(lif_bank, mode):
    """Whole-network records: discrete outputs/events identical, analog
    energy/latency within the documented tolerance, both modes."""
    from repro.core.network import NetworkEngine, snn_spec
    sur = lif_bank.to_surrogate()
    ws, params, spikes = _small_net()
    spec = snn_spec(ws, params)
    run_f = NetworkEngine(spec, surrogates=sur, mode=mode).run(spikes)
    run_u = NetworkEngine(spec, surrogates=sur, mode=mode,
                          fused=False).run(spikes)
    np.testing.assert_array_equal(run_f.outputs, run_u.outputs)
    np.testing.assert_array_equal(run_f.events, run_u.events)
    np.testing.assert_array_equal(run_f.out_spikes, run_u.out_spikes)
    np.testing.assert_allclose(run_f.energy, run_u.energy,
                               rtol=RTOL, atol=1e-20)
    np.testing.assert_allclose(run_f.latency, run_u.latency,
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(run_f.flush_energy, run_u.flush_energy,
                               rtol=RTOL, atol=1e-20)


def test_streaming_fused_bit_identical_to_monolithic_fused(lif_bank):
    """The ISSUE-4 streaming contract must survive fusion: chunked fused
    runs stay BIT-identical to the monolithic fused run."""
    from repro.core.network import NetworkEngine, snn_spec
    sur = lif_bank.to_surrogate()
    ws, params, spikes = _small_net(seed=2)
    spec = snn_spec(ws, params)
    eng = NetworkEngine(spec, surrogates=sur, record_hidden=True)
    mono = eng.run(spikes)
    stream = eng.run_stream(spikes, chunk_ticks=7)   # 20 % 7 != 0
    np.testing.assert_array_equal(mono.outputs, stream.outputs)
    np.testing.assert_array_equal(mono.energy, stream.energy)
    np.testing.assert_array_equal(mono.events, stream.events)
    np.testing.assert_array_equal(mono.flush_energy, stream.flush_energy)


def test_fused_zero_recompile_hot_swap(lif_bank, lif_dataset):
    """Surrogate hot-swap through one compiled FUSED program: stacking
    happens inside the traced fn from existing pytree leaves, so swapping
    retrained weights is still zero recompiles."""
    from repro.core.network import NetworkEngine, snn_spec
    from repro.core.predictors import PredictorBank
    sur = lif_bank.to_surrogate()
    sur2 = PredictorBank("lif", families=("mean", "linear")) \
        .fit(lif_dataset).to_surrogate()
    ws, params, spikes = _small_net(seed=3)
    spec = snn_spec(ws, params)
    eng = NetworkEngine(spec, surrogates=sur)
    assert eng.fused
    eng.run(spikes)
    assert eng.compile_count == 1
    eng.run(spikes, surrogates=sur2)
    assert eng.compile_count == 1        # weight swap, not a recompile
    # the unfused baseline is a DIFFERENT program (separate cache key)
    eng_u = NetworkEngine(spec, surrogates=sur, fused=False)
    eng_u.run(spikes)
    assert eng_u.compile_count == 1
