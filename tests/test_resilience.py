"""Deterministic fault injection + end-to-end recovery (ISSUE-10).

Acceptance properties:

  * fault plans are replayable: firing is a pure function of (seed,
    per-site invocation ordinal) — never wall clock or interleaving —
    and round-trips through JSON;
  * stream checkpoint/resume: a run killed at a chunk-boundary
    checkpoint and resumed on a FRESH engine merges bit-identical to the
    uninterrupted monolithic run (discrete records bitwise, energy
    rtol 1e-5) with ZERO extra compiled programs on a warm engine;
  * serve deadlines: an expired request fails fast with
    ``DeadlineExceeded`` from the queue — it never occupies a slot;
  * serve retries: a lane-step fault or NaN/Inf quarantine requeues the
    request with backoff; a retried request replays from scratch, so its
    merged record still matches a solo run exactly, and co-tenants of a
    quarantined request keep records bitwise identical to solo;
  * graceful degradation: after ``degrade_after`` surrogate faults on a
    spec, new admissions serve on the behavioral backend, flagged
    ``degraded`` on the handle and in ``/stats``;
  * watchdog: a lane step hung past ``hang_timeout_s`` fails only its
    own requests while the server keeps serving;
  * artifact quarantine: a corrupt on-disk surrogate fails only the
    requesting caller, with ``ArtifactError`` naming ``name@version``
    and the path.

Every test pins its own plan via ``faults.use_plan`` (shadowing any
ambient ``REPRO_FAULT_PLAN``), so this file behaves identically under
tier-1 and under the CI faults leg; the final sentinel test drives the
canned CI plan (or the ambient env plan when one is set) through a
workload that fires EVERY site at least once.
"""

import math
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.lasana as lasana
from repro.core.network import snn_spec
from repro.resilience import (FAULT_SITES, FaultInjected, FaultPlan,
                              SiteSchedule, StreamCheckpoint, faults)
from repro.serve import (ArtifactError, DeadlineExceeded, ServeConfig,
                         SimServer)

CHUNK = 8
PARAMS = [0.58, 0.5, 0.5, 0.5]
_CI_PLAN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "data", "fault_plan_ci.json")


def _make_spec(seed=0):
    k1, k2 = jax.random.PRNGKey(seed), jax.random.PRNGKey(seed + 100)
    w1 = jax.random.normal(k1, (12, 8)) * 0.8
    w2 = jax.random.normal(k2, (8, 4)) * 0.8
    return snn_spec([w1, w2], [jnp.asarray(PARAMS)] * 2)


def _stim(rng, t, b, n_in=12, rate=0.2, amp=1.5):
    return (rng.random((t, b, n_in)) < rate).astype(np.float32) * amp


def _assert_runs_equal(a, b, *, energy_rtol=1e-5):
    np.testing.assert_array_equal(a.outputs, b.outputs)
    np.testing.assert_array_equal(a.events, b.events)
    if a.out_spikes is not None:
        np.testing.assert_array_equal(a.out_spikes, b.out_spikes)
    np.testing.assert_allclose(a.energy, b.energy, rtol=energy_rtol,
                               atol=0)
    np.testing.assert_allclose(a.latency, b.latency, rtol=energy_rtol,
                               atol=1e-6)
    np.testing.assert_allclose(a.flush_energy, b.flush_energy,
                               rtol=energy_rtol, atol=0)


@pytest.fixture(scope="module")
def lif_surrogate(lif_bank):
    return lif_bank.to_surrogate()


@pytest.fixture(scope="module")
def shared_spec():
    return _make_spec(0)


@pytest.fixture(autouse=True)
def _no_ambient_faults():
    """Isolation: each test opts into its own plan; the ambient env plan
    (CI faults leg) is consumed only by the sentinel test below."""
    with faults.use_plan(None):
        yield


# --- fault-plan semantics -----------------------------------------------------

def test_plan_fires_are_deterministic():
    def pattern(seed):
        plan = FaultPlan(seed, {"lane.step": {"rate": 0.3},
                                "chunk.stall": {"at": [2, 5]}})
        return ([plan.should_fire("lane.step") for _ in range(50)],
                [plan.should_fire("chunk.stall") for _ in range(8)])
    lane_a, stall_a = pattern(7)
    lane_b, stall_b = pattern(7)
    assert lane_a == lane_b and any(lane_a) and not all(lane_a)
    assert stall_a == stall_b
    assert [i for i, f in enumerate(stall_a) if f] == [2, 5]
    lane_c, _ = pattern(8)
    assert lane_c != lane_a                 # seed actually matters


def test_plan_at_hits_never_shift_the_rate_stream():
    """The rate draw is consumed unconditionally, so adding explicit
    'at' indices cannot change which OTHER ordinals rate-fire."""
    base = FaultPlan(3, {"lane.step": {"rate": 0.2}})
    with_at = FaultPlan(3, {"lane.step": {"rate": 0.2, "at": [0]}})
    a = [base.should_fire("lane.step") for _ in range(40)]
    b = [with_at.should_fire("lane.step") for _ in range(40)]
    assert b[0] and a[1:] == b[1:]


def test_plan_max_fires_bounds_disruption():
    plan = FaultPlan(0, {"chunk.stall": {"rate": 1.0, "max_fires": 2}})
    fires = [plan.should_fire("chunk.stall") for _ in range(10)]
    assert sum(fires) == 2 and fires[:2] == [True, True]


def test_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(11, {"surrogate.nan": {"at": [1], "rate": 0.5,
                                            "max_fires": 4}},
                     stall_seconds=0.5)
    path = plan.save(str(tmp_path / "plan.json"))
    back = FaultPlan.load(path)
    assert back.seed == 11 and back.stall_seconds == 0.5
    assert back.sites["surrogate.nan"] == SiteSchedule(
        at=(1,), rate=0.5, max_fires=4)
    a = [plan.should_fire("surrogate.nan") for _ in range(30)]
    b = [back.should_fire("surrogate.nan") for _ in range(30)]
    assert a == b


def test_plan_rejects_unknown_site_and_newer_format():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(0, {"lane.stepp": {"rate": 0.1}})
    with pytest.raises(ValueError, match="newer than supported"):
        FaultPlan.from_json({"format_version": 99, "seed": 0})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(0, {}).should_fire("not.a.site")


def test_hooks_are_noops_without_a_plan():
    assert faults.active_plan() is None     # autouse fixture pins None
    assert not faults.should_fire("lane.step")
    faults.check("lane.step")               # no raise
    assert faults.stall() == 0.0
    assert faults.draw("surrogate.nan") == 0.0


# --- stream checkpoint / resume -----------------------------------------------

def test_checkpoint_resume_bit_identical(lif_surrogate, shared_spec,
                                         tmp_path):
    """Kill-and-resume == uninterrupted run, with zero extra compiles.

    A stream with ``checkpoint_every`` attaches carry snapshots at chunk
    boundaries; cutting the run at EVERY available checkpoint and
    resuming on a fresh engine must merge bit-identical to the
    monolithic record (energy rtol 1e-5 for the float sums), and the
    resumed tail re-chunks onto the same compiled stream program."""
    rng = np.random.default_rng(42)
    x = _stim(rng, 26, 3)
    full = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    chunks = list(lasana.stream(shared_spec, x, surrogates=lif_surrogate,
                                chunk_ticks=CHUNK, checkpoint_every=1))
    assert len(chunks) == math.ceil(26 / CHUNK)
    ckpts = [c.checkpoint for c in chunks]
    assert all(c is not None for c in ckpts[:-1])
    assert ckpts[-1] is None                # flush chunk never checkpoints
    for i, ckpt in enumerate(ckpts[:-1]):
        assert ckpt.k0 == (i + 1) * CHUNK
        path = str(tmp_path / f"ck{i}.npz")
        ckpt.save(path)
        resumed = lasana.resume(path, shared_spec, x,
                                surrogates=lif_surrogate)
        _assert_runs_equal(full, resumed)
    eng = lasana.engine(shared_spec, record_hidden=False)
    before = eng.compile_count
    again = lasana.resume(ckpts[0], shared_spec, x,
                          surrogates=lif_surrogate)
    _assert_runs_equal(full, again)
    assert eng.compile_count == before      # ZERO extra compiled programs


def test_checkpoint_verifies_engine_and_shapes(lif_surrogate, shared_spec,
                                               tmp_path):
    rng = np.random.default_rng(5)
    x = _stim(rng, 16, 2)
    chunks = list(lasana.stream(shared_spec, x, surrogates=lif_surrogate,
                                chunk_ticks=CHUNK, checkpoint_every=1))
    ckpt = chunks[0].checkpoint
    with pytest.raises(ValueError, match="spec"):
        lasana.resume(ckpt, _make_spec(9), x, surrogates=lif_surrogate)
    path = str(tmp_path / "ck")
    ckpt.save(path)
    loaded = StreamCheckpoint.load(path)    # extension-optional
    assert loaded.k0 == ckpt.k0 and loaded.backend == ckpt.backend
    with pytest.raises(FileNotFoundError):
        StreamCheckpoint.load(str(tmp_path / "missing"))


def test_stream_completes_under_stall_faults(lif_surrogate, shared_spec):
    """chunk.stall only slows chunks; records stay bit-identical."""
    rng = np.random.default_rng(6)
    x = _stim(rng, 16, 2)
    clean = lasana.simulate_stream(shared_spec, x,
                                   surrogates=lif_surrogate,
                                   chunk_ticks=CHUNK)
    plan = FaultPlan(0, {"chunk.stall": {"rate": 1.0, "max_fires": 2}},
                     stall_seconds=0.01)
    with faults.use_plan(plan):
        stalled = lasana.simulate_stream(shared_spec, x,
                                         surrogates=lif_surrogate,
                                         chunk_ticks=CHUNK)
    assert plan.fired["chunk.stall"] == 2
    _assert_runs_equal(clean, stalled, energy_rtol=0)


# --- serve deadlines ----------------------------------------------------------

def test_deadline_expired_fails_fast_without_a_slot(lif_surrogate,
                                                    shared_spec):
    import time
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    h = srv.submit(shared_spec, _stim(np.random.default_rng(0), 8, 1),
                   surrogates=lif_surrogate, deadline_ms=1.0)
    time.sleep(0.02)                        # expire while still queued
    srv.step()
    with pytest.raises(DeadlineExceeded):
        h.result(timeout=5)
    stats = srv.stats()
    assert stats["requests_deadline_exceeded"] == 1
    assert stats["requests_failed"] == 1
    assert stats["requests_in_flight"] == 0
    assert stats["requests_completed"] == 0
    assert srv.compile_count() == 0         # never seated, never compiled


def test_deadline_validation(lif_surrogate, shared_spec):
    srv = SimServer()
    with pytest.raises(ValueError, match="deadline_ms"):
        srv.submit(shared_spec, np.zeros((2, 1, 12), np.float32),
                   surrogates=lif_surrogate, deadline_ms=-5)


# --- serve retries + quarantine -----------------------------------------------

def test_lane_step_fault_retries_and_recovers(lif_surrogate, shared_spec):
    """One injected lane-step failure: the request is requeued with
    backoff, replays on a fresh lane (no recompile — programs are cached
    on the engine), and its record still matches the solo run."""
    rng = np.random.default_rng(8)
    x = _stim(rng, 12, 2)
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    plan = FaultPlan(0, {"lane.step": {"at": [0]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_retries=2, retry_backoff_ms=1.0))
    with faults.use_plan(plan):
        h = srv.submit(shared_spec, x, surrogates=lif_surrogate)
        srv.run_until_idle()
    assert plan.fired["lane.step"] == 1
    _assert_runs_equal(solo, h.result())
    stats = srv.stats()
    assert stats["requests_retried"] == 1
    assert stats["requests_completed"] == 1
    assert stats["requests_failed"] == 0
    assert stats["requests_in_flight"] == 0
    assert h.attempts == 2


def test_lane_step_fault_without_retries_fails_request(lif_surrogate,
                                                       shared_spec):
    plan = FaultPlan(0, {"lane.step": {"at": [0]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_retries=0))
    with faults.use_plan(plan):
        h = srv.submit(shared_spec,
                       _stim(np.random.default_rng(9), 8, 1),
                       surrogates=lif_surrogate)
        srv.run_until_idle()
    with pytest.raises(FaultInjected):
        h.result(timeout=5)
    assert srv.stats()["requests_in_flight"] == 0


def test_nan_quarantine_spares_cotenants(lif_surrogate, shared_spec):
    """A NaN/Inf burst in one request's head outputs quarantines ONLY
    that request; its co-tenant's merged record is bitwise identical to
    running alone, and the victim's retry (full replay) is exact too."""
    rng = np.random.default_rng(10)
    xa, xb = _stim(rng, 20, 2), _stim(rng, 20, 2)
    solo_a = lasana.simulate(shared_spec, xa, surrogates=lif_surrogate,
                             record_hidden=False)
    solo_b = lasana.simulate(shared_spec, xb, surrogates=lif_surrogate,
                             record_hidden=False)
    plan = FaultPlan(0, {"surrogate.nan": {"at": [0]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_retries=2, retry_backoff_ms=1.0))
    with faults.use_plan(plan):
        ha = srv.submit(shared_spec, xa, surrogates=lif_surrogate)
        hb = srv.submit(shared_spec, xb, surrogates=lif_surrogate)
        srv.run_until_idle()
    assert plan.fired["surrogate.nan"] == 1
    _assert_runs_equal(solo_a, ha.result())
    _assert_runs_equal(solo_b, hb.result())
    stats = srv.stats()
    assert stats["numerical_faults"] == 1
    assert stats["requests_retried"] == 1
    assert stats["requests_completed"] == 2
    assert stats["requests_in_flight"] == 0
    assert {ha.attempts, hb.attempts} == {1, 2}   # exactly one victim


# --- graceful degradation -----------------------------------------------------

def test_degrades_to_behavioral_after_fault_budget(lif_surrogate,
                                                   shared_spec):
    """After ``degrade_after`` surrogate faults on a spec, NEW requests
    for it serve on the behavioral backend — completed, flagged, and
    matching a solo behavioral run bitwise."""
    rng = np.random.default_rng(11)
    x1, x2 = _stim(rng, 12, 1), _stim(rng, 12, 1)
    plan = FaultPlan(0, {"surrogate.nan": {"at": [0]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_retries=0, degrade_after=1))
    with faults.use_plan(plan):
        h1 = srv.submit(shared_spec, x1, surrogates=lif_surrogate)
        srv.run_until_idle()
        with pytest.raises(RuntimeError, match="quarantined"):
            h1.result(timeout=5)
        h2 = srv.submit(shared_spec, x2, surrogates=lif_surrogate)
        srv.run_until_idle()
    assert h2.degraded and not h1.degraded
    solo = lasana.simulate(shared_spec, x2, backend="behavioral",
                           record_hidden=False)
    _assert_runs_equal(solo, h2.result(), energy_rtol=1e-5)
    stats = srv.stats()
    assert stats["requests_degraded"] == 1
    assert stats["degraded_specs"]          # spec key is published
    assert any(l["degraded"] for l in stats["lanes"])
    wire_degraded = [l["degraded"] for l in stats["lanes"]]
    assert True in wire_degraded


# --- watchdog -----------------------------------------------------------------

def test_watchdog_fails_hung_lane_only(lif_surrogate, shared_spec):
    """A lane step stalled past ``hang_timeout_s`` is detected by the
    watchdog: its requests fail NOW (no request blocks forever) and the
    server keeps serving subsequent work."""
    rng = np.random.default_rng(12)
    plan = FaultPlan(0, {"chunk.stall": {"at": [0], "max_fires": 1}},
                     stall_seconds=0.6)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                hang_timeout_s=0.05))
    with faults.use_plan(plan):
        h1 = srv.submit(shared_spec, _stim(rng, 8, 1),
                        surrogates=lif_surrogate)
        srv.run_until_idle()
        with pytest.raises(RuntimeError, match="watchdog"):
            h1.result(timeout=5)
        h2 = srv.submit(shared_spec, _stim(rng, 8, 1),
                        surrogates=lif_surrogate)
        srv.run_until_idle()
    h2.result(timeout=5)                    # server survived the hang
    stats = srv.stats()
    assert stats["lane_hangs"] == 1
    assert stats["requests_failed"] == 1
    assert stats["requests_completed"] == 1
    assert stats["requests_in_flight"] == 0


# --- artifact quarantine (satellite: serve/store) -----------------------------

def test_corrupt_artifact_fails_only_requester(lif_surrogate, tmp_path,
                                               shared_spec):
    corrupt = tmp_path / "bad.npz"
    corrupt.write_bytes(b"PK\x03\x04 truncated garbage")
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    srv.register_surrogate("good", lif_surrogate)
    assert srv.register_surrogate_path("bad", str(corrupt)) == 1
    with pytest.raises(ArtifactError, match="bad@1") as exc:
        srv.submit(shared_spec, np.zeros((2, 1, 12), np.float32),
                   surrogates="bad")
    assert "bad.npz" in str(exc.value)      # names the on-disk path
    # only the requesting caller failed: the store, the server, and
    # other artifacts are untouched
    h = srv.submit(shared_spec,
                   _stim(np.random.default_rng(13), 8, 1),
                   surrogates="good")
    srv.run_until_idle()
    h.result(timeout=5)


def test_valid_artifact_roundtrips_through_path_registration(
        lif_surrogate, shared_spec, tmp_path):
    path = str(tmp_path / "lif.npz")
    lasana.save(lif_surrogate, path)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    srv.register_surrogate_path("lif", path)
    x = _stim(np.random.default_rng(14), 12, 2)
    h = srv.submit(shared_spec, x, surrogates="lif")
    srv.run_until_idle()
    solo = lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                           record_hidden=False)
    _assert_runs_equal(solo, h.result())
    assert h.surrogate_ref == ("lif", 1)


def test_artifact_load_fault_site_wrapped(lif_surrogate, tmp_path):
    from repro.serve.store import load_artifact
    path = str(tmp_path / "ok.npz")
    lasana.save(lif_surrogate, path)
    plan = FaultPlan(0, {"artifact.load": {"at": [0]}})
    with faults.use_plan(plan):
        with pytest.raises(ArtifactError):
            load_artifact(path, name="ok", version=1)
        load_artifact(path, name="ok", version=1)   # next call is clean
    assert plan.fired["artifact.load"] == 1


def test_missing_artifact_keeps_raw_file_not_found(tmp_path):
    from repro.serve.store import load_artifact
    with pytest.raises(FileNotFoundError):
        load_artifact(str(tmp_path / "never_saved"))


# --- callback explosion -------------------------------------------------------

def test_callback_explosion_fails_only_its_request(lif_surrogate,
                                                   shared_spec):
    rng = np.random.default_rng(15)
    xa, xb = _stim(rng, 12, 1), _stim(rng, 12, 1)
    solo_b = lasana.simulate(shared_spec, xb, surrogates=lif_surrogate,
                             record_hidden=False)
    plan = FaultPlan(0, {"callback.explode": {"at": [0]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK))
    with faults.use_plan(plan):
        ha = srv.submit(shared_spec, xa, surrogates=lif_surrogate,
                        on_chunk=lambda c: None)
        hb = srv.submit(shared_spec, xb, surrogates=lif_surrogate)
        srv.run_until_idle()
    with pytest.raises(FaultInjected):
        ha.result(timeout=5)
    _assert_runs_equal(solo_b, hb.result())


# --- metrics accounting (satellite: serve/metrics) ----------------------------

def test_in_flight_never_negative_across_outcomes(lif_surrogate,
                                                  shared_spec):
    """requests_in_flight = submitted - completed - failed must hold (and
    stay >= 0) across completion, rejection, deadline expiry, injected
    faults with retries, and quarantine."""
    import time
    from repro.serve import ServerBusy
    rng = np.random.default_rng(16)
    plan = FaultPlan(0, {"lane.step": {"at": [0]},
                         "surrogate.nan": {"at": [1]}})
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_queue=2, max_retries=3,
                                retry_backoff_ms=1.0))

    def check():
        s = srv.stats()
        assert s["requests_in_flight"] >= 0
        assert s["requests_in_flight"] == (s["requests_submitted"]
                                           - s["requests_completed"]
                                           - s["requests_failed"])
        return s

    with faults.use_plan(plan):
        handles = [srv.submit(shared_spec, _stim(rng, 10, 1),
                              surrogates=lif_surrogate,
                              max_retries=3)
                   for _ in range(2)]
        with pytest.raises(ServerBusy):     # rejection: never in flight
            srv.submit(shared_spec, _stim(rng, 10, 1),
                       surrogates=lif_surrogate)
        check()
        srv.run_until_idle()
        s = check()
        assert s["requests_completed"] == 2
        h = srv.submit(shared_spec, _stim(rng, 10, 1),
                       surrogates=lif_surrogate, deadline_ms=1.0)
        time.sleep(0.02)
        srv.run_until_idle()
        s = check()
        assert s["requests_deadline_exceeded"] == 1
    for hd in handles:
        hd.result(timeout=5)
    with pytest.raises(DeadlineExceeded):
        h.result(timeout=5)
    s = check()
    assert s["requests_retried"] >= 1
    assert s["requests_rejected"] == 1


def test_metrics_snapshot_has_resilience_counters():
    snap = SimServer().stats()
    for key in ("requests_retried", "requests_deadline_exceeded",
                "requests_degraded", "numerical_faults", "lane_hangs",
                "degraded_specs"):
        assert key in snap


# --- the CI sentinel: every site fires ----------------------------------------

def test_canned_plan_fires_every_site(lif_surrogate, shared_spec,
                                      tmp_path):
    """The faults CI leg's acceptance: driving a small workload under
    the canned plan (or the ambient ``REPRO_FAULT_PLAN`` when one is
    set) fires EVERY injection site at least once, no request leaks or
    blocks forever, and every completed record is exact."""
    with faults.use_plan(None):
        env = None
        from repro.kernels import ops
        if ops.fault_plan_path():
            env = FaultPlan.load(ops.fault_plan_path())
    plan = env if env is not None else FaultPlan.load(_CI_PLAN)
    rng = np.random.default_rng(17)
    xs = [_stim(rng, 20, 1) for _ in range(3)]
    solos = [lasana.simulate(shared_spec, x, surrogates=lif_surrogate,
                             record_hidden=False) for x in xs]
    art = str(tmp_path / "lif.npz")
    lasana.save(lif_surrogate, art)
    srv = SimServer(ServeConfig(slot_widths=(4,), chunk_ticks=CHUNK,
                                max_retries=4, retry_backoff_ms=1.0))
    srv.register_surrogate_path("lif", art)
    with faults.use_plan(plan):
        # artifact.load: first resolve fires -> ArtifactError; the next
        # resolve loads clean (the store entry stays registered)
        with pytest.raises(ArtifactError):
            srv.submit(shared_spec, xs[0], surrogates="lif")
        boom = srv.submit(shared_spec, xs[0], surrogates="lif",
                          on_chunk=lambda c: None)   # callback.explode
        handles = [srv.submit(shared_spec, x, surrogates="lif")
                   for x in xs[1:]]
        srv.run_until_idle()
        # streaming consumes chunk.stall sites too
        lasana.simulate_stream(shared_spec, xs[0],
                               surrogates=lif_surrogate,
                               chunk_ticks=CHUNK)
    for site in FAULT_SITES:
        assert plan.fired[site] >= 1, (site, plan.fired)
    with pytest.raises(FaultInjected):      # the exploded callback
        boom.result(timeout=5)
    for x, h, solo in zip(xs[1:], handles, solos[1:]):
        assert h.done                       # nothing leaked or hung
        _assert_runs_equal(solo, h.result())
    stats = srv.stats()
    assert stats["requests_in_flight"] == 0
