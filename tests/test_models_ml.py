"""Surrogate model-family tests: GBDT jax==numpy, fit quality ordering,
standardizer properties."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.core.models import (GBDTModel, LinearModel, MLPModel, MeanModel,
                               Standardizer, TableModel)


def _toy(n=3000, f=8, seed=0, noise=0.05):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.5 * x[:, 1] * x[:, 2] + 0.2 * x[:, 3]
         + noise * rng.normal(size=n)).astype(np.float32)
    return x[: n // 2], y[: n // 2], x[n // 2 :], y[n // 2 :]


def test_model_quality_ordering():
    xtr, ytr, xte, yte = _toy()
    xva, yva = xte[:500], yte[:500]
    mean = MeanModel().fit(xtr, ytr, xva, yva)
    lin = LinearModel().fit(xtr, ytr, xva, yva)
    gbdt = GBDTModel(n_trees=40, max_depth=6).fit(xtr, ytr, xva, yva)
    mse = {m.name: float(np.mean((m.predict(xte) - yte) ** 2))
           for m in (mean, lin, gbdt)}
    assert mse["linear"] < mse["mean"]
    assert mse["gbdt"] < mse["linear"]
    assert mse["gbdt"] < 0.2


def test_gbdt_jax_equals_numpy():
    xtr, ytr, xte, yte = _toy(n=2000)
    m = GBDTModel(n_trees=20, max_depth=5).fit(xtr, ytr, xte[:200], yte[:200])
    got_np = m.predict(xte)
    got_jax = np.asarray(m.jax_predict(jnp.asarray(xte)))
    np.testing.assert_allclose(got_np, got_jax, rtol=1e-5, atol=1e-5)


def test_mlp_learns_nonlinearity():
    xtr, ytr, xte, yte = _toy(n=4000)
    m = MLPModel(max_epochs=60, patience=10).fit(xtr, ytr, xte[:500], yte[:500])
    mse = float(np.mean((m.predict(xte) - yte) ** 2))
    base = float(np.var(yte))
    assert mse < 0.5 * base, (mse, base)
    # jax/np parity
    np.testing.assert_allclose(m.predict(xte[:64]),
                               np.asarray(m.jax_predict(jnp.asarray(xte[:64]))),
                               rtol=1e-5, atol=1e-5)


def test_table_exact_on_training_points():
    xtr, ytr, xte, yte = _toy(n=1000)
    m = TableModel().fit(xtr, ytr, xte[:100], yte[:100])
    pred = m.predict(xtr[:50])
    np.testing.assert_allclose(pred, ytr[:50], atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 200), st.integers(1, 8), st.integers(0, 1000))
def test_standardizer_properties(n, f, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(3.0, 10.0, size=(n, f)).astype(np.float32)
    s = Standardizer.fit(x)
    z = s.apply(x)
    np.testing.assert_allclose(z.mean(axis=0), 0.0, atol=1e-3)
    sd = z.std(axis=0)
    # constant columns map to zeros (sd clamped to 1)
    assert np.all((np.abs(sd - 1) < 1e-3) | (sd < 1e-6))
