"""Algorithm 1: the masked/batched TPU formulation must equal the paper's
per-circuit loop exactly (same bank, same stimuli)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.core.circuits import LIFNeuron
from repro.core.wrapper import (init_state, lasana_step,
                                lasana_step_reference)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), frac=st.floats(0.1, 1.0),
       spiking=st.booleans())
def test_masked_equals_reference(lif_bank, seed, frac, spiking):
    circ = LIFNeuron()
    key = jax.random.PRNGKey(seed)
    n = 24
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = circ.sample_params(k1, n)
    state = init_state(n, params)
    state = state._replace(
        v=jax.random.uniform(k2, (n,), jnp.float32, 0.0, 1.2),
        o=jnp.where(jax.random.bernoulli(k2, 0.3, (n,)), 1.5, 0.0),
        t_last=jnp.asarray(
            np.random.default_rng(seed).choice([0.0, 5.0, 10.0, 20.0], n)
            .astype(np.float32)))
    changed = jax.random.bernoulli(k3, frac, (n,))
    x = circ.sample_inputs(k4, (n,))
    t = 25.0
    s1, e1, l1, o1 = lasana_step(lif_bank, state, changed, x, t, 5.0,
                                 spiking=spiking)
    s2, e2, l2, o2 = lasana_step_reference(lif_bank, state,
                                           np.asarray(changed), np.asarray(x),
                                           t, 5.0, spiking=spiking)
    np.testing.assert_allclose(np.asarray(s1.v), np.asarray(s2.v),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(e1) * 1e12, e2 * 1e12,
                               rtol=2e-3, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l1), l2, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1.t_last), np.asarray(s2.t_last))


def test_unchanged_circuits_untouched(lif_bank):
    circ = LIFNeuron()
    key = jax.random.PRNGKey(3)
    n = 8
    params = circ.sample_params(key, n)
    state = init_state(n, params)._replace(
        v=jnp.linspace(0, 1, n), t_last=jnp.full((n,), 10.0))
    changed = jnp.zeros((n,), bool)
    x = circ.sample_inputs(key, (n,))
    s, e, l, o = lasana_step(lif_bank, state, changed, x, 20.0, 5.0)
    np.testing.assert_array_equal(np.asarray(s.v), np.asarray(state.v))
    assert float(jnp.sum(e)) == 0.0
    np.testing.assert_array_equal(np.asarray(s.t_last),
                                  np.asarray(state.t_last))


def test_idle_catchup_uses_merged_tau(lif_bank):
    """A circuit idle for k ticks gets ONE E2 catch-up with tau = k*T."""
    circ = LIFNeuron()
    key = jax.random.PRNGKey(4)
    n = 4
    params = circ.sample_params(key, n)
    x = circ.sample_inputs(key, (n,))
    base = init_state(n, params)._replace(v=jnp.full((n,), 0.8))
    # circuit 0 updated last at t=5, others at t=20; step at t=25, T=5
    st = base._replace(t_last=jnp.asarray([5.0, 20.0, 20.0, 20.0]))
    changed = jnp.ones((n,), bool)
    s, e, l, o = lasana_step(lif_bank, st, changed, x, 25.0, 5.0)
    # circuit 0 must differ from an identical circuit without staleness:
    st2 = base._replace(t_last=jnp.full((n,), 20.0))
    s2, e2, _, _ = lasana_step(lif_bank, st2, changed, x, 25.0, 5.0)
    assert not np.isclose(float(e[0]), float(e2[0]), rtol=1e-3, atol=0.0)
    np.testing.assert_allclose(np.asarray(e)[1:] * 1e12,
                               np.asarray(e2)[1:] * 1e12, rtol=1e-5)


def test_vdd_threads_through_spike_resolution(lif_bank):
    """ISSUE-4 regression: the spike discriminator (V_dd/2) and resolved
    spike amplitude (V_dd) were hardcoded at 1.5 V — a non-1.5-V_dd
    circuit must resolve to ITS supply on both the vectorized and the
    reference paths, and the two must still agree."""
    circ = LIFNeuron()
    key = jax.random.PRNGKey(7)
    n = 16
    params = circ.sample_params(key, n)
    state = init_state(n, params)
    changed = jnp.ones((n,), bool)
    x = circ.sample_inputs(key, (n,))
    for vdd in (1.5, 1.2, 0.9):
        s, e, l, o = lasana_step(lif_bank, state, changed, x, 5.0, 5.0,
                                 spiking=True, vdd=vdd)
        s2, e2, l2, o2 = lasana_step_reference(
            lif_bank, state, np.asarray(changed), np.asarray(x), 5.0, 5.0,
            spiking=True, vdd=vdd)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o2),
                                   atol=1e-6)
        # outputs live on the circuit's own rails, not a hardcoded 1.5
        assert set(np.unique(np.asarray(o))) <= {0.0, np.float32(vdd)}
    # a lower discriminator fires on outputs a higher one rejects
    o_hi = lasana_step(lif_bank, state, changed, x, 5.0, 5.0,
                       spiking=True, vdd=1.5)[3]
    o_lo = lasana_step(lif_bank, state, changed, x, 5.0, 5.0,
                       spiking=True, vdd=0.5)[3]
    assert int(jnp.sum(o_lo > 0)) >= int(jnp.sum(o_hi > 0))


def test_drive_to_circuit_inputs_spike_amp():
    """The (w, x, n) LIF drive encoding follows spike_amp/n_spk instead
    of hardcoding the 1.5-V/5-spike defaults."""
    from repro.core.network import drive_to_circuit_inputs
    drive = jnp.asarray([[0.3, -2.0]], jnp.float32)
    default = drive_to_circuit_inputs(drive)
    np.testing.assert_allclose(np.asarray(default[..., 1]), 1.5)
    np.testing.assert_allclose(np.asarray(default[..., 2]), 5.0)
    custom = drive_to_circuit_inputs(drive, spike_amp=1.2, n_spk=3.0)
    np.testing.assert_allclose(np.asarray(custom[..., 0]),
                               [[0.3, -1.0]])          # clipped weight
    np.testing.assert_allclose(np.asarray(custom[..., 1]), 1.2)
    np.testing.assert_allclose(np.asarray(custom[..., 2]), 3.0)
