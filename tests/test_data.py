"""Data pipeline determinism and shaping."""

import numpy as np
import pytest

from repro.data.lm_data import Prefetcher, SyntheticCorpus, make_train_batch
from repro.data.mnist import make_digits, poisson_encode


def test_corpus_deterministic():
    c = SyntheticCorpus(1000, seed=3)
    a = c.batch(7, 4, 64)
    b = c.batch(7, 4, 64)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c.batch(8, 4, 64))
    assert a.min() >= 0 and a.max() < 1000


def test_microbatch_major_shape():
    c = SyntheticCorpus(100, seed=0)
    b = make_train_batch(c, 0, global_batch=8, seq=16, num_microbatches=4)
    assert b["tokens"].shape == (4, 2, 16)
    assert b["labels"].shape == (4, 2, 16)


def test_labels_are_shifted_tokens():
    c = SyntheticCorpus(100, seed=0)
    b = make_train_batch(c, 0, global_batch=2, seq=16)
    full = c.batch(0, 2, 17)
    np.testing.assert_array_equal(b["tokens"], full[:, :-1])
    np.testing.assert_array_equal(b["labels"], full[:, 1:])


def test_prefetcher_orders_steps():
    c = SyntheticCorpus(50, seed=1)
    pf = Prefetcher(lambda s: make_train_batch(c, s, global_batch=2, seq=8),
                    depth=2, start_step=5)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (5, 6)
    finally:
        pf.close()


def test_digits_and_spikes():
    imgs, labels = make_digits(64, size=20, seed=0)
    assert imgs.shape == (64, 400)
    assert 0 <= imgs.min() and imgs.max() <= 1
    assert set(np.unique(labels)) <= set(range(10))
    spikes = poisson_encode(imgs, 50, seed=0)
    assert spikes.shape == (50, 64, 400)
    # brighter pixels spike more
    hi = imgs > 0.6
    lo = imgs < 0.1
    assert spikes[:, hi].mean() > 5 * max(spikes[:, lo].mean(), 1e-4)
