"""repro.lasana facade: pytree Surrogate artifacts + one train->persist->
simulate API.

Covers the ISSUE-3 acceptance properties:

  * compile-once serving: swapping two differently-trained Surrogates
    through one jitted ``lasana.simulate`` program triggers ZERO
    recompiles (surrogates are traced pytree arguments, not closures);
  * deprecation shims (``run_snn_lasana``, ``PredictorBank.predict``,
    ``NetworkEngine(bank=...)``) produce identical results to the new API;
  * the curated ``repro.core`` surface re-exports the facade;
  * SurrogateLibrary semantics (kind binding, persistence, pytree-ness).
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.lasana as lasana
from repro.core.network import NetworkEngine, snn_spec
from repro.core.surrogate import Surrogate, SurrogateLibrary

T_STEPS, BATCH = 25, 4


@pytest.fixture(scope="module")
def two_surrogates():
    """Two linear-family surrogates trained on different testbench seeds:
    identical manifests + shapes (same compiled program), different
    weights (observably different predictions)."""
    cfg1 = lasana.TrainConfig(n_runs=60, n_steps=50, seed=1,
                              families=("linear",))
    cfg2 = lasana.TrainConfig(n_runs=60, n_steps=50, seed=2,
                              families=("linear",))
    return lasana.train("lif", cfg1), lasana.train("lif", cfg2)


@pytest.fixture(scope="module")
def small_net():
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (12, 8)) * 0.8
    w2 = jax.random.normal(jax.random.PRNGKey(1), (8, 4)) * 0.8
    params = [jnp.asarray([0.58, 0.5, 0.5, 0.5])] * 2
    spec = snn_spec([w1, w2], params)
    spikes = (jax.random.bernoulli(jax.random.PRNGKey(2), 0.2,
                                   (T_STEPS, BATCH, 12)) * 1.5
              ).astype(jnp.float32)
    return spec, spikes


# --- compile-once serving (the tentpole contract) -----------------------------

def test_surrogate_is_registered_pytree(two_surrogates):
    s1, _ = two_surrogates
    leaves, treedef = jax.tree.flatten(s1)
    assert leaves and all(hasattr(l, "shape") for l in leaves)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, Surrogate)
    assert rebuilt.manifest == s1.manifest
    # tree.map over the artifact touches only arrays
    doubled = jax.tree.map(lambda a: a * 2, s1)
    assert isinstance(doubled, Surrogate)


def test_swap_surrogates_zero_recompiles(two_surrogates, small_net):
    """Two differently-trained surrogates through ONE engine: exactly one
    trace + one compile, and the runs demonstrably use different weights."""
    s1, s2 = two_surrogates
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana")
    r1 = eng.run(spikes, surrogates=s1)
    r2 = eng.run(spikes, surrogates=s2)
    r1b = eng.run(spikes, surrogates=s1)
    assert eng.compile_count == 1
    assert eng._trace_count == 1
    # the swapped weights actually flowed through the compiled program
    assert r1.energy.sum() != r2.energy.sum()
    np.testing.assert_array_equal(r1.energy, r1b.energy)


def test_facade_simulate_reuses_one_program(two_surrogates, small_net):
    """lasana.simulate with the same live spec + retrained surrogates
    shares one cached engine and zero extra compiles."""
    s1, s2 = two_surrogates
    spec, spikes = small_net
    r1 = lasana.simulate(spec, spikes, surrogates=s1)
    eng = lasana.engine(spec)
    compiles_after_first = eng.compile_count
    r2 = lasana.simulate(spec, spikes, surrogates=s2)
    assert lasana.engine(spec) is eng
    assert eng.compile_count == compiles_after_first == 1
    assert r1.energy.sum() != r2.energy.sum()
    assert r2.compile_seconds == r1.compile_seconds  # same cached program


def test_different_structure_recompiles_cleanly(two_surrogates, small_net):
    """A surrogate with a DIFFERENT structure (family mix) compiles a new
    program instead of misusing the cached one."""
    s1, _ = two_surrogates
    spec, spikes = small_net
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("lif", TestbenchConfig(n_runs=60, n_steps=50, seed=3))
    s_mean = PredictorBank("lif", families=("mean",)).fit(ds).to_surrogate()
    eng = NetworkEngine(spec, backend="lasana")
    eng.run(spikes, surrogates=s1)
    eng.run(spikes, surrogates=s_mean)
    assert eng.compile_count == 2


# --- deprecation shims produce identical results ------------------------------

def test_run_snn_lasana_shim_matches_facade(lif_bank, small_net):
    from repro.core.simulate import run_snn_lasana
    spec, spikes = small_net
    ws = [l.weight for l in spec.layers]
    ps = [l.params for l in spec.layers]
    counts, energy = run_snn_lasana(lif_bank, ws, spikes, ps)
    run = lasana.simulate(snn_spec(ws, ps), spikes,
                          surrogates=lif_bank.to_surrogate())
    np.testing.assert_array_equal(counts, run.outputs)
    np.testing.assert_allclose(
        energy, run.energy.sum() + run.flush_energy.sum(), rtol=1e-6)


def test_bank_kwarg_shim_matches_surrogates(lif_bank, small_net):
    spec, spikes = small_net
    with pytest.deprecated_call():
        legacy = NetworkEngine(spec, backend="lasana", bank=lif_bank
                               ).run(spikes)
    new = NetworkEngine(spec, backend="lasana",
                        surrogates=lif_bank.to_surrogate()).run(spikes)
    np.testing.assert_array_equal(legacy.outputs, new.outputs)
    np.testing.assert_array_equal(legacy.energy, new.energy)


def test_predictor_bank_predict_matches_surrogate(lif_bank):
    """PredictorBank.predict (legacy inference) == Surrogate.predict."""
    sur = lif_bank.to_surrogate()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (32, 9)).astype(np.float32))
    for pname in ("M_O", "M_V", "M_ES"):
        np.testing.assert_array_equal(
            np.asarray(lif_bank.predict(pname, x)),
            np.asarray(sur.predict(pname, x)))


# --- library + surface --------------------------------------------------------

def test_surrogate_library_semantics(two_surrogates, tmp_path):
    s1, _ = two_surrogates
    lib = SurrogateLibrary({"lif": s1})
    assert "lif" in lib and lib["lif"] is s1 and lib.kinds() == ("lif",)
    # kind/circuit binding is validated
    with pytest.raises(ValueError, match="registered under kind"):
        SurrogateLibrary({"crossbar": s1})
    # the library is itself a pytree
    leaves, treedef = jax.tree.flatten(lib)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, SurrogateLibrary) and "lif" in rebuilt
    # directory persistence — also through the facade save/load round trip
    lasana.save(lib, str(tmp_path / "lib"))
    loaded = lasana.load(str(tmp_path / "lib"))
    assert isinstance(loaded, SurrogateLibrary)
    assert loaded.kinds() == ("lif",)
    assert loaded["lif"].manifest == s1.manifest


def test_surrogate_kind_mismatch_rejected(two_surrogates, small_net):
    import dataclasses
    s1, _ = two_surrogates
    spec, spikes = small_net
    wrong_kind = Surrogate(
        manifest=dataclasses.replace(s1.manifest, circuit="crossbar"),
        params=s1.params)
    with pytest.raises(ValueError, match="bound to layer kind"):
        NetworkEngine(spec, backend="lasana",
                      surrogates={"lif": wrong_kind})


def test_core_namespace_reexports_facade():
    import repro.core as core
    for name in core.__all__:
        assert getattr(core, name) is not None, name
    assert core.train is lasana.train
    assert core.Surrogate is lasana.Surrogate
    # ``simulate`` is reachable via the facade module (the name itself
    # would be shadowed by the repro.core.simulate submodule)
    assert core.lasana.simulate is lasana.simulate
    assert "simulate" not in core.__all__


def test_facade_symbols_documented():
    import inspect
    for name in lasana.__all__:
        obj = getattr(lasana, name)
        if inspect.isclass(obj) or callable(obj):
            assert inspect.getdoc(obj), f"{name} lacks a docstring"


def test_misuse_raises_not_silently_ignores(two_surrogates, small_net):
    """Guard rails: surrogates on a reference backend, annotation without
    behavioral states, and a surrogate where a mesh belongs all raise."""
    s1, _ = two_surrogates
    spec, spikes = small_net
    with pytest.raises(ValueError, match="does not use surrogates"):
        lasana.simulate(spec, spikes, backend="golden", surrogates=s1)
    from repro.core.simulate import make_stimulus, run_lasana
    active, x, params = make_stimulus("lif", 8, 5, seed=0)
    with pytest.raises(ValueError, match="oracle_states"):
        run_lasana(s1, "lif", active, x, params,
                   annotate_outputs=np.zeros((5, 8), np.float32))
    from repro.core.distributed import make_distributed_step
    with pytest.raises(TypeError, match="Mesh"):
        make_distributed_step(s1, clock_ns=5.0)


def test_simulated_spec_still_pickles(two_surrogates, small_net):
    """The engine cache attached to a spec (compiled executables) must not
    leak into pickling or deep-copying of the spec value object."""
    import copy
    import pickle
    s1, _ = two_surrogates
    spec, spikes = small_net
    lasana.simulate(spec, spikes, surrogates=s1)
    clone = pickle.loads(pickle.dumps(spec))
    assert clone.n_layers == spec.n_layers
    assert not hasattr(clone, "_lasana_engine_cache")
    deep = copy.deepcopy(spec)
    assert deep.n_layers == spec.n_layers


def test_engine_cache_dies_with_spec(two_surrogates, small_net):
    """Compiled-program caches are attached to the spec, not a module
    table: dropping the spec releases the engines."""
    import weakref
    s1, _ = two_surrogates
    _, spikes = small_net
    w = jax.random.normal(jax.random.PRNGKey(5), (12, 4))
    spec = snn_spec([w], [jnp.asarray([0.58, 0.5, 0.5, 0.5])])
    lasana.simulate(spec, spikes, surrogates=s1)
    ref = weakref.ref(lasana.engine(spec))
    assert ref() is not None
    del spec
    import gc
    gc.collect()
    assert ref() is None


def test_engine_cache_keys_mesh_by_value(small_net):
    """The per-spec engine cache must key meshes by VALUE, never id():
    after a mesh is garbage-collected, a new mesh allocated at the same
    address must not silently reuse an engine compiled for the dead mesh.
    Value-equal meshes legitimately share one engine."""
    import gc
    spec, _ = small_net
    dev = np.array(jax.devices()[:1])
    m_x = jax.sharding.Mesh(dev, ("x",))
    m_y = jax.sharding.Mesh(dev, ("y",))
    e_x = lasana.engine(spec, mesh=m_x)
    e_y = lasana.engine(spec, mesh=m_y)
    assert e_x is not e_y
    assert e_x.mesh is m_x and e_y.mesh is m_y
    # same devices + axis names -> same engine, even via a new Mesh object
    assert lasana.engine(spec, mesh=jax.sharding.Mesh(dev, ("x",))) is e_x
    # address-reuse stress: short-lived meshes cycled through the GC must
    # always resolve to an engine carrying the REQUESTED axis names
    for name in ("x", "y", "x", "y", "x"):
        mesh = jax.sharding.Mesh(dev, (name,))
        eng = lasana.engine(spec, mesh=mesh)
        assert tuple(eng.mesh.axis_names) == (name,)
        del mesh, eng
        gc.collect()


def test_engine_cache_lru_bounded(monkeypatch):
    """The per-spec engine cache is a bounded LRU (ISSUE-8 satellite):
    beyond ENGINE_CACHE_CAPACITY variants the least-recently-USED engine
    is evicted — a recency hit protects an old entry — so long-lived
    serving processes cannot accumulate compiled programs without bound."""
    monkeypatch.setattr(lasana, "ENGINE_CACHE_CAPACITY", 2)
    spec = snn_spec(
        [jax.random.normal(jax.random.PRNGKey(41), (6, 5)) * 0.8,
         jax.random.normal(jax.random.PRNGKey(42), (5, 3)) * 0.8],
        [jnp.asarray([0.58, 0.5, 0.5, 0.5])] * 2)
    e_std = lasana.engine(spec)
    e_hid = lasana.engine(spec, record_hidden=False)
    assert lasana.engine(spec) is e_std            # refresh e_std's recency
    e_ann = lasana.engine(spec, mode="annotation")  # 3rd entry: evicts LRU
    cache = getattr(spec, "_lasana_engine_cache")
    assert len(cache) == 2
    assert lasana.engine(spec) is e_std             # survived (recent)
    assert lasana.engine(spec, mode="annotation") is e_ann
    # e_hid was least-recently-used: evicted, a fresh request rebuilds
    # (and that rebuild in turn evicts today's LRU, e_std)
    rebuilt = lasana.engine(spec, record_hidden=False)
    assert rebuilt is not e_hid
    assert len(cache) == 2
    assert lasana.engine(spec) is not e_std
    # capacity is read live: raising it stops eviction immediately
    monkeypatch.setattr(lasana, "ENGINE_CACHE_CAPACITY", 8)
    e_fused = lasana.engine(spec, fused=False)
    assert len(cache) >= 3
    assert lasana.engine(spec, fused=False) is e_fused
    assert lasana.engine(spec, record_hidden=False) is rebuilt


def test_check_api_tool_passes():
    """The CI API guard agrees with the committed snapshot."""
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run([sys.executable, str(root / "tools" / "check_api.py")],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
