"""Architecture-exploration feature: tile math, facade paths, and the
vectorized design-space engine (batched CandidateSpec -> DSEReport)."""

import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.explore import (TILE, CandidateSpec, DSEEngine, DSEReport,
                                _matrix_dims, _tile_table, explore_arch,
                                pareto_mask)
from repro.models.params import ParamSpec


@pytest.fixture(scope="module")
def xbar_bank():
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("crossbar", TestbenchConfig(n_runs=60, n_steps=60))
    return PredictorBank("crossbar", families=("linear",)).fit(ds)


@pytest.fixture(scope="module")
def xbar_surrogate(xbar_bank):
    return xbar_bank.to_surrogate()


# --- legacy per-arch path -----------------------------------------------------

def test_reduced_tile_counts(xbar_bank):
    cfg = reduced_config("starcoder2-3b")
    rep = explore_arch(cfg, xbar_bank)
    # d=64, ff=128, 2 layers ungated: up (64,128)+down (128,64) = 2*(2*4)=16
    # attn per layer: wq (64,4,16)->(64,64): 2x2; wk/wv (64,2,16)->(64,32): 2x1
    # wo (4,16,64)->(64,64): 2x2 ; per layer 4+2+2+4=12, ffn 8+8=16... total>0
    assert rep.n_tiles > 0
    assert rep.analog_params < rep.total_params
    assert 0.0 < rep.analog_flop_fraction <= 1.0
    assert rep.energy_per_token_j > 0


def test_moe_active_fraction_discount(xbar_bank):
    dense = reduced_config("granite-3-8b")
    moe = reduced_config("deepseek-moe-16b")
    rd = explore_arch(dense, xbar_bank)
    rm = explore_arch(moe, xbar_bank)
    # MoE energy/token must NOT scale with total expert tiles (top-k only)
    assert rm.energy_per_token_j < 0.9 * rm.n_tiles * rm.tile_energy_j
    # dense arch fires every tile
    np.testing.assert_allclose(rd.energy_per_token_j,
                               rd.n_tiles * rd.tile_energy_j, rtol=1e-6)


def test_ssm_is_partially_analog(xbar_bank):
    cfg = reduced_config("mamba2-1.3b")
    rep = explore_arch(cfg, xbar_bank)
    # projections map, the scan itself does not -> fraction strictly < 1
    assert 0.1 < rep.analog_flop_fraction < 1.0


# --- the expert-axis tiling bugfix --------------------------------------------

def test_matrix_dims_expert_axis_multiplies_count():
    """An (E, d, f) expert bank is E independent d x f matrices — tiled
    E * ceil(d/T) * ceil(f/T), never ceil(E/T) * ceil(d*f/T)."""
    spec = ParamSpec((4, 64, 96), ("experts", "embed", "mlp"))
    assert _matrix_dims(spec) == (4, 64, 96)
    stacked = ParamSpec((2, 4, 64, 96),
                        ("layers", "experts", "embed", "mlp"))
    assert _matrix_dims(stacked) == (8, 64, 96)
    layers_only = ParamSpec((3, 64, 96), ("layers", "embed", "mlp"))
    assert _matrix_dims(layers_only) == (3, 64, 96)
    plain = ParamSpec((64, 4, 24), ("embed", "heads", "head_dim"))
    assert _matrix_dims(plain) == (1, 64, 96)


def test_moe_expert_tile_counts_exact(xbar_bank):
    """Every routed-expert matrix in the reduced deepseek-moe config tiles
    to the EXACT per-expert count (L * E * ceil(d/32) * ceil(f/32))."""
    cfg = reduced_config("deepseek-moe-16b")
    rep = explore_arch(cfg, xbar_bank)
    m = cfg.moe
    moe_layers = cfg.n_layers - m.first_dense
    d, f = cfg.d_model, (m.d_ff_expert or cfg.d_ff)
    per = -(-d // TILE) * (-(-f // TILE))
    expect_routed = moe_layers * m.n_experts * per
    for comp in ("w_gate", "w_up", "w_down"):
        assert rep.tiles_by_component[comp] == expect_routed, comp


# --- facade-path coverage -----------------------------------------------------

def test_explore_arch_accepts_surrogate_and_library(xbar_bank,
                                                    xbar_surrogate):
    cfg = reduced_config("starcoder2-3b")
    r_bank = explore_arch(cfg, xbar_bank)
    r_sur = explore_arch(cfg, xbar_surrogate)
    r_lib = explore_arch(cfg, {"crossbar": xbar_surrogate})
    from repro.core.surrogate import SurrogateLibrary
    r_slib = explore_arch(cfg, SurrogateLibrary({"crossbar":
                                                 xbar_surrogate}))
    assert r_bank.n_tiles == r_sur.n_tiles == r_lib.n_tiles
    for other in (r_sur, r_lib, r_slib):
        np.testing.assert_allclose(other.energy_per_token_j,
                                   r_bank.energy_per_token_j, rtol=1e-5)


def test_explore_rejects_library_without_crossbar(xbar_surrogate):
    with pytest.raises(ValueError, match="crossbar"):
        explore_arch(reduced_config("starcoder2-3b"),
                     {"lif": xbar_surrogate})


def test_dse_rejects_non_crossbar_surrogate():
    import repro.lasana as lasana
    sur = lasana.train("lif", lasana.TrainConfig(n_runs=40, n_steps=40,
                                                 families=("mean",)))
    with pytest.raises(ValueError, match="crossbar"):
        DSEEngine(n_samples=16).evaluate(CandidateSpec.of(), sur)


# --- CandidateSpec ------------------------------------------------------------

def test_candidate_spec_broadcast_and_validation():
    c = CandidateSpec.of(d_model=[128, 256, 512], v_dd=1.0)
    assert len(c) == 3
    assert c.v_dd.shape == (3,) and np.all(c.v_dd == 1.0)
    with pytest.raises(ValueError, match="top_k"):
        CandidateSpec.of(n_experts=8, top_k=16)
    with pytest.raises(ValueError, match="entries"):
        CandidateSpec.of(d_model=[128, 256], n_layers=[2, 4, 6])
    with pytest.raises(TypeError, match="unknown"):
        CandidateSpec.of(d_modell=128)


def test_candidate_spec_grid_and_take():
    g = CandidateSpec.grid(d_model=[256, 512], tile=[16, 32, 64])
    assert len(g) == 6
    assert sorted(set(zip(g.d_model.tolist(), g.tile.tolist()))) == [
        (256, 16), (256, 32), (256, 64), (512, 16), (512, 32), (512, 64)]
    sub = g.take([0, 5])
    assert len(sub) == 2 and sub.d_model.tolist() == [256, 512]
    row = g.row(1)
    assert row["d_model"] == 256 and row["tile"] == 32


def test_candidate_sample_deterministic():
    a = CandidateSpec.sample(64, seed=7)
    b = CandidateSpec.sample(64, seed=7)
    assert np.array_equal(a.d_model, b.d_model)
    assert np.array_equal(a.v_dd, b.v_dd)
    moe = a.n_experts > 0
    assert np.all(a.top_k[moe] >= 1) and np.all(
        a.top_k[moe] <= a.n_experts[moe])


# --- vectorized tile math -----------------------------------------------------

def test_tile_table_matches_hand_formula():
    c = CandidateSpec.of(d_model=96, d_ff=200, n_layers=3, n_heads=3,
                         n_kv_heads=1, tile=32, vocab=1000)
    tt = _tile_table(c)
    dh = 96 // 3
    td, tf, tkv = 3, 7, 1                       # ceil(96/32), ceil(200/32)
    attn = 2 * td * td + 2 * td * tkv
    ffn = 3 * td * tf
    assert tt["n_tiles"][0] == 3 * (attn + ffn)
    assert tt["stages"][0] == 3 * 4
    p_attn = 2 * 96 * 96 + 2 * 96 * (1 * dh)
    p_ffn = 3 * 96 * 200
    assert tt["analog_params"][0] == 3 * (p_attn + p_ffn)
    assert tt["total_params"][0] == 3 * (p_attn + p_ffn) + 2 * 1000 * 96


def test_tile_table_moe_utilization():
    dense = CandidateSpec.of(d_model=64, d_ff=64, n_layers=2)
    moe = CandidateSpec.of(d_model=64, d_ff=64, n_layers=2, n_experts=8,
                           top_k=2)
    td, tm = _tile_table(dense), _tile_table(moe)
    # d=64, tile=32, kv heads = heads -> td = tkv = tf = 2 tiles per edge
    attn_tiles = 2 * (2 * 2 * 2 + 2 * 2 * 2)     # layers * (wq+wo + wk+wv)
    ffn_dense = 2 * (3 * 2 * 2)                  # layers * gate/up/down
    assert td["n_tiles"][0] == attn_tiles + ffn_dense
    # expert bank multiplies mapped FFN tiles by E ...
    assert tm["n_tiles"][0] == attn_tiles + 8 * ffn_dense
    # ... but fires only the routed top-k fraction per token
    np.testing.assert_allclose(
        tm["tiles_token"][0], attn_tiles + 8 * ffn_dense * (2 / 8))
    np.testing.assert_allclose(td["tiles_token"][0], td["n_tiles"][0])


def test_tile_size_scales_counts_not_total_area():
    """Bigger macros -> fewer tiles; energy/token is roughly tile-size
    invariant (same matrix area) up to ceil-padding."""
    c = CandidateSpec.of(d_model=[512, 512], d_ff=[2048, 2048],
                         tile=[32, 128])
    tt = _tile_table(c)
    assert tt["n_tiles"][1] < tt["n_tiles"][0]
    area32 = tt["tiles_token"][0] * (32 / TILE) ** 2
    area128 = tt["tiles_token"][1] * (128 / TILE) ** 2
    np.testing.assert_allclose(area128, area32, rtol=0.05)


# --- the vectorized evaluator -------------------------------------------------

@pytest.fixture(scope="module")
def dse(xbar_surrogate):
    eng = DSEEngine(n_samples=64)
    return eng, xbar_surrogate


def test_batched_sweep_compiles_once_and_hot_swaps(xbar_surrogate):
    # a private engine so compile_count is independent of test order
    eng, sur = DSEEngine(n_samples=64), xbar_surrogate
    cands = CandidateSpec.sample(128, seed=3)
    r1 = eng.evaluate(cands, sur)
    r2 = eng.evaluate(cands, sur)
    assert eng.compile_count == 1
    np.testing.assert_array_equal(r1.energy_per_token_j,
                                  r2.energy_per_token_j)
    # a retrained equal-structure surrogate re-prices with zero recompiles
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("crossbar", TestbenchConfig(n_runs=60, n_steps=60,
                                                   seed=9))
    sur2 = PredictorBank("crossbar", families=("linear",)).fit(ds) \
        .to_surrogate()
    r3 = eng.evaluate(cands, sur2)
    assert eng.compile_count == 1
    assert not np.array_equal(r3.tile_energy_j, r1.tile_energy_j)


def test_batched_vs_looped_parity(dse):
    """The vectorized sweep equals per-candidate eager evaluation — the
    batched program is a pure vectorization, not a different model."""
    eng, sur = dse
    cands = CandidateSpec.sample(16, seed=11)
    batched = eng.evaluate(cands, sur)
    for i in range(len(cands)):
        one = eng.evaluate(cands.take([i]), sur, compiled=False)
        np.testing.assert_allclose(one.energy_per_token_j[0],
                                   batched.energy_per_token_j[i], rtol=1e-5)
        np.testing.assert_allclose(one.latency_critical_ns[0],
                                   batched.latency_critical_ns[i], rtol=1e-5)
        assert one.n_tiles[0] == batched.n_tiles[i]


def test_facade_explore(xbar_surrogate):
    import repro.lasana as lasana
    cands = CandidateSpec.sample(32, seed=1)
    rep = lasana.explore(cands, xbar_surrogate)
    assert isinstance(rep, DSEReport) and len(rep) == 32
    # fully-digital candidates burn zero analog energy; everyone else > 0
    assert np.all(rep.energy_per_token_j >= 0)
    mapped = (cands.analog_attn | cands.analog_ffn) > 0
    assert mapped.any() and np.all(rep.energy_per_token_j[mapped] > 0)
    assert np.all((rep.analog_flop_fraction >= 0)
                  & (rep.analog_flop_fraction <= 1))
    # library form resolves the crossbar entry
    rep2 = lasana.explore(cands, {"crossbar": xbar_surrogate})
    np.testing.assert_array_equal(rep.n_tiles, rep2.n_tiles)
    d = rep.as_dict(rep.pareto())
    assert len(d["energy_per_token_j"]) == rep.pareto().size


def test_vdd_drive_moves_energy(dse):
    """V_dd enters through the DAC drive: a hotter rail must change the
    predicted per-tile energy (monotone under the linear family)."""
    eng, sur = dse
    c = CandidateSpec.of(d_model=[256, 256], v_dd=[0.9, 1.5])
    rep = eng.evaluate(c, sur)
    assert rep.tile_energy_j[0] != rep.tile_energy_j[1]
    assert rep.energy_per_token_j[0] != rep.energy_per_token_j[1]


# --- Pareto extraction --------------------------------------------------------

def test_pareto_mask_simple():
    objs = np.array([[1.0, 1.0], [2.0, 2.0], [0.5, 3.0], [1.0, 1.0]])
    mask = pareto_mask(objs)
    # [2,2] is dominated by [1,1]; duplicates of an optimal point survive
    assert mask.tolist() == [True, False, True, True]


def test_report_pareto_members_not_dominated(dse):
    eng, sur = dse
    rep = eng.evaluate(CandidateSpec.sample(96, seed=5), sur)
    front = rep.pareto()
    assert 0 < front.size <= len(rep)
    objs = np.stack([rep.energy_per_token_j, rep.latency_critical_ns,
                     -rep.analog_flop_fraction], axis=1)
    for i in front:
        dominated = np.any(
            np.all(objs <= objs[i], axis=1) & np.any(objs < objs[i], axis=1))
        assert not dominated
    assert rep.summary(int(front[0]))     # human row renders
