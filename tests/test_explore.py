"""Architecture-exploration feature: tile math and report sanity."""

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.explore import TILE, explore_arch


@pytest.fixture(scope="module")
def xbar_bank():
    from repro.core.dataset import TestbenchConfig, build_dataset
    from repro.core.predictors import PredictorBank
    ds = build_dataset("crossbar", TestbenchConfig(n_runs=60, n_steps=60))
    return PredictorBank("crossbar", families=("linear",)).fit(ds)


def test_reduced_tile_counts(xbar_bank):
    cfg = reduced_config("starcoder2-3b")
    rep = explore_arch(cfg, xbar_bank)
    # d=64, ff=128, 2 layers ungated: up (64,128)+down (128,64) = 2*(2*4)=16
    # attn per layer: wq (64,4,16)->(64,64): 2x2; wk/wv (64,2,16)->(64,32): 2x1
    # wo (4,16,64)->(64,64): 2x2 ; per layer 4+2+2+4=12, ffn 8+8=16... total>0
    assert rep.n_tiles > 0
    assert rep.analog_params < rep.total_params
    assert 0.0 < rep.analog_flop_fraction <= 1.0
    assert rep.energy_per_token_j > 0


def test_moe_active_fraction_discount(xbar_bank):
    dense = reduced_config("granite-3-8b")
    moe = reduced_config("deepseek-moe-16b")
    rd = explore_arch(dense, xbar_bank)
    rm = explore_arch(moe, xbar_bank)
    # MoE energy/token must NOT scale with total expert tiles (top-k only)
    assert rm.energy_per_token_j < 0.9 * rm.n_tiles * rm.tile_energy_j
    # dense arch fires every tile
    np.testing.assert_allclose(rd.energy_per_token_j,
                               rd.n_tiles * rd.tile_energy_j, rtol=1e-6)


def test_ssm_is_partially_analog(xbar_bank):
    cfg = reduced_config("mamba2-1.3b")
    rep = explore_arch(cfg, xbar_bank)
    # projections map, the scan itself does not -> fraction strictly < 1
    assert 0.1 < rep.analog_flop_fraction < 1.0
