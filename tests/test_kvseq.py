"""Numerical correctness of the §Perf Cell-3 optimization: sequence-sharded
KV caches must produce the same decode logits as replicated caches."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import reduced_config
    from repro.models.model import Model
    from repro.sharding import serve_rules
    from repro.train import step as step_mod
    from repro.configs.shapes import ShapeConfig

    cfg = reduced_config("granite-3-8b")
    key = jax.random.PRNGKey(0)
    B, S, GEN = 4, 32, 3
    toks = jax.random.randint(key, (B, S + GEN), 0, cfg.vocab)

    def run(kv_seq):
        # jax.sharding.AxisType only exists on newer jax; 0.4.x meshes are
        # implicitly Auto
        if hasattr(jax.sharding, "AxisType"):
            mesh = jax.make_mesh(
                (2, 4), ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)
        else:
            mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = serve_rules(mesh, kv_seq_sharding=kv_seq)
        model = Model(cfg, mesh=mesh, rules=rules)
        with mesh:
            params = model.init(key)
            shape = ShapeConfig("t", S + GEN, B, "decode")
            dec = step_mod.jit_decode_step(model, mesh, rules, shape)
            _, cache = jax.jit(lambda p, b: model.prefill(
                p, b, max_seq=S + GEN))(params, {"tokens": toks[:, :S]})
            # re-place the prefill cache under the decode shardings
            csh = step_mod.cache_shardings(model, mesh, rules, B, S + GEN)
            cache = jax.tree.map(jax.device_put, cache, csh)
            outs = []
            for i in range(GEN):
                logits, cache = dec(params, cache, toks[:, S + i : S + i + 1])
                outs.append(np.asarray(logits, np.float32))
        return np.concatenate(outs, axis=1)

    a = run(False)
    b = run(True)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    print("REL_ERR", err)
    assert err < 5e-2, err
    print("KVSEQ-OK")
""")


@pytest.mark.slow
def test_kvseq_sharding_preserves_decode(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    script = tmp_path / "kvseq_check.py"
    script.write_text(_SCRIPT)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, cwd=_ROOT, timeout=900)
    out = r.stdout + r.stderr
    assert r.returncode == 0, out[-3000:]
    assert "KVSEQ-OK" in out
