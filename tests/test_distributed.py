"""Distributed equivalence (subprocess, forced host devices): the sharded
train step must match the single-device step, and the shard_map'd LASANA
step must match the local wrapper."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import reduced_config
    from repro.models.model import Model
    from repro.optim import AdamW, AdamWConfig
    from repro.sharding import train_rules
    from repro.train import step as step_mod
    from repro.configs.shapes import ShapeConfig

    cfg = reduced_config("granite-3-8b")
    opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}

    # single device
    m1 = Model(cfg)
    s1 = step_mod.init_train_state(m1, opt, key)
    step1 = jax.jit(step_mod.make_train_step(m1, opt))
    _, met1 = step1(s1, batch)

    def make_mesh(shape, names):
        # jax.sharding.AxisType only exists on newer jax; 0.4.x meshes are
        # implicitly Auto
        if hasattr(jax.sharding, "AxisType"):
            return jax.make_mesh(
                shape, names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
        return jax.make_mesh(shape, names)

    # 4x2 mesh, explicit shardings
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = train_rules(mesh)
    m2 = Model(cfg, mesh=mesh, rules=rules)
    shape = ShapeConfig("t", 32, 8, "train")
    with mesh:
        s2 = step_mod.init_train_state(m2, opt, key)
        jitted = step_mod.jit_train_step(m2, opt, mesh, rules, shape,
                                         n_moe_groups=4)
        _, met2 = jitted(s2, batch)
    l1, l2 = float(met1["loss"]), float(met2["loss"])
    print("LOSS1", l1, "LOSS2", l2)
    assert abs(l1 - l2) / abs(l1) < 2e-2, (l1, l2)

    # LASANA shard_map equivalence: the surrogate is a TRACED argument of
    # the sharded step (swap-without-recompile serving contract)
    import repro.lasana as lasana
    from repro.core.wrapper import init_state, lasana_step
    from repro.core.distributed import make_distributed_step
    from repro.core.circuits import LIFNeuron
    surrogate = lasana.train("lif", lasana.TrainConfig(
        n_runs=40, n_steps=40, families=("linear",)))
    circ = LIFNeuron()
    n = 64
    params = circ.sample_params(key, n)
    state = init_state(n, params)
    changed = jax.random.bernoulli(key, 0.8, (n,))
    x = circ.sample_inputs(key, (n,))
    sm_mesh = make_mesh((8,), ("data",))
    dstep = make_distributed_step(sm_mesh, clock_ns=5.0, spiking=True)
    with sm_mesh:
        st_d, e_tot, n_out = dstep(surrogate, state, changed, x,
                                   jnp.asarray([5.0]))
    st_l, e_l, _, o_l = lasana_step(surrogate, state, changed, x, 5.0, 5.0,
                                    spiking=True)
    np.testing.assert_allclose(np.asarray(st_d.v), np.asarray(st_l.v),
                               rtol=1e-5, atol=1e-6)
    assert abs(float(e_tot) - float(jnp.sum(e_l))) <= 1e-18 + 1e-5 * abs(float(e_tot))
    print("SHARDMAP-OK")
""")


@pytest.mark.slow
def test_sharded_equals_single_device(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    script = tmp_path / "dist_check.py"
    script.write_text(_SCRIPT)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, cwd=_ROOT, timeout=900)
    out = r.stdout + r.stderr
    assert r.returncode == 0, out[-3000:]
    assert "SHARDMAP-OK" in out
