"""Event-processing invariants (hypothesis property tests)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # minimal CPU container
    from _hyp_fallback import given, settings, st

from repro.core.dataset import TestbenchConfig, build_dataset, \
    generate_testbench, simulate_golden
from repro.core.events import EventKind, EventSet, extract_events, \
    split_runwise


def _small_trace(circuit, seed, n_runs=6, n_steps=40, alpha=0.7):
    cfg = TestbenchConfig(n_runs=n_runs, n_steps=n_steps, alpha=alpha,
                          seed=seed)
    from repro.core.circuits import get_circuit
    circ = get_circuit(circuit)
    active, inputs, params = generate_testbench(circ, cfg)
    return simulate_golden(circ, active, inputs, params)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_event_partition_covers_active_steps(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    # one E1-or-E3 event per active step
    n_active = int(trace.active.sum())
    n_e13 = int(np.sum((ev.kind == 1) | (ev.kind == 3)))
    assert n_e13 == n_active


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_event_energy_conserved(seed):
    """Sum of event energies == trace energy over the covered interval."""
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    for run in range(trace.active.shape[0]):
        idx = np.flatnonzero(trace.active[run])
        last = idx[-1]
        covered = trace.energy[run, : last + 1]
        # events cover [0, last]; trailing idle is excluded by design
        ev_run = ev.select(ev.run_id == run)
        np.testing.assert_allclose(ev_run.energy.sum(), covered.sum(),
                                   rtol=1e-6)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_e2_tau_is_multiple_of_clock(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    e2 = ev.of_kind(EventKind.E2)
    ratios = e2.tau / trace.clock_ns
    np.testing.assert_allclose(ratios, np.round(ratios), atol=1e-5)
    assert np.all(ratios >= 1)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_e1_has_output_change_e3_does_not(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    e1 = ev.of_kind(EventKind.E1)
    # LIF output events are spikes at V_dd
    assert np.all(e1.o_end > 0.75)
    e3 = ev.of_kind(EventKind.E3)
    assert np.all(e3.o_end < 0.75)


def test_runwise_split_disjoint_and_complete():
    trace = _small_trace("crossbar", 3, n_runs=20)
    ev = extract_events(trace)
    tr, te, va = split_runwise(ev, 20, seed=0)
    assert len(tr) + len(te) + len(va) == len(ev)
    runs = [set(np.unique(s.run_id)) for s in (tr, te, va)]
    assert not (runs[0] & runs[1]) and not (runs[0] & runs[2]) \
        and not (runs[1] & runs[2])


def test_state_continuity_within_run():
    """Consecutive events chain: v_end of one == v_start of the next."""
    trace = _small_trace("lif", 11)
    ev = extract_events(trace)
    for run in range(trace.active.shape[0]):
        sel = ev.select(ev.run_id == run)
        # events were appended in temporal order per run
        for i in range(len(sel) - 1):
            np.testing.assert_allclose(sel.v_end[i], sel.v_start[i + 1],
                                       atol=1e-6)


# --- edge cases: degenerate traces -------------------------------------------

from repro.core.events import Trace


def _hand_trace(active, n_in=3, n_p=4, out_changed=None, clock_ns=5.0):
    """Build a Trace by hand with deterministic filler observables."""
    active = np.asarray(active, bool)
    r, t = active.shape
    rng = np.random.default_rng(0)
    return Trace(
        active=active,
        inputs=rng.uniform(0, 1, (r, t, n_in)).astype(np.float32),
        state=rng.uniform(0, 1, (r, t + 1)).astype(np.float32),
        output=np.zeros((r, t + 1), np.float32),
        energy=np.full((r, t), 1e-12),
        latency=np.ones((r, t), np.float32),
        out_changed=np.zeros((r, t), bool) if out_changed is None
        else np.asarray(out_changed, bool),
        params=rng.uniform(0, 1, (r, n_p)).astype(np.float32),
        clock_ns=clock_ns,
        idle_x_is_zero=True)


def test_all_idle_trace_yields_wellformed_empty_set():
    """No active steps -> no events, but column shapes must survive so
    downstream feature building still works."""
    ev = extract_events(_hand_trace(np.zeros((3, 12), bool), n_in=3, n_p=4))
    assert len(ev) == 0
    assert ev.x.shape == (0, 3)
    assert ev.params.shape == (0, 4)
    assert ev.energy.dtype == np.float64
    # slicing and feature building on the empty set must not raise
    assert len(ev.of_kind(EventKind.E1, EventKind.E2, EventKind.E3)) == 0
    from repro.core.predictors import build_features
    feats = build_features(ev, prev_out=True, chain_out=True)
    assert feats.shape == (0, 3 + 1 + 1 + 4 + 1 + 1)


def test_single_timestep_trace():
    """T=1: one active step is one E1/E3 event with tau == clock; an idle
    single step yields nothing."""
    ev = extract_events(_hand_trace(np.array([[True]])))
    assert len(ev) == 1
    assert ev.kind[0] == int(EventKind.E3)          # out_changed=False
    np.testing.assert_allclose(ev.tau, [5.0])
    ev_spk = extract_events(_hand_trace(np.array([[True]]),
                                        out_changed=np.array([[True]])))
    assert ev_spk.kind[0] == int(EventKind.E1)
    assert len(extract_events(_hand_trace(np.array([[False]])))) == 0


def test_leading_idle_emits_an_e2():
    """Idle before the FIRST active step is a real static-energy interval:
    it must surface as an E2 anchored at the run's initial state/output,
    or event-set energy silently under-counts the trace."""
    act = np.zeros((1, 10), bool)
    act[0, 4] = True                                 # idle [0,4) then active
    trace = _hand_trace(act)
    ev = extract_events(trace)
    assert len(ev) == 2
    assert ev.kind[0] == int(EventKind.E2)
    assert ev.kind[1] in (int(EventKind.E1), int(EventKind.E3))
    np.testing.assert_allclose(ev.tau, [4 * 5.0, 5.0])
    # the E2 starts at the run boundary, not at some phantom prior event
    np.testing.assert_allclose(ev.v_start[0], trace.state[0, 0])
    np.testing.assert_allclose(ev.o_prev[0], trace.output[0, 0])
    assert float(ev.energy[0]) == pytest.approx(4 * 1e-12)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_event_energy_conserved_with_leading_idle(seed):
    """Golden-trace conservation when runs idle before their first active
    step (the randomized testbench always fires step 0, so carve the
    prefix out and re-simulate)."""
    cfg = TestbenchConfig(n_runs=6, n_steps=40, alpha=0.7, seed=seed)
    from repro.core.circuits import get_circuit
    circ = get_circuit("lif")
    active, inputs, params = generate_testbench(circ, cfg)
    active = np.asarray(active).copy()
    inputs = np.asarray(inputs).copy()
    active[:, :6] = False
    inputs[:, :6] = 0.0
    trace = simulate_golden(circ, active, inputs, params)
    ev = extract_events(trace)
    for run in range(trace.active.shape[0]):
        idx = np.flatnonzero(trace.active[run])
        if idx.size == 0:
            continue
        covered = trace.energy[run, : idx[-1] + 1]
        ev_run = ev.select(ev.run_id == run)
        np.testing.assert_allclose(ev_run.energy.sum(), covered.sum(),
                                   rtol=1e-6)


def test_trailing_idle_is_excluded():
    """Idle after the LAST active step is not emitted (nothing reactivates
    the circuit inside the trace) — coverage is exactly [0, last active]."""
    act = np.zeros((1, 10), bool)
    act[0, 2] = True
    ev = extract_events(_hand_trace(act))
    # leading gap [0,2) is an E2, step 2 is the E3; steps 3..9 are dropped
    assert len(ev) == 2
    assert ev.kind.tolist() == [int(EventKind.E2), int(EventKind.E3)]
    assert float(ev.energy.sum()) == pytest.approx(3 * 1e-12)


def test_e2_spanning_almost_whole_trace():
    """Active at both ends, idle in between -> exactly one merged E2 whose
    tau covers the full interior gap."""
    t = 12
    act = np.zeros((1, t), bool)
    act[0, 0] = act[0, t - 1] = True
    ev = extract_events(_hand_trace(act))
    kinds = sorted(ev.kind.tolist())
    e2 = ev.of_kind(EventKind.E2)
    assert len(ev) == 3 and len(e2) == 1
    np.testing.assert_allclose(e2.tau, [(t - 2) * 5.0])
    # E2 energy is the sum over the merged idle steps
    np.testing.assert_allclose(e2.energy, [(t - 2) * 1e-12])


def test_back_to_back_active_has_no_e2():
    """Consecutive active steps leave no gap: only E1/E3 events appear."""
    ev = extract_events(_hand_trace(np.ones((2, 6), bool)))
    assert len(ev) == 12
    assert len(ev.of_kind(EventKind.E2)) == 0
    np.testing.assert_allclose(ev.tau, 5.0)
