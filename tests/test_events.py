"""Event-processing invariants (hypothesis property tests)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dataset import TestbenchConfig, build_dataset, \
    generate_testbench, simulate_golden
from repro.core.events import EventKind, EventSet, extract_events, \
    split_runwise


def _small_trace(circuit, seed, n_runs=6, n_steps=40, alpha=0.7):
    cfg = TestbenchConfig(n_runs=n_runs, n_steps=n_steps, alpha=alpha,
                          seed=seed)
    from repro.core.circuits import get_circuit
    circ = get_circuit(circuit)
    active, inputs, params = generate_testbench(circ, cfg)
    return simulate_golden(circ, active, inputs, params)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_event_partition_covers_active_steps(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    # one E1-or-E3 event per active step
    n_active = int(trace.active.sum())
    n_e13 = int(np.sum((ev.kind == 1) | (ev.kind == 3)))
    assert n_e13 == n_active


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_event_energy_conserved(seed):
    """Sum of event energies == trace energy over the covered interval."""
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    for run in range(trace.active.shape[0]):
        idx = np.flatnonzero(trace.active[run])
        last = idx[-1]
        covered = trace.energy[run, : last + 1]
        # events cover [0, last]; trailing idle is excluded by design
        ev_run = ev.select(ev.run_id == run)
        np.testing.assert_allclose(ev_run.energy.sum(), covered.sum(),
                                   rtol=1e-6)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_e2_tau_is_multiple_of_clock(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    e2 = ev.of_kind(EventKind.E2)
    ratios = e2.tau / trace.clock_ns
    np.testing.assert_allclose(ratios, np.round(ratios), atol=1e-5)
    assert np.all(ratios >= 1)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_e1_has_output_change_e3_does_not(seed):
    trace = _small_trace("lif", seed)
    ev = extract_events(trace)
    e1 = ev.of_kind(EventKind.E1)
    # LIF output events are spikes at V_dd
    assert np.all(e1.o_end > 0.75)
    e3 = ev.of_kind(EventKind.E3)
    assert np.all(e3.o_end < 0.75)


def test_runwise_split_disjoint_and_complete():
    trace = _small_trace("crossbar", 3, n_runs=20)
    ev = extract_events(trace)
    tr, te, va = split_runwise(ev, 20, seed=0)
    assert len(tr) + len(te) + len(va) == len(ev)
    runs = [set(np.unique(s.run_id)) for s in (tr, te, va)]
    assert not (runs[0] & runs[1]) and not (runs[0] & runs[2]) \
        and not (runs[1] & runs[2])


def test_state_continuity_within_run():
    """Consecutive events chain: v_end of one == v_start of the next."""
    trace = _small_trace("lif", 11)
    ev = extract_events(trace)
    for run in range(trace.active.shape[0]):
        sel = ev.select(ev.run_id == run)
        # events were appended in temporal order per run
        for i in range(len(sel) - 1):
            np.testing.assert_allclose(sel.v_end[i], sel.v_start[i + 1],
                                       atol=1e-6)
