"""Streaming chunked network runs (ISSUE-4 tentpole).

Acceptance properties:

  * streaming-vs-monolithic BIT-equivalence — outputs, per-tick
    energy/latency/events, idle flush, spike traces — across chunk sizes
    including T % chunk_ticks != 0, on homogeneous LIF nets and on a
    mixed crossbar->LIF recurrent graph, through the engine and the
    ``lasana.simulate_stream`` facade;
  * zero recompiles on surrogate hot-swap across chunks and on
    chunk-count changes: at most one compiled chunk program per distinct
    chunk shape (<= 2 for any (T, chunk_ticks));
  * donation smoke test: the chunk program actually consumes its carry /
    prev-output / surrogate-leaf buffers (XLA aliases them in place), and
    the caller's surrogate survives streaming untouched;
  * generator variant + StreamingRun/NetworkRun.merge semantics (flush on
    the final chunk only, live totals, iterator stimuli).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.lasana as lasana
from repro.core.network import (NetworkEngine, NetworkRun, StreamingRun,
                                crossbar_layer, graph_spec, lif_layer,
                                recurrent_edge, snn_spec)

T_STEPS, BATCH = 24, 4


def _assert_runs_identical(mono, st, *, hidden=True):
    np.testing.assert_array_equal(mono.outputs, st.outputs)
    np.testing.assert_array_equal(mono.energy, st.energy)
    np.testing.assert_array_equal(mono.latency, st.latency)
    np.testing.assert_array_equal(mono.events, st.events)
    np.testing.assert_array_equal(mono.flush_energy, st.flush_energy)
    if mono.out_spikes is not None:
        np.testing.assert_array_equal(mono.out_spikes, st.out_spikes)
    if hidden and mono.layer_spikes is not None:
        for a, b in zip(mono.layer_spikes, st.layer_spikes):
            np.testing.assert_array_equal(a, b)


@pytest.fixture(scope="module")
def lif_surrogate(lif_bank):
    return lif_bank.to_surrogate()


@pytest.fixture(scope="module")
def small_net():
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (12, 8)) * 0.8
    w2 = jax.random.normal(jax.random.PRNGKey(1), (8, 4)) * 0.8
    params = [jnp.asarray([0.58, 0.5, 0.5, 0.5])] * 2
    spec = snn_spec([w1, w2], params)
    spikes = (jax.random.bernoulli(jax.random.PRNGKey(2), 0.2,
                                   (T_STEPS, BATCH, 12)) * 1.5
              ).astype(jnp.float32)
    return spec, spikes


@pytest.fixture(scope="module")
def mixed_net():
    """Crossbar MAC front-end -> LIF readout + recurrent inhibition."""
    rng = np.random.default_rng(3)
    xw = rng.integers(-1, 2, (20, 8)).astype(np.float32)
    lw = (rng.normal(0, 0.5, (8, 6)) * 2.2).astype(np.float32)
    params = jnp.asarray([0.58, 0.5, 0.5, 0.5], jnp.float32)
    inhib = -0.6 * (1 - np.eye(6, dtype=np.float32))
    spec = graph_spec([crossbar_layer(xw), lif_layer(lw, params)],
                      edges=[recurrent_edge(1, 1, inhib)])
    seq = (rng.integers(-1, 2, (T_STEPS, BATCH, 20)) * 0.8
           ).astype(np.float32)
    return spec, jnp.asarray(seq)


# --- bit-equivalence ----------------------------------------------------------

@pytest.mark.parametrize("chunk_ticks", [T_STEPS, 8, 7, 5, 1])
def test_stream_bitidentical_to_monolithic(lif_surrogate, small_net,
                                           chunk_ticks):
    """Every tested chunk size — divisor or not — reproduces the
    monolithic record bit-for-bit (incl. the single end-of-run flush)."""
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    mono = eng.run(spikes)
    st = eng.run_stream(spikes, chunk_ticks=chunk_ticks)
    _assert_runs_identical(mono, st)


@pytest.mark.parametrize("backend", ["behavioral", "golden"])
def test_stream_reference_backends(small_net, backend):
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend=backend)
    _assert_runs_identical(eng.run(spikes),
                           eng.run_stream(spikes, chunk_ticks=7))


def test_stream_crossbar_final_layer():
    """A crossbar-final graph streams too: primary is the LAST tick's
    codes (taken from the last chunk), no spike trace is kept."""
    from repro.core.network import crossbar_mlp_spec
    rng = np.random.default_rng(7)
    ws = [rng.integers(-1, 2, (40, 8)).astype(np.float32),
          rng.integers(-1, 2, (8, 4)).astype(np.float32)]
    spec = crossbar_mlp_spec(ws)
    x = rng.uniform(-0.8, 0.8, (10, 4, 40)).astype(np.float32)
    eng = NetworkEngine(spec, backend="behavioral")
    mono, st = eng.run(x), eng.run_stream(x, chunk_ticks=4)
    _assert_runs_identical(mono, st)
    assert st.out_spikes is None and mono.out_spikes is None


def test_stream_mixed_recurrent_graph(lif_surrogate, small_net, mixed_net,
                                      crossbar_dataset):
    """The acceptance graph: crossbar->LIF with a recurrent edge, bit-
    identical for every tested chunk size through the facade."""
    from repro.core.predictors import PredictorBank
    spec, seq = mixed_net
    banks = {"lif": lif_surrogate,
             "crossbar": PredictorBank("crossbar", families=("mean",
                                                             "linear")
                                       ).fit(crossbar_dataset)}
    mono = lasana.simulate(spec, seq, surrogates=banks, record_hidden=True)
    for chunk in (T_STEPS, 9, 4):
        st = lasana.simulate_stream(spec, seq, chunk_ticks=chunk,
                                    surrogates=banks, record_hidden=True)
        _assert_runs_identical(mono, st)


def test_stream_annotation_mode(lif_surrogate, small_net):
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate,
                        mode="annotation")
    _assert_runs_identical(eng.run(spikes),
                           eng.run_stream(spikes, chunk_ticks=5))


def test_stream_iterator_stimulus_rebuffered(lif_surrogate, small_net):
    """Host-generator stimulus blocks are re-buffered to chunk_ticks and
    still merge to the exact monolithic record."""
    spec, spikes = small_net
    x = np.asarray(spikes)
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    mono = eng.run(spikes)

    def blocks():
        for a in range(0, T_STEPS, 6):          # 6-tick producer blocks
            yield x[a:a + 6]

    st = eng.run_stream(blocks(), chunk_ticks=9)    # 9-tick chunks
    _assert_runs_identical(mono, st)


def test_stream_mesh_batch_parallel(lif_surrogate, small_net):
    """The chunked path composes with shard_map batch sharding."""
    from jax.sharding import Mesh
    spec, spikes = small_net
    mesh = Mesh(np.array(jax.devices()[:1]), ("batch",))
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate,
                        mesh=mesh)
    _assert_runs_identical(eng.run(spikes),
                           eng.run_stream(spikes, chunk_ticks=8))


# --- compile discipline -------------------------------------------------------

def test_chunk_shapes_bound_compiles(lif_surrogate, small_net):
    """<= 2 compiled chunk programs per (T, chunk_ticks): the full-chunk
    shape + the remainder shape; chunk-COUNT changes reuse them all."""
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    eng.run_stream(spikes, chunk_ticks=7)        # chunks 7,7,7,3
    assert eng.compile_count == 2
    # longer stream (T=52: chunks 7x7 + 3), same shapes: no new compiles
    longer = jnp.concatenate([spikes, spikes, spikes[:4]], axis=0)
    eng.run_stream(longer, chunk_ticks=7)
    assert eng.compile_count == 2
    # divisor chunking adds at most ONE new shape (no remainder program)
    eng.run_stream(spikes, chunk_ticks=8)
    assert eng.compile_count == 3


def test_surrogate_hot_swap_zero_recompiles(two_stream_surrogates,
                                            small_net):
    """Swapping equal-structure surrogates per chunk mid-stream reuses
    the compiled chunk programs and demonstrably changes the weights."""
    s1, s2 = two_stream_surrogates
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana")
    base = eng.run_stream(spikes, chunk_ticks=8, surrogates=s1)
    compiles = eng.compile_count
    swapped = eng.run_stream(spikes, chunk_ticks=8,
                             surrogates=itertools.cycle([s1, s2]))
    assert eng.compile_count == compiles
    assert base.energy.sum() != swapped.energy.sum()
    # first chunk used s1 in both runs: identical until the first swap
    np.testing.assert_array_equal(base.energy[:8], swapped.energy[:8])
    assert not np.array_equal(base.energy[8:16], swapped.energy[8:16])


def test_stream_then_monolithic_independent_programs(lif_surrogate,
                                                     small_net):
    """Monolithic and chunked programs cache under distinct keys — one
    run of each compiles exactly one program apiece."""
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    eng.run_stream(spikes, chunk_ticks=T_STEPS)      # one full-T chunk
    assert eng.compile_count == 1
    eng.run(spikes)                                  # same shapes, mono key
    assert eng.compile_count == 2


# --- donation -----------------------------------------------------------------

def test_donated_carries_are_consumed(lif_surrogate, small_net):
    """The chunk program must actually donate: carry / prev-output /
    surrogate-leaf input buffers are deleted (aliased into the outputs),
    while the non-donated stimulus buffer survives."""
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    b = BATCH
    banks = eng._donatable_banks(eng._runtime_banks(None))
    carries = [eng._init_carry(i, b) for i in range(spec.n_layers)]
    prev = [jnp.zeros((b, l.n_out), jnp.float32) for l in spec.layers]
    k0 = jnp.asarray(0.0, jnp.float32)
    key = eng._program_key("stream", b, T_STEPS, banks)
    compiled, _ = eng._compiled(
        key, lambda: eng._build_stream_step(b, banks),
        (spikes, k0, carries, prev, banks))
    outs = compiled(spikes, k0, carries, prev, banks)
    assert all(a.is_deleted() for a in jax.tree.leaves(carries))
    assert all(a.is_deleted() for a in jax.tree.leaves(prev))
    assert all(a.is_deleted() for a in jax.tree.leaves(banks))
    assert not spikes.is_deleted()
    # the returned state is alive and feeds the next chunk
    assert all(not a.is_deleted() for a in jax.tree.leaves(outs[6]))


def test_callers_surrogate_survives_streaming(lif_surrogate, small_net):
    """Donation must consume the stream's PRIVATE copy, never the
    caller's artifact."""
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana")
    eng.run_stream(spikes, chunk_ticks=8, surrogates=lif_surrogate)
    for leaf in jax.tree.leaves(lif_surrogate):
        if hasattr(leaf, "is_deleted"):
            assert not leaf.is_deleted()
    feats = np.zeros((1, 9), np.float32)
    assert np.all(np.isfinite(lif_surrogate.predict_np("M_O", feats)))


# --- generator + merge semantics ----------------------------------------------

def test_generator_yields_per_chunk_records(lif_surrogate, small_net):
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    recs = list(eng.stream(spikes, chunk_ticks=9))
    assert [r.energy.shape[0] for r in recs] == [9, 9, 6]
    # flush lands exactly once, on the final chunk
    assert all(r.flush_energy.sum() == 0.0 for r in recs[:-1])
    assert recs[-1].flush_energy.sum() > 0.0
    _assert_runs_identical(eng.run(spikes), NetworkRun.merge(recs))


def test_streaming_run_live_totals(lif_surrogate, small_net):
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    acc = StreamingRun()
    seen_ticks = []
    for rec in eng.stream(spikes, chunk_ticks=10):
        acc.update(rec)
        seen_ticks.append(acc.ticks)
    assert seen_ticks == [10, 20, 24]            # live mid-stream progress
    run = acc.result()
    assert acc.events == int(run.events.sum())
    np.testing.assert_allclose(acc.energy_j, run.energy.sum(), rtol=1e-7)
    rep = run.report()
    assert rep["network"]["ticks"] == T_STEPS


def test_merge_rejects_mismatched_chunks(lif_surrogate, small_net):
    spec, spikes = small_net
    eng_l = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    eng_b = NetworkEngine(spec, backend="behavioral")
    a = next(iter(eng_l.stream(spikes, chunk_ticks=8)))
    c = next(iter(eng_b.stream(spikes, chunk_ticks=8)))
    with pytest.raises(ValueError, match="different runs"):
        NetworkRun.merge([a, c])
    with pytest.raises(ValueError, match="before any update"):
        StreamingRun().result()


def test_stream_input_validation(lif_surrogate, small_net):
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    with pytest.raises(ValueError, match="chunk_ticks"):
        eng.run_stream(spikes, chunk_ticks=0)
    # argument errors surface at the stream() CALL, not at first next():
    # a dropped generator must not swallow them
    with pytest.raises(ValueError, match="chunk_ticks"):
        eng.stream(spikes, chunk_ticks=-1)
    with pytest.raises(ValueError, match="fan_in"):
        eng.stream(np.zeros((4, 2, 5), np.float32))
    with pytest.raises(ValueError, match="must be"):
        eng.stream(np.zeros((4, 2, 2, 12), np.float32))
    with pytest.raises(ValueError, match="requires surrogates"):
        NetworkEngine(spec, backend="lasana").stream(spikes, chunk_ticks=4)
    with pytest.raises(ValueError, match="fan_in"):
        eng.run_stream(np.zeros((4, 2, 5), np.float32), chunk_ticks=2)
    with pytest.raises(ValueError, match="at least one"):
        eng.run_stream(iter([]), chunk_ticks=2)
    bad_batch = iter([np.zeros((2, BATCH, 12), np.float32),
                      np.zeros((2, BATCH + 1, 12), np.float32)])
    with pytest.raises(ValueError, match="batch"):
        eng.run_stream(bad_batch)


def test_facade_stream_generator(lif_surrogate, small_net):
    """lasana.stream is the facade spelling of the generator variant."""
    spec, spikes = small_net
    recs = list(lasana.stream(spec, spikes, chunk_ticks=8,
                              surrogates=lif_surrogate))
    assert len(recs) == 3
    merged = NetworkRun.merge(recs)
    mono = lasana.simulate(spec, spikes, surrogates=lif_surrogate,
                           record_hidden=False)
    _assert_runs_identical(mono, merged, hidden=False)


# --- generator cleanup + thread safety (ISSUE-8 satellites) -------------------

def test_stream_generator_early_close_settles(lif_surrogate, small_net):
    """Abandoning a stream mid-run (break / close / GC) settles the
    in-flight chunk — donated device buffers are not left dangling — and
    the SAME engine re-streams afterwards with zero recompiles and an
    untouched record."""
    import gc
    spec, spikes = small_net
    eng = NetworkEngine(spec, backend="lasana", surrogates=lif_surrogate)
    mono = eng.run(spikes)
    gen = eng.stream(spikes, chunk_ticks=8)
    next(gen)
    gen.close()                        # explicit close after one chunk
    for rec in eng.stream(spikes, chunk_ticks=8):
        break                          # for-loop break (implicit close)
    dangling = eng.stream(spikes, chunk_ticks=8)
    next(dangling)
    del dangling                       # GC finalization path
    gc.collect()
    compiles = eng.compile_count
    st = NetworkRun.merge(list(eng.stream(spikes, chunk_ticks=8)))
    assert eng.compile_count == compiles
    _assert_runs_identical(mono, st)


def test_concurrent_streams_share_one_program(two_stream_surrogates,
                                              small_net):
    """Two threads streaming through ONE engine — different stimuli,
    different (equal-structure) surrogates — race on first use yet
    compile exactly one chunk program, and each thread's record is
    bit-identical to its sequential run."""
    import threading
    s1, s2 = two_stream_surrogates
    spec, spikes = small_net
    x2 = jnp.roll(spikes, 3, axis=0)
    eng_seq = NetworkEngine(spec, backend="lasana")
    want = {"a": eng_seq.run_stream(spikes, chunk_ticks=8, surrogates=s1),
            "b": eng_seq.run_stream(x2, chunk_ticks=8, surrogates=s2)}
    eng = NetworkEngine(spec, backend="lasana")
    got, errors = {}, []

    def work(name, x, s):
        try:
            got[name] = eng.run_stream(x, chunk_ticks=8, surrogates=s)
        except Exception as err:               # surface in the main thread
            errors.append((name, err))

    threads = [threading.Thread(target=work, args=("a", spikes, s1)),
               threading.Thread(target=work, args=("b", x2, s2))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert eng.compile_count == 1              # the race compiled ONCE
    _assert_runs_identical(want["a"], got["a"])
    _assert_runs_identical(want["b"], got["b"])


@pytest.fixture(scope="module")
def two_stream_surrogates(lif_dataset):
    """Two equal-structure surrogates with different weights (mean+linear
    on disjoint dataset halves would change structure; two seeds keep the
    family selection — and thus the treedef — identical)."""
    import repro.lasana as lasana
    cfg = lambda seed: lasana.TrainConfig(n_runs=50, n_steps=40, seed=seed,
                                          families=("linear",))
    return lasana.train("lif", cfg(1)), lasana.train("lif", cfg(2))
