"""Checkpoint manager: roundtrip, atomicity, GC, async, auto-resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import zstd


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"step": jnp.asarray(7, jnp.int32),
            "params": {"a": jax.random.normal(key, (16, 8)),
                       "b": jax.random.normal(key, (3,)).astype(jnp.bfloat16)},
            "opt": [jnp.zeros((4, 4)), jnp.ones((2,))]}


def test_roundtrip_identity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(10, tree, metadata={"loss": 1.5})
    got, user = mgr.restore(10, tree)
    assert user["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_into_abstract(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got, _ = mgr.restore(1, abstract)
    np.testing.assert_array_equal(np.asarray(got["params"]["a"]),
                                  np.asarray(tree["params"]["a"]))


def test_gc_keeps_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]


def test_half_written_dir_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree)
    os.makedirs(tmp_path / "step_0000009.tmp")
    assert mgr.latest_step() == 5


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3
    got, _ = mgr.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(got["opt"][1]),
                                  np.asarray(tree["opt"][1]))


def test_restore_latest_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_latest(_tree()) is None


def test_codec_recorded(tmp_path):
    """meta.json records which codec wrote the leaves."""
    import json

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, _tree())
    with open(tmp_path / "step_0000002" / "meta.json") as f:
        meta = json.load(f)
    assert meta["codec"] == ("zstd" if zstd is not None else "raw")


@pytest.mark.skipif(zstd is None, reason="zstandard not installed")
def test_zstd_roundtrip_and_compression(tmp_path):
    """zstd path: leaves are .zst, actually compressed, and roundtrip."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.zeros((256, 256))}       # compressible
    mgr.save(4, tree)
    path = tmp_path / "step_0000004"
    leaf = path / "leaf_00000.zst"
    assert leaf.exists()
    assert leaf.stat().st_size < 256 * 256 * 4
    got, _ = mgr.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))
