"""End-to-end behaviour tests for the LASANA system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.circuits import CrossbarRow, LIFNeuron
from repro.core.dataset import TestbenchConfig, build_dataset
from repro.core.simulate import (make_stimulus, run_behavioral, run_golden,
                                 run_lasana)


def test_dataset_event_counts(lif_dataset):
    counts = lif_dataset.counts()
    # all three event classes must occur for the stateful circuit
    assert counts["E1"] > 100
    assert counts["E2"] > 100
    assert counts["E3"] > 1000


def test_crossbar_has_no_e3_dominance(crossbar_dataset):
    counts = crossbar_dataset.counts()
    # nearly every input change moves the crossbar output (paper: no E3 rows)
    assert counts["E1"] > 10 * max(counts["E3"], 1)


def test_golden_energy_positive_and_finite():
    active, x, params = make_stimulus("lif", 64, 50, seed=0)
    g = run_golden("lif", active, x, params)
    assert np.all(np.isfinite(g.energy))
    assert np.all(g.energy >= 0)
    assert g.outputs.shape == (50, 64)


def test_lasana_matches_golden_spikes(lif_bank_mlp):
    active, x, params = make_stimulus("lif", 256, 80, seed=5)
    g = run_golden("lif", active, x, params)
    lz = run_lasana(lif_bank_mlp, "lif", active, x, params)
    acc = float(np.mean((g.outputs > 0.75) == (lz.outputs > 0.75)))
    # 0.9287 with the session fixture's 150-run bank on this container;
    # the paper-scale bank clears 0.95+ (see benchmarks/bench_propagation)
    assert acc > 0.92, f"spike accuracy {acc}"
    e_err = abs(lz.energy.sum() - g.energy.sum()) / g.energy.sum()
    assert e_err < 0.15, f"total energy err {e_err}"


def test_error_does_not_diverge_over_time(lif_bank_mlp):
    """Fig 8 property: state-feedback error must not blow up over ticks."""
    active, x, params = make_stimulus("lif", 256, 90, seed=7)
    g = run_golden("lif", active, x, params)
    lz = run_lasana(lif_bank_mlp, "lif", active, x, params)
    mse = np.mean((g.states - lz.states) ** 2, axis=1)     # per tick
    first = float(np.mean(mse[: len(mse) // 3]))
    last = float(np.mean(mse[-len(mse) // 3:]))
    assert last < 5 * first + 1e-3, (first, last)


def test_oracle_state_mode(lif_bank_mlp):
    """LASANA-O (oracle state) must beat or match LASANA-P on state MSE."""
    active, x, params = make_stimulus("lif", 128, 60, seed=9)
    g = run_golden("lif", active, x, params)
    lp = run_lasana(lif_bank_mlp, "lif", active, x, params)
    lo = run_lasana(lif_bank_mlp, "lif", active, x, params,
                    oracle_states=g.states)
    mse_p = float(np.mean((g.states - lp.states) ** 2))
    mse_o = float(np.mean((g.states - lo.states) ** 2))
    assert mse_o <= mse_p * 1.2, (mse_o, mse_p)


def test_behavioral_runs_all_circuits():
    for name in ("lif", "crossbar"):
        active, x, params = make_stimulus(name, 32, 30, seed=1)
        b = run_behavioral(name, active, x, params)
        assert np.all(np.isfinite(b.outputs))
