"""Deterministic mini-fallback for ``hypothesis`` property tests.

The CPU CI container does not ship hypothesis; rather than losing the
property suites to collection errors, test modules import through

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hyp_fallback import given, settings, st

Fallback semantics: each ``@given`` test runs ``max_examples`` times over
samples drawn from a per-test seeded RNG (crc32 of the qualname), so runs
are reproducible across processes. No shrinking, no database — just enough
to keep the invariants exercised. Strategies cover only what this repo
uses: integers, floats, booleans, sampled_from.
"""

from __future__ import annotations

import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])


st = _Strategies()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Outer decorator in this repo: records max_examples on the runner."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    """Run the test over drawn examples; leaves fixture params visible to
    pytest by rewriting the wrapper signature (hypothesis does the same)."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if arg_strats:
            # positional strategies bind to the rightmost parameters
            bound = {p.name: s for p, s in
                     zip(params[-len(arg_strats):], arg_strats)}
        else:
            bound = dict(kw_strats)
        fixture_params = [p for p in params if p.name not in bound]

        def runner(*args, **kwargs):
            n = getattr(runner, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in bound.items()}
                fn(*args, **kwargs, **drawn)

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__signature__ = sig.replace(parameters=fixture_params)
        return runner

    return deco
