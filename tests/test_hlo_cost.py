"""Loop-aware HLO cost model: parity with XLA on loop-free programs, correct
trip-count multiplication on scans (fwd and fwd+bwd)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_loop_free_parity_with_xla():
    def f(a, b):
        return jnp.sum(jax.nn.relu(a @ b))
    c = _compile(f, jax.ShapeDtypeStruct((512, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 1024), jnp.float32))
    mine = analyze(c.as_text())
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):      # jax 0.4.x wraps in a list
        xla = xla[0]
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.05
    assert abs(mine.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.05


def test_scan_flops_multiply_by_trip_count():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    mine = analyze(c.as_text())
    want = 10 * 2 * 256 ** 3
    assert abs(mine.flops - want) / want < 0.1


def test_grad_scan_counts_both_loops():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y)
    c = _compile(jax.grad(f), jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mine = analyze(c.as_text())
    # fwd 1 matmul + bwd 2 matmuls per step
    want = 10 * 3 * 2 * 128 ** 3
    assert abs(mine.flops - want) / want < 0.15


def test_nested_scan_multiplies():
    def f(x):
        def inner(c, _):
            return jnp.tanh(c @ c), None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mine = analyze(c.as_text())
    want = 12 * 2 * 128 ** 3
    assert abs(mine.flops - want) / want < 0.1
